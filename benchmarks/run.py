"""Benchmark harness — one module per paper artifact.  Prints
``name,us_per_call,derived`` CSV.

  bench_cr_overhead   Fig. 4: no-C/R vs checkpoint-only vs checkpoint+restart
  bench_startup       Fig. 2: restore latency vs ranks x storage tier
  bench_coordinator   §III-A: two-phase barrier latency vs worker count
  bench_kernels       kernel-layer + checkpoint-substrate throughput
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
for p in (str(SRC), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config mode for CI: exercise every benchmark "
                         "path end to end in a couple of minutes; numbers "
                         "are NOT representative, only crashes are failures")
    args = ap.parse_args(argv)

    from benchmarks import bench_coordinator, bench_cr_overhead, bench_kernels, bench_startup

    rows = []
    for mod in (bench_kernels, bench_startup, bench_coordinator, bench_cr_overhead):
        rows.extend(mod.run(RESULTS, smoke=args.smoke))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
