"""Benchmark harness — one module per paper artifact.  Prints
``name,us_per_call,derived`` CSV.

  bench_cr_overhead   Fig. 4: no-C/R vs checkpoint-only vs checkpoint+restart
  bench_startup       Fig. 2: restore latency vs ranks x storage tier
  bench_coordinator   §III-A: two-phase barrier latency vs worker count
  bench_kernels       kernel-layer + checkpoint-substrate throughput
  bench_delta         shard v3: delta save bytes + stale-node peer fetch
  bench_weight_push   serving fleet: delta weight push vs full broadcast

Each module declares the BENCH_ckpt_io.json keys it owns in ``BENCH_KEYS``;
after a run the harness prunes artifact keys no module claims any more, so a
renamed benchmark cannot leave stale rows masquerading as fresh data.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
for p in (str(SRC), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"


def collect_run_meta(smoke: bool = False) -> dict:
    """Provenance stamp for BENCH_ckpt_io.json: which commit / interpreter /
    machine produced the numbers, so the bench trajectory is comparable
    PR-over-PR (a faster row means nothing if the box shrank)."""
    import os
    import platform
    import subprocess
    import time

    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None
    return {
        "git_commit": commit,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "smoke": bool(smoke),
    }


def known_bench_keys(modules) -> set[str]:
    """Union of every key a benchmark module claims in the shared artifact
    (``BENCH_KEYS``), plus the harness's own provenance stamp."""
    known = {"run_meta"}
    for mod in modules:
        known.update(getattr(mod, "BENCH_KEYS", ()))
    return known


def prune_bench_ckpt_io(known: set[str],
                        path: Path | None = None) -> list[str]:
    """Schema check on the merge-written artifact: drop BENCH_ckpt_io.json
    keys no benchmark module produces any more.  merge_bench_ckpt_io never
    deletes, so without this a renamed/retired benchmark would leave its old
    row in the artifact forever, silently read as current data.  Returns the
    pruned keys (for logging/tests)."""
    path = path or (ROOT / "BENCH_ckpt_io.json")
    try:
        data = json.loads(path.read_text())
    except (FileNotFoundError, ValueError):
        return []
    stale = sorted(k for k in data if k not in known)
    if not stale:
        return []
    for k in stale:
        del data[k]
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(data, indent=1))
    tmp.rename(path)
    print(f"[bench] pruned stale artifact keys: {stale}", file=sys.stderr)
    return stale


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config mode for CI: exercise every benchmark "
                         "path end to end in a couple of minutes; numbers "
                         "are NOT representative, only crashes are failures")
    args = ap.parse_args(argv)

    from benchmarks import (bench_coordinator, bench_cr_overhead, bench_delta,
                            bench_kernels, bench_startup, bench_weight_push)

    modules = (bench_kernels, bench_startup, bench_coordinator,
               bench_cr_overhead, bench_delta, bench_weight_push)
    # stamped FIRST so even a partially-crashed run is attributable, and the
    # modules' own merge_bench_ckpt_io calls ride on top of it
    bench_startup.merge_bench_ckpt_io(
        {"run_meta": collect_run_meta(smoke=args.smoke)})
    rows = []
    for mod in modules:
        rows.extend(mod.run(RESULTS, smoke=args.smoke))
    prune_bench_ckpt_io(known_bench_keys(modules))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
