"""Benchmark harness — one module per paper artifact.  Prints
``name,us_per_call,derived`` CSV.

  bench_cr_overhead   Fig. 4: no-C/R vs checkpoint-only vs checkpoint+restart
  bench_startup       Fig. 2: restore latency vs ranks x storage tier
  bench_coordinator   §III-A: two-phase barrier latency vs worker count
  bench_kernels       kernel-layer + checkpoint-substrate throughput
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
for p in (str(SRC), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"


def collect_run_meta(smoke: bool = False) -> dict:
    """Provenance stamp for BENCH_ckpt_io.json: which commit / interpreter /
    machine produced the numbers, so the bench trajectory is comparable
    PR-over-PR (a faster row means nothing if the box shrank)."""
    import os
    import platform
    import subprocess
    import time

    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None
    return {
        "git_commit": commit,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "smoke": bool(smoke),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config mode for CI: exercise every benchmark "
                         "path end to end in a couple of minutes; numbers "
                         "are NOT representative, only crashes are failures")
    args = ap.parse_args(argv)

    from benchmarks import bench_coordinator, bench_cr_overhead, bench_kernels, bench_startup

    # stamped FIRST so even a partially-crashed run is attributable, and the
    # modules' own merge_bench_ckpt_io calls ride on top of it
    bench_startup.merge_bench_ckpt_io(
        {"run_meta": collect_run_meta(smoke=args.smoke)})
    rows = []
    for mod in (bench_kernels, bench_startup, bench_coordinator, bench_cr_overhead):
        rows.extend(mod.run(RESULTS, smoke=args.smoke))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
