"""Paper Fig. 2 analogue: restore/startup latency vs rank count per storage tier.

The paper measures `from mpi4py import MPI` latency vs MPI ranks for different
filesystems, showing container-image caching beats shared filesystems at scale.
Framework analogue: N workers concurrently read their checkpoint shards at
restart.  Tiers carry the simulated bandwidth/latency of DEFAULT_TIERS
(ram/local = node-local container-cache-like; shared = parallel FS whose
*effective* per-reader bandwidth divides by reader count).  Output: mean
restore seconds + effective GB/s per (tier x ranks), plus a ranged-restore
row: reading one leaf of a multi-leaf v2 shard vs parsing the whole file
(the incremental/MxN restart path reads only manifest-referenced ranges).
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

# keys this module owns in BENCH_ckpt_io.json (run.py prunes stale ones)
BENCH_KEYS = ("placement_requeue", "peer_fetch")


def run(results_dir: Path | None = None,
        ranks_list=(1, 4, 16, 64), shard_mb: float = 4.0,
        smoke: bool = False):
    from repro.checkpoint import serialization as SER
    from repro.checkpoint.store import TieredStore
    import tempfile

    if smoke:
        ranks_list, shard_mb = (1, 4), 1.0
    rows = []
    detail = {}
    for tier in ("ram", "local", "shared"):
        detail[tier] = {}
        for ranks in ranks_list:
            with tempfile.TemporaryDirectory() as d:
                store = TieredStore(Path(d), sim_io_factor=1.0)
                payload = np.zeros(int(shard_mb * 1e6 // 4), np.float32)
                data = SER.write_shard_bytes([("w", payload)])
                for w in range(ranks):
                    store.put(tier, f"ck/shard_{w}.bin", data)
                # shared parallel FS: per-reader bandwidth divides under load
                contention = ranks if tier == "shared" else 1

                def reader(w, out):
                    t0 = time.perf_counter()
                    got, _ = store.get_verified(tier, f"ck/shard_{w}.bin")
                    # model contention: replay the simulated delay (c-1) more times
                    spec = store.tiers[tier]
                    time.sleep((contention - 1) * (len(data) / (spec.bandwidth_gbps * 1e9)))
                    out[w] = time.perf_counter() - t0

                times = [0.0] * ranks
                threads = [threading.Thread(target=reader, args=(w, times))
                           for w in range(ranks)]
                t0 = time.perf_counter()
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                wall = time.perf_counter() - t0
                detail[tier][ranks] = {
                    "mean_s": float(np.mean(times)),
                    "wall_s": wall,
                    "gb_per_s": len(data) / max(float(np.mean(times)), 1e-9) / 1e9,
                }
        r1 = detail[tier][ranks_list[0]]
        rN = detail[tier][ranks_list[-1]]
        rows.append({
            "name": f"startup_restore_{tier}",
            "us_per_call": r1["mean_s"] * 1e6,
            "derived": (f"ranks{ranks_list[0]}={r1['mean_s']*1e3:.1f}ms"
                        f"({r1['gb_per_s']:.2f}GB/s) "
                        f"ranks{ranks_list[-1]}={rN['mean_s']*1e3:.1f}ms "
                        f"scale_penalty={rN['mean_s']/max(r1['mean_s'],1e-9):.1f}x"),
        })
    detail["ranged_restore"] = _ranged_restore_detail(shard_mb)
    rr = detail["ranged_restore"]
    rows.append({
        "name": "startup_ranged_restore",
        "us_per_call": rr["one_leaf_s"] * 1e6,
        "derived": (f"full={rr['full_s']*1e3:.1f}ms "
                    f"one_leaf={rr['one_leaf_s']*1e3:.1f}ms "
                    f"bytes={rr['one_leaf_bytes']}/{rr['shard_bytes']}"),
    })
    detail["promoted_restore"] = pr = _promoted_restore_detail(shard_mb)
    rows.append({
        "name": "startup_promoted_restore",
        "us_per_call": pr["promoted_s"] * 1e6,
        "derived": (f"cold_shared={pr['cold_s']*1e3:.1f}ms "
                    f"promoted_local={pr['promoted_s']*1e3:.1f}ms "
                    f"speedup={pr['cold_s']/max(pr['promoted_s'],1e-9):.1f}x"),
    })
    detail["placement_requeue"] = pl = _placement_requeue_detail(shard_mb)
    merge_bench_ckpt_io({"placement_requeue": pl})
    rows.append({
        "name": "startup_placed_vs_blind",
        "us_per_call": pl["placed_mean_s"] * 1e6,
        "derived": (f"placed={pl['placed_mean_s']*1e3:.1f}ms "
                    f"blind={pl['blind_mean_s']*1e3:.1f}ms "
                    f"speedup={pl['placed_speedup']:.1f}x "
                    f"warm={pl['placed_warm_fraction']:.2f}"
                    f"/{pl['blind_warm_fraction']:.2f}"),
    })
    detail["peer_fetch"] = pf = _peer_fetch_detail(
        shard_mb, n_shards=8 if smoke else 32)
    merge_bench_ckpt_io({"peer_fetch": pf})
    rows.append({
        "name": "startup_peer_fetch",
        "us_per_call": pf["peer1_s"] * 1e6,
        "derived": (f"shared={pf['shared_cold_s']*1e3:.1f}ms "
                    f"peer1={pf['peer1_s']*1e3:.1f}ms "
                    f"peer2={pf['peer2_s']*1e3:.1f}ms "
                    f"speedup_1peer={pf['speedup_peer1']:.1f}x "
                    f"scaling_2v1={pf['peer_scaling_2v1']:.2f}x "
                    f"shared_bytes={pf['peer1_shared_bytes']}"),
    })
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "startup.json").write_text(json.dumps(detail, indent=1))
    return rows


def merge_bench_ckpt_io(updates: dict) -> None:
    """Merge keys into the repo-root BENCH_ckpt_io.json tracking artifact
    without clobbering the keys other benchmark modules own (run.py executes
    the modules in sequence; each merges rather than rewrites)."""
    path = Path(__file__).resolve().parents[1] / "BENCH_ckpt_io.json"
    try:
        data = json.loads(path.read_text())
    except (FileNotFoundError, ValueError):
        data = {}
    data.update(updates)
    tmp = path.with_suffix(".tmp")        # atomic: a torn artifact would be
    tmp.write_text(json.dumps(data, indent=1))   # silently reset to {} next run
    tmp.rename(path)


def stamp_run_meta(patch: dict) -> dict:
    """Merge provenance keys into the artifact's run_meta and return the
    merged dict (ready to hand to ``merge_bench_ckpt_io``).
    ``merge_bench_ckpt_io`` replaces top-level keys wholesale, so run_meta is
    read back and updated rather than overwritten (run.py writes it before
    any module runs; a direct module invocation starts from empty)."""
    path = Path(__file__).resolve().parents[1] / "BENCH_ckpt_io.json"
    meta: dict = {}
    try:
        meta = json.loads(path.read_text()).get("run_meta") or {}
    except (FileNotFoundError, ValueError, OSError):
        pass
    meta.update(patch)
    return meta


def _placement_requeue_detail(shard_mb: float, n_nodes: int = 2,
                              cycles: int = 4) -> dict:
    """Placed-vs-blind requeue latency curve (the tentpole's payoff): each
    cycle is one preemption->requeue->restore->train->commit round.  The
    restore-aware policy lands every requeue on the node whose promoted cache
    tracks the training frontier; the blind baseline round-robins, so each
    restore after the first pays shared-filesystem bytes (its own promotion
    is invalidated by the step committed on the OTHER node — exactly the
    paper's cold-container-cache effect)."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore, node_local_tier_roots

    rng = np.random.default_rng(0)
    elems = int(shard_mb * 1e6 // 4 // 4)
    tree = {f"l{i}": rng.standard_normal(elems).astype(np.float32)
            for i in range(4)}

    def run_policy(policy: str) -> list[dict]:
        with tempfile.TemporaryDirectory() as d:
            root = Path(d)

            def mgr(node: int) -> CheckpointManager:
                store = TieredStore(
                    root / "ck", sim_io_factor=1.0, seed=0,
                    tier_roots=node_local_tier_roots(
                        root / "nodes" / f"node{node}"))
                return CheckpointManager(store, CheckpointPolicy(replicas=1, promote="eager"))

            m = mgr(0)                 # initial commit from node0 (untimed)
            step = 1
            m.save(step, tree)
            m.commit(step)
            m.wait_promotions()
            m.close()
            out = []
            for c in range(cycles):
                node = 0 if policy == "placed" else (c % n_nodes)
                m = mgr(node)
                t0 = time.perf_counter()
                m.restore(tree)
                dt = time.perf_counter() - t0
                out.append({
                    "cycle": c, "node": f"node{node}", "restore_s": dt,
                    "promoted": bool((m.last_restore_stats or {}
                                      ).get("promoted"))})
                step += 1              # "train", then checkpoint the frontier
                m.save(step, tree)
                m.commit(step)
                m.wait_promotions()
                m.close()
            return out

    placed = run_policy("placed")
    blind = run_policy("blind")
    p_mean = float(np.mean([r["restore_s"] for r in placed]))
    b_mean = float(np.mean([r["restore_s"] for r in blind]))
    return {
        "n_nodes": n_nodes, "cycles": cycles,
        "placed": placed, "blind": blind,
        "placed_mean_s": p_mean, "blind_mean_s": b_mean,
        "placed_speedup": b_mean / max(p_mean, 1e-9),
        "placed_warm_fraction": float(np.mean(
            [r["promoted"] for r in placed])),
        "blind_warm_fraction": float(np.mean(
            [r["promoted"] for r in blind])),
    }


def _peer_fetch_detail(shard_mb: float, n_shards: int = 32,
                       sim_factor: float = 4.0, workers: int = 8,
                       repeats: int = 5) -> dict:
    """Peer cache fabric (the tentpole's payoff): a cold node restores the
    committed step by multi-source ranged reads from warm PEERS' local caches
    over the simulated interconnect instead of the shared parallel FS.  One
    warm peer should beat the shared tier outright (10x lower per-op
    latency); two peers should beat one (each peer tier brings its own
    concurrency slots, and range tasks round-robin across them — bandwidth
    aggregation).  Shared-tier bytes are counted at the ``_pread``/``get``
    choke points: a peer-served restore must read ZERO of them.

    Setup (commit + peer warm-up) runs with simulation OFF; only the timed
    restores pay tier costs, amplified by ``sim_factor`` so the simulated
    economics dominate this box's real tmpfs/python overhead — many small
    shards, one range task each, is exactly the restart herd the paper's
    Fig. 2 measures."""
    import os
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore, node_local_tier_roots

    rng = np.random.default_rng(0)
    elems = max(1, int(shard_mb * 1e6 // 4 // n_shards))
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_shards)}
    tmp_root = "/dev/shm" if os.path.isdir("/dev/shm") else None

    with tempfile.TemporaryDirectory(dir=tmp_root) as d:
        root = Path(d)

        def store_for(node: str, sim: float = 0.0) -> TieredStore:
            return TieredStore(
                root / "ck", sim_io_factor=sim, seed=0,
                tier_roots=node_local_tier_roots(root / "nodes" / node))

        w = store_for("writer")                  # commit once (untimed)
        pol = CheckpointPolicy(replicas=1)
        for i in range(n_shards):
            CheckpointManager(w, pol, worker_id=i,
                              num_workers=n_shards).save(1, tree)
        CheckpointManager(w, pol,
                          num_workers=n_shards).commit(1, num_workers=n_shards)

        def warm(node: str) -> None:
            m = CheckpointManager(store_for(node), CheckpointPolicy(replicas=1, promote="eager"))
            m.prefetch_latest()
            m.wait_promotions()
            m.close()

        warm("peerA")
        warm("peerB")

        def timed_cold_restore(node: str, peer_roots: dict) -> tuple:
            """Best-of-``repeats`` cold restore (promote off, so every repeat
            is equally cold; min wall rejects this box's scheduler noise)."""
            best = None
            for _ in range(repeats):
                got = _timed_cold_restore_once(node, peer_roots)
                if best is None or got[0] < best[0]:
                    best = got
            return best

        def _timed_cold_restore_once(node: str, peer_roots: dict) -> tuple:
            store = store_for(node, sim=sim_factor)
            shared_dirs = store._node_dirs("shared")
            counts = {"shared": 0}
            orig_pread, orig_get = store._pread, store.get

            def counting_pread(path, off, n):
                data = orig_pread(path, off, n)
                if any(nd in Path(path).parents for nd in shared_dirs):
                    counts["shared"] += len(data)
                return data

            def counting_get(tier, rel):
                data = orig_get(tier, rel)
                if tier == "shared":
                    counts["shared"] += len(data)
                return data

            store._pread, store.get = counting_pread, counting_get
            m = CheckpointManager(store,
                                  CheckpointPolicy(replicas=1, restore_workers=workers,
                                                   promote="off"), node=node, peer_roots=peer_roots)
            t0 = time.perf_counter()
            m.restore(tree)
            dt = time.perf_counter() - t0
            stats = m.last_restore_stats or {}
            m.close()
            return dt, counts["shared"], stats

        peers = {"peerA": root / "nodes" / "peerA",
                 "peerB": root / "nodes" / "peerB"}
        shared_s, shared_bytes, _ = timed_cold_restore("cold0", {})
        peer1_s, peer1_shared, st1 = timed_cold_restore(
            "cold1", {"peerA": peers["peerA"]})
        peer2_s, peer2_shared, st2 = timed_cold_restore("cold2", peers)

    return {
        "n_shards": n_shards,
        "payload_mb": sum(a.nbytes for a in tree.values()) / 1e6,
        "shared_cold_s": shared_s,
        "peer1_s": peer1_s,
        "peer2_s": peer2_s,
        "speedup_peer1": shared_s / max(peer1_s, 1e-9),
        "peer_scaling_2v1": peer1_s / max(peer2_s, 1e-9),
        "shared_cold_bytes": shared_bytes,
        "peer1_shared_bytes": peer1_shared,
        "peer2_shared_bytes": peer2_shared,
        "peer1_bytes_by_tier": st1.get("bytes_by_tier"),
        "peer2_bytes_by_tier": st2.get("bytes_by_tier"),
    }


def _promoted_restore_detail(shard_mb: float, n_shards: int = 4) -> dict:
    """The paper's container-image-cache effect as tier promotion: a cold
    restart reads every shard from the simulated shared parallel FS; after
    ``promote=on_restore`` tees the shards into the node-local tier, the next
    restart is served entirely node-locally."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = int(shard_mb * 1e6 // 4 // n_shards)
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_shards)}
    with tempfile.TemporaryDirectory() as d:
        store = TieredStore(Path(d), sim_io_factor=1.0, seed=0)
        pol = CheckpointPolicy(replicas=1)
        for w in range(n_shards):
            CheckpointManager(store, pol, worker_id=w,
                              num_workers=n_shards).save(1, tree)
        CheckpointManager(store, pol,
                          num_workers=n_shards).commit(1, num_workers=n_shards)

        m = CheckpointManager(store, CheckpointPolicy(promote="on_restore"))
        t0 = time.perf_counter()
        m.restore(tree)
        cold_s = time.perf_counter() - t0
        m.wait_promotions()
        m2 = CheckpointManager(store, CheckpointPolicy(promote="on_restore"))
        t0 = time.perf_counter()
        _, man = m2.restore(tree)
        promoted_s = time.perf_counter() - t0
        stats = m2.last_restore_stats or {}
        m.close()
        m2.close()
    return {"cold_s": cold_s, "promoted_s": promoted_s,
            "served_promoted": bool(stats.get("promoted")),
            "step": man["step"], "n_shards": n_shards}


def _ranged_restore_detail(shard_mb: float, n_leaves: int = 16) -> dict:
    """One leaf out of an n-leaf v2 shard: ranged read vs whole-file parse."""
    import tempfile

    from repro.checkpoint import serialization as SER
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = int(shard_mb * 1e6 // 4 // n_leaves)
    records = [(f"l{i:02d}", rng.standard_normal(elems).astype(np.float32))
               for i in range(n_leaves)]
    with tempfile.TemporaryDirectory() as d:
        store = TieredStore(Path(d))
        read_bytes = [0]
        orig_pread = store._pread

        def counting_pread(path, offset, nbytes):
            read_bytes[0] += nbytes
            return orig_pread(path, offset, nbytes)

        store._pread = counting_pread
        store.put_stream(
            "local", "ck/shard.bin",
            lambda fp: SER.write_shard_stream(fp, records))
        shard_bytes = store.size("local", "ck/shard.bin")

        t0 = time.perf_counter()
        store.get_verified("local", "ck/shard.bin")
        full_s = time.perf_counter() - t0

        read_bytes[0] = 0
        t0 = time.perf_counter()
        store.read_shard_leaves("local", "ck/shard.bin", [records[-1][0]])
        one_leaf_s = time.perf_counter() - t0
        return {"full_s": full_s, "one_leaf_s": one_leaf_s,
                "one_leaf_bytes": read_bytes[0], "shard_bytes": shard_bytes,
                "n_leaves": n_leaves}
