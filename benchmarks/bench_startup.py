"""Paper Fig. 2 analogue: restore/startup latency vs rank count per storage tier.

The paper measures `from mpi4py import MPI` latency vs MPI ranks for different
filesystems, showing container-image caching beats shared filesystems at scale.
Framework analogue: N workers concurrently read their checkpoint shards at
restart.  Tiers carry the simulated bandwidth/latency of DEFAULT_TIERS
(ram/local = node-local container-cache-like; shared = parallel FS whose
*effective* per-reader bandwidth divides by reader count).  Output: mean
restore seconds + effective GB/s per (tier x ranks), plus a ranged-restore
row: reading one leaf of a multi-leaf v2 shard vs parsing the whole file
(the incremental/MxN restart path reads only manifest-referenced ranges).
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np


def run(results_dir: Path | None = None,
        ranks_list=(1, 4, 16, 64), shard_mb: float = 4.0,
        smoke: bool = False):
    from repro.checkpoint import serialization as SER
    from repro.checkpoint.store import TieredStore
    import tempfile

    if smoke:
        ranks_list, shard_mb = (1, 4), 1.0
    rows = []
    detail = {}
    for tier in ("ram", "local", "shared"):
        detail[tier] = {}
        for ranks in ranks_list:
            with tempfile.TemporaryDirectory() as d:
                store = TieredStore(Path(d), sim_io_factor=1.0)
                payload = np.zeros(int(shard_mb * 1e6 // 4), np.float32)
                data = SER.write_shard_bytes([("w", payload)])
                for w in range(ranks):
                    store.put(tier, f"ck/shard_{w}.bin", data)
                # shared parallel FS: per-reader bandwidth divides under load
                contention = ranks if tier == "shared" else 1

                def reader(w, out):
                    t0 = time.perf_counter()
                    got, _ = store.get_verified(tier, f"ck/shard_{w}.bin")
                    # model contention: replay the simulated delay (c-1) more times
                    spec = store.tiers[tier]
                    time.sleep((contention - 1) * (len(data) / (spec.bandwidth_gbps * 1e9)))
                    out[w] = time.perf_counter() - t0

                times = [0.0] * ranks
                threads = [threading.Thread(target=reader, args=(w, times))
                           for w in range(ranks)]
                t0 = time.perf_counter()
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                wall = time.perf_counter() - t0
                detail[tier][ranks] = {
                    "mean_s": float(np.mean(times)),
                    "wall_s": wall,
                    "gb_per_s": len(data) / max(float(np.mean(times)), 1e-9) / 1e9,
                }
        r1 = detail[tier][ranks_list[0]]
        rN = detail[tier][ranks_list[-1]]
        rows.append({
            "name": f"startup_restore_{tier}",
            "us_per_call": r1["mean_s"] * 1e6,
            "derived": (f"ranks{ranks_list[0]}={r1['mean_s']*1e3:.1f}ms"
                        f"({r1['gb_per_s']:.2f}GB/s) "
                        f"ranks{ranks_list[-1]}={rN['mean_s']*1e3:.1f}ms "
                        f"scale_penalty={rN['mean_s']/max(r1['mean_s'],1e-9):.1f}x"),
        })
    detail["ranged_restore"] = _ranged_restore_detail(shard_mb)
    rr = detail["ranged_restore"]
    rows.append({
        "name": "startup_ranged_restore",
        "us_per_call": rr["one_leaf_s"] * 1e6,
        "derived": (f"full={rr['full_s']*1e3:.1f}ms "
                    f"one_leaf={rr['one_leaf_s']*1e3:.1f}ms "
                    f"bytes={rr['one_leaf_bytes']}/{rr['shard_bytes']}"),
    })
    detail["promoted_restore"] = pr = _promoted_restore_detail(shard_mb)
    rows.append({
        "name": "startup_promoted_restore",
        "us_per_call": pr["promoted_s"] * 1e6,
        "derived": (f"cold_shared={pr['cold_s']*1e3:.1f}ms "
                    f"promoted_local={pr['promoted_s']*1e3:.1f}ms "
                    f"speedup={pr['cold_s']/max(pr['promoted_s'],1e-9):.1f}x"),
    })
    detail["placement_requeue"] = pl = _placement_requeue_detail(shard_mb)
    merge_bench_ckpt_io({"placement_requeue": pl})
    rows.append({
        "name": "startup_placed_vs_blind",
        "us_per_call": pl["placed_mean_s"] * 1e6,
        "derived": (f"placed={pl['placed_mean_s']*1e3:.1f}ms "
                    f"blind={pl['blind_mean_s']*1e3:.1f}ms "
                    f"speedup={pl['placed_speedup']:.1f}x "
                    f"warm={pl['placed_warm_fraction']:.2f}"
                    f"/{pl['blind_warm_fraction']:.2f}"),
    })
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "startup.json").write_text(json.dumps(detail, indent=1))
    return rows


def merge_bench_ckpt_io(updates: dict) -> None:
    """Merge keys into the repo-root BENCH_ckpt_io.json tracking artifact
    without clobbering the keys other benchmark modules own (run.py executes
    the modules in sequence; each merges rather than rewrites)."""
    path = Path(__file__).resolve().parents[1] / "BENCH_ckpt_io.json"
    try:
        data = json.loads(path.read_text())
    except (FileNotFoundError, ValueError):
        data = {}
    data.update(updates)
    tmp = path.with_suffix(".tmp")        # atomic: a torn artifact would be
    tmp.write_text(json.dumps(data, indent=1))   # silently reset to {} next run
    tmp.rename(path)


def _placement_requeue_detail(shard_mb: float, n_nodes: int = 2,
                              cycles: int = 4) -> dict:
    """Placed-vs-blind requeue latency curve (the tentpole's payoff): each
    cycle is one preemption->requeue->restore->train->commit round.  The
    restore-aware policy lands every requeue on the node whose promoted cache
    tracks the training frontier; the blind baseline round-robins, so each
    restore after the first pays shared-filesystem bytes (its own promotion
    is invalidated by the step committed on the OTHER node — exactly the
    paper's cold-container-cache effect)."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.store import TieredStore, node_local_tier_roots

    rng = np.random.default_rng(0)
    elems = int(shard_mb * 1e6 // 4 // 4)
    tree = {f"l{i}": rng.standard_normal(elems).astype(np.float32)
            for i in range(4)}

    def run_policy(policy: str) -> list[dict]:
        with tempfile.TemporaryDirectory() as d:
            root = Path(d)

            def mgr(node: int) -> CheckpointManager:
                store = TieredStore(
                    root / "ck", sim_io_factor=1.0, seed=0,
                    tier_roots=node_local_tier_roots(
                        root / "nodes" / f"node{node}"))
                return CheckpointManager(store, replicas=1, promote="eager")

            m = mgr(0)                 # initial commit from node0 (untimed)
            step = 1
            m.save(step, tree)
            m.commit(step)
            m.wait_promotions()
            m.close()
            out = []
            for c in range(cycles):
                node = 0 if policy == "placed" else (c % n_nodes)
                m = mgr(node)
                t0 = time.perf_counter()
                m.restore(tree)
                dt = time.perf_counter() - t0
                out.append({
                    "cycle": c, "node": f"node{node}", "restore_s": dt,
                    "promoted": bool((m.last_restore_stats or {}
                                      ).get("promoted"))})
                step += 1              # "train", then checkpoint the frontier
                m.save(step, tree)
                m.commit(step)
                m.wait_promotions()
                m.close()
            return out

    placed = run_policy("placed")
    blind = run_policy("blind")
    p_mean = float(np.mean([r["restore_s"] for r in placed]))
    b_mean = float(np.mean([r["restore_s"] for r in blind]))
    return {
        "n_nodes": n_nodes, "cycles": cycles,
        "placed": placed, "blind": blind,
        "placed_mean_s": p_mean, "blind_mean_s": b_mean,
        "placed_speedup": b_mean / max(p_mean, 1e-9),
        "placed_warm_fraction": float(np.mean(
            [r["promoted"] for r in placed])),
        "blind_warm_fraction": float(np.mean(
            [r["promoted"] for r in blind])),
    }


def _promoted_restore_detail(shard_mb: float, n_shards: int = 4) -> dict:
    """The paper's container-image-cache effect as tier promotion: a cold
    restart reads every shard from the simulated shared parallel FS; after
    ``promote=on_restore`` tees the shards into the node-local tier, the next
    restart is served entirely node-locally."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = int(shard_mb * 1e6 // 4 // n_shards)
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_shards)}
    with tempfile.TemporaryDirectory() as d:
        store = TieredStore(Path(d), sim_io_factor=1.0, seed=0)
        for w in range(n_shards):
            CheckpointManager(store, worker_id=w, num_workers=n_shards,
                              replicas=1).save(1, tree)
        CheckpointManager(store, num_workers=n_shards,
                          replicas=1).commit(1, num_workers=n_shards)

        m = CheckpointManager(store, promote="on_restore")
        t0 = time.perf_counter()
        m.restore(tree)
        cold_s = time.perf_counter() - t0
        m.wait_promotions()
        m2 = CheckpointManager(store, promote="on_restore")
        t0 = time.perf_counter()
        _, man = m2.restore(tree)
        promoted_s = time.perf_counter() - t0
        stats = m2.last_restore_stats or {}
        m.close()
        m2.close()
    return {"cold_s": cold_s, "promoted_s": promoted_s,
            "served_promoted": bool(stats.get("promoted")),
            "step": man["step"], "n_shards": n_shards}


def _ranged_restore_detail(shard_mb: float, n_leaves: int = 16) -> dict:
    """One leaf out of an n-leaf v2 shard: ranged read vs whole-file parse."""
    import tempfile

    from repro.checkpoint import serialization as SER
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = int(shard_mb * 1e6 // 4 // n_leaves)
    records = [(f"l{i:02d}", rng.standard_normal(elems).astype(np.float32))
               for i in range(n_leaves)]
    with tempfile.TemporaryDirectory() as d:
        store = TieredStore(Path(d))
        read_bytes = [0]
        orig_pread = store._pread

        def counting_pread(path, offset, nbytes):
            read_bytes[0] += nbytes
            return orig_pread(path, offset, nbytes)

        store._pread = counting_pread
        store.put_stream(
            "local", "ck/shard.bin",
            lambda fp: SER.write_shard_stream(fp, records))
        shard_bytes = store.size("local", "ck/shard.bin")

        t0 = time.perf_counter()
        store.get_verified("local", "ck/shard.bin")
        full_s = time.perf_counter() - t0

        read_bytes[0] = 0
        t0 = time.perf_counter()
        store.read_shard_leaves("local", "ck/shard.bin", [records[-1][0]])
        one_leaf_s = time.perf_counter() - t0
        return {"full_s": full_s, "one_leaf_s": one_leaf_s,
                "one_leaf_bytes": read_bytes[0], "shard_bytes": shard_bytes,
                "n_leaves": n_leaves}
