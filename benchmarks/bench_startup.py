"""Paper Fig. 2 analogue: restore/startup latency vs rank count per storage tier.

The paper measures `from mpi4py import MPI` latency vs MPI ranks for different
filesystems, showing container-image caching beats shared filesystems at scale.
Framework analogue: N workers concurrently read their checkpoint shards at
restart.  Tiers carry the simulated bandwidth/latency of DEFAULT_TIERS
(ram/local = node-local container-cache-like; shared = parallel FS whose
*effective* per-reader bandwidth divides by reader count).  Output: mean
restore seconds per (tier x ranks).
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np


def run(results_dir: Path | None = None,
        ranks_list=(1, 4, 16, 64), shard_mb: float = 4.0):
    from repro.checkpoint import serialization as SER
    from repro.checkpoint.store import DEFAULT_TIERS, TieredStore
    import tempfile

    rows = []
    detail = {}
    for tier in ("ram", "local", "shared"):
        detail[tier] = {}
        for ranks in ranks_list:
            with tempfile.TemporaryDirectory() as d:
                store = TieredStore(Path(d), sim_io_factor=1.0)
                payload = np.zeros(int(shard_mb * 1e6 // 4), np.float32)
                data = SER.write_shard_bytes([("w", payload)])
                for w in range(ranks):
                    store.put(tier, f"ck/shard_{w}.bin", data)
                # shared parallel FS: per-reader bandwidth divides under load
                contention = ranks if tier == "shared" else 1

                def reader(w, out):
                    t0 = time.perf_counter()
                    got, _ = store.get_verified(tier, f"ck/shard_{w}.bin")
                    # model contention: replay the simulated delay (c-1) more times
                    spec = store.tiers[tier]
                    time.sleep((contention - 1) * (len(data) / (spec.bandwidth_gbps * 1e9)))
                    out[w] = time.perf_counter() - t0

                times = [0.0] * ranks
                threads = [threading.Thread(target=reader, args=(w, times))
                           for w in range(ranks)]
                t0 = time.perf_counter()
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                wall = time.perf_counter() - t0
                detail[tier][ranks] = {"mean_s": float(np.mean(times)),
                                       "wall_s": wall}
        r1 = detail[tier][ranks_list[0]]["mean_s"]
        rN = detail[tier][ranks_list[-1]]["mean_s"]
        rows.append({
            "name": f"startup_restore_{tier}",
            "us_per_call": r1 * 1e6,
            "derived": (f"ranks{ranks_list[0]}={r1*1e3:.1f}ms "
                        f"ranks{ranks_list[-1]}={rN*1e3:.1f}ms "
                        f"scale_penalty={rN/max(r1,1e-9):.1f}x"),
        })
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "startup.json").write_text(json.dumps(detail, indent=1))
    return rows
