"""Serving-fleet weight push: delta distribution over the chunk fabric vs a
naive full-shard broadcast.

One artifact row:

  weight_push    a trainer commits step 2 as a small delta and announces it
                 on the registry push plane; N serving replicas (each warm
                 at step 1 from their initial restore) sync via
                 ``WeightSyncClient`` — unchanged chunks from their OWN
                 node-local cache, the delta from the publisher's promoted
                 cache (peer tier), shared-filesystem reads ~0.  The naive
                 arm re-restores the FULL shard from the shared tier on
                 every replica.  Propagation time covers poll+fetch+stage
                 (off the request path); the request-visible stall is ONLY
                 the double-buffer pointer swap, reported separately.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

# keys this module owns in BENCH_ckpt_io.json (run.py prunes stale ones)
BENCH_KEYS = ("weight_push",)

N_REPLICAS = 4
SIM_IO = 1.0          # replicas read over the simulated interconnect/pfs


def _mutate(tree: dict, frac_leaves: float, elems: int) -> dict:
    """Same churn pattern as bench_delta: a fine-tune push touches a slice
    of the first ``frac_leaves`` of the leaves."""
    out = dict(tree)
    names = sorted(out)
    for name in names[:max(1, int(len(names) * frac_leaves))]:
        a = out[name].copy()
        a[:elems] += 1.0
        out[name] = a
    return out


def _weight_push_detail(payload_mb: int, n_replicas: int = N_REPLICAS,
                        n_leaves: int = 8,
                        chunk_bytes: int = 256 << 10) -> dict:
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore, node_local_tier_roots
    from repro.sched.cache_registry import CacheRegistry
    from repro.serve.weight_sync import ParamHandle, WeightSyncClient

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}
    payload_bytes = sum(a.nbytes for a in tree.values())

    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        registry = CacheRegistry(root / "registry")

        def store_for(node: str, sim: float = 0.0) -> TieredStore:
            return TieredStore(
                root / "ck", sim_io_factor=sim, seed=0,
                tier_roots=node_local_tier_roots(root / "nodes" / node))

        # publisher (the fine-tune trainer): eager promotion keeps its own
        # node-local cache at the pushed step, and the registry entry from
        # that promotion is what lets the fleet fetch the delta peer-to-peer
        # instead of N times from the shared tier
        pub = CheckpointManager(
            store_for("publisher"),
            CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes,
                             promote="eager"),
            node="publisher", registry=registry)
        pub.save(1, tree)
        man1 = pub.commit(1)
        pub.wait_promotions()
        registry.announce_push(step=1, node="publisher",
                               manifest_version=man1.get("manifest_version"))

        # fleet start-up: every replica restores the announced step (its
        # on_restore promotion warms the replica's own node-local cache)
        fleet = []
        for i in range(n_replicas):
            name = f"r{i}"
            mgr = CheckpointManager(
                store_for(name, sim=SIM_IO),
                CheckpointPolicy(replicas=1, delta=True,
                                 chunk_bytes=chunk_bytes,
                                 promote="on_restore"),
                node=name, registry=registry)
            host, man = mgr.restore(tree)
            mgr.wait_promotions()
            handle = ParamHandle(host, step=man["step"])
            fleet.append((name, mgr, handle,
                          WeightSyncClient(mgr, handle, tree,
                                           registry=registry, replica=name)))

        # the push: a small delta committed and announced
        tree2 = _mutate(tree, 1.0 / n_leaves, chunk_bytes // 8)
        p = pub.save(2, tree2)
        man2 = pub.commit(2)
        pub.wait_promotions()
        registry.announce_push(step=2, node="publisher",
                               manifest_version=man2.get("manifest_version"))
        delta_bytes = p["delta"]["bytes_written"]

        # fleet convergence: poll + fetch + stage per replica (off the
        # request path), then one boundary swap (the request-visible part)
        per_replica = []
        t_fleet = time.perf_counter()
        for name, mgr, handle, client in fleet:
            t0 = time.perf_counter()
            rec = client.sync_once()
            fetch_s = time.perf_counter() - t0
            handle.commit_pending()
            per_replica.append({
                "replica": name, "fetch_s": fetch_s,
                "swap_stall_s": handle.last_swap_s,
                "bytes_by_tier": rec["bytes_by_tier"],
                "bytes_read": rec["bytes_read"],
            })
        propagation_s = time.perf_counter() - t_fleet
        for name, mgr, handle, client in fleet:
            assert handle.step == 2, f"{name} did not converge"
            np.testing.assert_array_equal(handle.current["l00"], tree2["l00"])
            mgr.close()
        status = registry.replica_status()
        pub.close()

        # naive arm: no delta plane, no peers — every replica re-restores
        # the FULL shard from the shared tier (the pre-fabric broadcast)
        full_store = TieredStore(root / "full", seed=0)
        w = CheckpointManager(full_store, CheckpointPolicy(replicas=1))
        w.save(1, tree)
        w.commit(1)
        w.save(2, tree2)
        w.commit(2)
        w.close()
        full_bytes = full_store.size(
            "shared", "ckpt/step_0000000002/shard_w00000.bin")
        naive_rows = []
        t_fleet = time.perf_counter()
        for i in range(n_replicas):
            m = CheckpointManager(
                TieredStore(root / "full", sim_io_factor=SIM_IO, seed=0,
                            tier_roots=node_local_tier_roots(
                                root / "nodes" / f"naive{i}")),
                CheckpointPolicy(replicas=1))
            t0 = time.perf_counter()
            m.restore(tree, 2)
            naive_rows.append({"replica": f"naive{i}",
                               "fetch_s": time.perf_counter() - t0,
                               "bytes_by_tier":
                                   (m.last_restore_stats or {}).get(
                                       "bytes_by_tier")})
            m.close()
        broadcast_s = time.perf_counter() - t_fleet

    fleet_by_tier: dict = {}
    for r in per_replica:
        for t, n in (r["bytes_by_tier"] or {}).items():
            fleet_by_tier[t] = fleet_by_tier.get(t, 0) + n
    shared_read = fleet_by_tier.get("shared", 0)
    return {
        "payload_mb": payload_bytes / 1e6,
        "chunk_bytes": chunk_bytes,
        "n_replicas": n_replicas,
        "delta_bytes_committed": delta_bytes,
        "full_shard_bytes": full_bytes,
        "propagation_s": propagation_s,
        "broadcast_s": broadcast_s,
        "speedup_vs_broadcast": broadcast_s / max(propagation_s, 1e-9),
        "per_replica": per_replica,
        "naive_per_replica": naive_rows,
        "fleet_bytes_by_tier": fleet_by_tier,
        "fleet_shared_read_bytes": shared_read,
        # the acceptance ratios: fleet shared reads vs ONE delta, and vs
        # the N-replica full broadcast it replaces
        "shared_vs_delta_ratio": shared_read / max(delta_bytes, 1),
        "shared_vs_naive_ratio": shared_read / max(n_replicas * full_bytes, 1),
        "max_swap_stall_s": max(r["swap_stall_s"] for r in per_replica),
        "mean_fetch_s": float(np.mean([r["fetch_s"] for r in per_replica])),
        "replica_status": {k: {"step": v.get("step"), "lag": v.get("lag"),
                               "phase": v.get("phase")}
                           for k, v in status.items()},
    }


def run(results_dir: Path | None = None, smoke: bool = False):
    from benchmarks.bench_startup import merge_bench_ckpt_io

    payload_mb = 8 if smoke else 64
    detail = _weight_push_detail(payload_mb)
    merge_bench_ckpt_io({"weight_push": detail})
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "weight_push.json").write_text(
            json.dumps({"weight_push": detail}, indent=1))
    return [{
        "name": "ckpt_weight_push",
        "us_per_call": detail["propagation_s"] * 1e6,
        "derived": (
            f"replicas={detail['n_replicas']} "
            f"prop={detail['propagation_s']*1e3:.1f}ms "
            f"broadcast={detail['broadcast_s']*1e3:.1f}ms "
            f"speedup={detail['speedup_vs_broadcast']:.1f}x "
            f"shared={detail['fleet_shared_read_bytes']} "
            f"delta={detail['delta_bytes_committed']} "
            f"swap_stall={detail['max_swap_stall_s']*1e6:.0f}us"),
    }]
