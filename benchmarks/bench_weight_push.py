"""Serving-fleet weight push: delta distribution over the chunk fabric vs a
naive full-shard broadcast.

Two artifact rows:

  weight_push    a trainer commits step 2 as a small delta and announces it
                 on the registry push plane; N serving replicas (each warm
                 at step 1 from their initial restore) sync via
                 ``WeightSyncClient`` — unchanged chunks from their OWN
                 node-local cache, the delta from the publisher's promoted
                 cache (peer tier), shared-filesystem reads ~0.  The naive
                 arm re-restores the FULL shard from the shared tier on
                 every replica.  Propagation time covers poll+fetch+stage
                 (off the request path); the request-visible stall is ONLY
                 the double-buffer pointer swap, reported separately.
                 Single-process (the PR-7 topology: replicas iterated
                 inline, publisher promoted cache as the peer source).

  weight_push_fleet
                 the PR-8 topology: every replica is a REAL OS process
                 (tests/fleet_harness.py), the publisher commits to the
                 shared tier only (``promote="off"``), and replicas
                 propagate deltas to each other via follower-cache
                 advertisements.  The headline scaling claim: shared-tier
                 bytes per push stay ~1x the delta as the fleet grows
                 (exactly one seed replica pays the shared fetch; everyone
                 else goes replica-to-replica), with the device upload
                 pipelined against the next fetch.  A paused-publisher
                 phase shows the fleet DRAINING (no StaleReplicaError
                 mid-generation) and re-admitting after catch-up.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

_TESTS = Path(__file__).resolve().parents[1] / "tests"
if str(_TESTS) not in sys.path:
    sys.path.insert(0, str(_TESTS))

# keys this module owns in BENCH_ckpt_io.json (run.py prunes stale ones)
BENCH_KEYS = ("weight_push", "weight_push_fleet")

N_REPLICAS = 4
SIM_IO = 1.0          # replicas read over the simulated interconnect/pfs


def _mutate(tree: dict, frac_leaves: float, elems: int) -> dict:
    """Same churn pattern as bench_delta: a fine-tune push touches a slice
    of the first ``frac_leaves`` of the leaves."""
    out = dict(tree)
    names = sorted(out)
    for name in names[:max(1, int(len(names) * frac_leaves))]:
        a = out[name].copy()
        a[:elems] += 1.0
        out[name] = a
    return out


def _weight_push_detail(payload_mb: int, n_replicas: int = N_REPLICAS,
                        n_leaves: int = 8,
                        chunk_bytes: int = 256 << 10) -> dict:
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore, node_local_tier_roots
    from repro.sched.cache_registry import CacheRegistry
    from repro.serve.weight_sync import ParamHandle, WeightSyncClient

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}
    payload_bytes = sum(a.nbytes for a in tree.values())

    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        registry = CacheRegistry(root / "registry")

        def store_for(node: str, sim: float = 0.0) -> TieredStore:
            return TieredStore(
                root / "ck", sim_io_factor=sim, seed=0,
                tier_roots=node_local_tier_roots(root / "nodes" / node))

        # publisher (the fine-tune trainer): eager promotion keeps its own
        # node-local cache at the pushed step, and the registry entry from
        # that promotion is what lets the fleet fetch the delta peer-to-peer
        # instead of N times from the shared tier
        pub = CheckpointManager(
            store_for("publisher"),
            CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes,
                             promote="eager"),
            node="publisher", registry=registry)
        pub.save(1, tree)
        man1 = pub.commit(1)
        pub.wait_promotions()
        registry.announce_push(step=1, node="publisher",
                               manifest_version=man1.get("manifest_version"))

        # fleet start-up: every replica restores the announced step (its
        # on_restore promotion warms the replica's own node-local cache)
        fleet = []
        for i in range(n_replicas):
            name = f"r{i}"
            mgr = CheckpointManager(
                store_for(name, sim=SIM_IO),
                CheckpointPolicy(replicas=1, delta=True,
                                 chunk_bytes=chunk_bytes,
                                 promote="on_restore"),
                node=name, registry=registry)
            host, man = mgr.restore(tree)
            mgr.wait_promotions()
            handle = ParamHandle(host, step=man["step"])
            fleet.append((name, mgr, handle,
                          WeightSyncClient(mgr, handle, tree,
                                           registry=registry, replica=name)))

        # the push: a small delta committed and announced
        tree2 = _mutate(tree, 1.0 / n_leaves, chunk_bytes // 8)
        p = pub.save(2, tree2)
        man2 = pub.commit(2)
        pub.wait_promotions()
        registry.announce_push(step=2, node="publisher",
                               manifest_version=man2.get("manifest_version"))
        delta_bytes = p["delta"]["bytes_written"]

        # fleet convergence: poll + fetch + stage per replica (off the
        # request path), then one boundary swap (the request-visible part)
        per_replica = []
        t_fleet = time.perf_counter()
        for name, mgr, handle, client in fleet:
            t0 = time.perf_counter()
            rec = client.sync_once()
            fetch_s = time.perf_counter() - t0
            handle.commit_pending()
            per_replica.append({
                "replica": name, "fetch_s": fetch_s,
                "swap_stall_s": handle.last_swap_s,
                "bytes_by_tier": rec["bytes_by_tier"],
                "bytes_read": rec["bytes_read"],
            })
        propagation_s = time.perf_counter() - t_fleet
        for name, mgr, handle, client in fleet:
            assert handle.step == 2, f"{name} did not converge"
            np.testing.assert_array_equal(handle.current["l00"], tree2["l00"])
            mgr.close()
        status = registry.replica_status()
        pub.close()

        # naive arm: no delta plane, no peers — every replica re-restores
        # the FULL shard from the shared tier (the pre-fabric broadcast)
        full_store = TieredStore(root / "full", seed=0)
        w = CheckpointManager(full_store, CheckpointPolicy(replicas=1))
        w.save(1, tree)
        w.commit(1)
        w.save(2, tree2)
        w.commit(2)
        w.close()
        full_bytes = full_store.size(
            "shared", "ckpt/step_0000000002/shard_w00000.bin")
        naive_rows = []
        t_fleet = time.perf_counter()
        for i in range(n_replicas):
            m = CheckpointManager(
                TieredStore(root / "full", sim_io_factor=SIM_IO, seed=0,
                            tier_roots=node_local_tier_roots(
                                root / "nodes" / f"naive{i}")),
                CheckpointPolicy(replicas=1))
            t0 = time.perf_counter()
            m.restore(tree, 2)
            naive_rows.append({"replica": f"naive{i}",
                               "fetch_s": time.perf_counter() - t0,
                               "bytes_by_tier":
                                   (m.last_restore_stats or {}).get(
                                       "bytes_by_tier")})
            m.close()
        broadcast_s = time.perf_counter() - t_fleet

    fleet_by_tier: dict = {}
    for r in per_replica:
        for t, n in (r["bytes_by_tier"] or {}).items():
            fleet_by_tier[t] = fleet_by_tier.get(t, 0) + n
    shared_read = fleet_by_tier.get("shared", 0)
    return {
        "payload_mb": payload_bytes / 1e6,
        "chunk_bytes": chunk_bytes,
        "n_replicas": n_replicas,
        "delta_bytes_committed": delta_bytes,
        "full_shard_bytes": full_bytes,
        "propagation_s": propagation_s,
        "broadcast_s": broadcast_s,
        "speedup_vs_broadcast": broadcast_s / max(propagation_s, 1e-9),
        "per_replica": per_replica,
        "naive_per_replica": naive_rows,
        "fleet_bytes_by_tier": fleet_by_tier,
        "fleet_shared_read_bytes": shared_read,
        # the acceptance ratios: fleet shared reads vs ONE delta, and vs
        # the N-replica full broadcast it replaces
        "shared_vs_delta_ratio": shared_read / max(delta_bytes, 1),
        "shared_vs_naive_ratio": shared_read / max(n_replicas * full_bytes, 1),
        "max_swap_stall_s": max(r["swap_stall_s"] for r in per_replica),
        "mean_fetch_s": float(np.mean([r["fetch_s"] for r in per_replica])),
        "replica_status": {k: {"step": v.get("step"), "lag": v.get("lag"),
                               "phase": v.get("phase")}
                           for k, v in status.items()},
    }


def _wait_fleet_step(registry, names, step, timeout_s=60.0) -> None:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        status = registry.replica_status()
        if all(n in status and (status[n].get("step") or 0) >= step
               for n in names):
            return
        time.sleep(0.02)
    raise TimeoutError(f"fleet never reached step {step}: "
                       f"{registry.replica_status()}")


def _wait_fleet_phase(registry, names, phase, timeout_s=60.0) -> None:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        status = registry.replica_status()
        if all(n in status and status[n].get("phase") == phase
               for n in names):
            return
        time.sleep(0.02)
    raise TimeoutError(f"fleet never reached phase {phase}: "
                       f"{registry.replica_status()}")


def _fleet_arm(root: Path, fleet_size: int, tree: dict, *,
               chunk_bytes: int, churn_elems: int) -> dict:
    """One fleet size: real follower processes, two paced delta pushes.
    Replica r0 is the ungated seed; everyone else gates each fetch on a
    peer follower-cache advertisement, so the measured shared bytes are
    the steady-state fabric, not a start-up race."""
    import fleet_harness as fh

    pub = fh.FleetPublisher(root, chunk_bytes=chunk_bytes)
    pub.push(1, tree)
    names = [f"r{i}" for i in range(fleet_size)]
    cfgs = [fh.replica_config(root, n, batches=1, final_step=3,
                              gate_on_peers=(n != "r0"),
                              pipeline_uploads=True, gen_s=0.002)
            for n in names]
    procs = [(c, fh.spawn_replica(c)) for c in cfgs]

    push_meta: dict[int, dict] = {}
    for step, leaf_frac in ((2, 0.25), (3, 0.25)):
        _wait_fleet_step(pub.registry, names, step - 1)
        tree = _mutate(tree, leaf_frac, churn_elems)
        push_meta[step] = pub.push(step, tree)
    results = fh.wait_fleet(procs, timeout_s=180.0)
    pub.close()
    for name, res in results.items():
        if "error" in res:
            raise RuntimeError(f"fleet replica {name} failed: "
                               f"{res['error']}\n{res.get('stderr', '')}")

    delta_bytes = [push_meta[s]["save_stats"]["delta"]["bytes_written"]
                   for s in (2, 3)]
    by_tier: dict = {}
    shared_push_bytes = 0
    prop: list[float] = []
    for res in results.values():
        for rec in res["syncs"]:
            if rec["step"] not in push_meta:
                continue        # the start-up fetch of step 1 is excluded
            for t, n in rec["bytes_by_tier"].items():
                by_tier[t] = by_tier.get(t, 0) + n
            shared_push_bytes += rec["bytes_by_tier"].get("shared", 0)
            prop.append(rec["completed_at"]
                        - push_meta[rec["step"]]["announced_at"])
    peer_bytes = sum(v for t, v in by_tier.items() if t.startswith("peer:"))
    mean_delta = float(np.mean(delta_bytes))
    return {
        "fleet_size": fleet_size,
        "pushes": len(push_meta),
        "delta_bytes_per_push": mean_delta,
        "shared_bytes_per_push": shared_push_bytes / len(push_meta),
        "shared_vs_delta_ratio": (shared_push_bytes / len(push_meta))
                                 / max(mean_delta, 1),
        "replica_to_replica_bytes": peer_bytes,
        "bytes_by_tier": by_tier,
        "p50_propagation_s": float(np.percentile(prop, 50)),
        "p99_propagation_s": float(np.percentile(prop, 99)),
        "digests_converged": len({r["digest"]
                                  for r in results.values()}) == 1,
    }


def _drain_arm(root: Path, tree: dict, *, chunk_bytes: int) -> dict:
    """Paused-publisher phase: announce an uncommitted step, watch every
    replica drain (refuse admissions, keep running), commit, watch them
    re-admit and converge."""
    import fleet_harness as fh

    pub = fh.FleetPublisher(root, chunk_bytes=chunk_bytes)
    pub.push(1, tree)
    names = ["d0", "d1"]
    cfgs = [fh.replica_config(root, n, batches=2, final_step=9,
                              max_lag_steps=2, gen_s=0.002)
            for n in names]
    procs = [(c, fh.spawn_replica(c)) for c in cfgs]
    _wait_fleet_step(pub.registry, names, 1)
    pub.announce_uncommitted(9)
    _wait_fleet_phase(pub.registry, names, "draining")
    tree = _mutate(tree, 1.0, chunk_bytes // 8)
    pub.push(9, tree)
    results = fh.wait_fleet(procs, timeout_s=120.0)
    pub.close()
    for name, res in results.items():
        if "error" in res:
            raise RuntimeError(f"drain replica {name} failed: "
                               f"{res['error']}\n{res.get('stderr', '')}")
    return {
        "fleet_size": len(names),
        "drained_replicas": sum(1 for r in results.values()
                                if r["drain_count"] > 0),
        "readmitted_replicas": sum(1 for r in results.values()
                                   if r["readmit_count"] > 0),
        "converged_step": max(r["final_step"] for r in results.values()),
    }


def _weight_push_fleet_detail(payload_mb: int, sizes: tuple[int, ...],
                              n_leaves: int = 8,
                              chunk_bytes: int = 128 << 10) -> dict:
    import tempfile

    rng = np.random.default_rng(1)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}
    churn_elems = chunk_bytes // 8

    scaling = []
    for size in sizes:
        with tempfile.TemporaryDirectory() as d:
            scaling.append(_fleet_arm(Path(d), size, tree,
                                      chunk_bytes=chunk_bytes,
                                      churn_elems=churn_elems))
    with tempfile.TemporaryDirectory() as d:
        drain = _drain_arm(Path(d), tree, chunk_bytes=chunk_bytes)

    top = scaling[-1]
    return {
        "payload_mb": sum(a.nbytes for a in tree.values()) / 1e6,
        "chunk_bytes": chunk_bytes,
        # headline keys (CI schema gate): the LARGEST fleet's shared bytes
        # per push — flat at ~1x delta whatever the size — plus the drain
        # phase outcome
        "fleet_size": top["fleet_size"],
        "shared_bytes_per_push": top["shared_bytes_per_push"],
        "shared_vs_delta_ratio": top["shared_vs_delta_ratio"],
        "p99_propagation_s": top["p99_propagation_s"],
        "bytes_by_tier": top["bytes_by_tier"],
        "drained_replicas": drain["drained_replicas"],
        "readmitted_replicas": drain["readmitted_replicas"],
        "scaling": scaling,
        "drain": drain,
    }


def run(results_dir: Path | None = None, smoke: bool = False):
    from benchmarks.bench_startup import merge_bench_ckpt_io

    payload_mb = 8 if smoke else 64
    detail = _weight_push_detail(payload_mb)
    fleet = _weight_push_fleet_detail(4 if smoke else 16,
                                      (1, 8) if smoke else (1, 4, 8, 16))
    merge_bench_ckpt_io({"weight_push": detail,
                         "weight_push_fleet": fleet})
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "weight_push.json").write_text(
            json.dumps({"weight_push": detail,
                        "weight_push_fleet": fleet}, indent=1))
    return [{
        "name": "ckpt_weight_push",
        "us_per_call": detail["propagation_s"] * 1e6,
        "derived": (
            f"replicas={detail['n_replicas']} "
            f"prop={detail['propagation_s']*1e3:.1f}ms "
            f"broadcast={detail['broadcast_s']*1e3:.1f}ms "
            f"speedup={detail['speedup_vs_broadcast']:.1f}x "
            f"shared={detail['fleet_shared_read_bytes']} "
            f"delta={detail['delta_bytes_committed']} "
            f"swap_stall={detail['max_swap_stall_s']*1e6:.0f}us"),
    }, {
        "name": "ckpt_weight_push_fleet",
        "us_per_call": fleet["p99_propagation_s"] * 1e6,
        "derived": (
            f"fleet={fleet['fleet_size']}proc "
            f"shared/push={fleet['shared_bytes_per_push']:.0f}B "
            f"(~{fleet['shared_vs_delta_ratio']:.2f}x delta) "
            f"p99_prop={fleet['p99_propagation_s']*1e3:.0f}ms "
            f"drained={fleet['drained_replicas']} "
            f"readmitted={fleet['readmitted_replicas']}"),
    }]
