"""Coordinator scalability (paper §III-A): two-phase barrier latency vs worker
count, real TCP sockets, trivial saves — isolates protocol cost from I/O."""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

BENCH_KEYS = ()     # prints rows only; owns no BENCH_ckpt_io.json keys


def run(results_dir: Path | None = None, worker_counts=(1, 4, 16, 64),
        rounds: int = 5, smoke: bool = False):
    from repro.core.coordinator import CheckpointCoordinator
    from repro.core.worker import CkptClient

    if smoke:
        worker_counts, rounds = (1, 4), 2

    rows = []
    detail = {}
    for n in worker_counts:
        coord = CheckpointCoordinator(expected_workers=n, straggler_timeout=30,
                                      commit_fn=lambda step, num_workers: {"step": step})
        stop = threading.Event()

        def worker(wid):
            c = CkptClient(coord.host, coord.port, wid)
            while not stop.is_set():
                c.service(0, lambda label: {})
                time.sleep(0.001)
            c.close()

        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(n)]
        for t in threads:
            t.start()
        coord.wait_for_workers(n)
        lat = []
        for r in range(rounds):
            t0 = time.perf_counter()
            rec = coord.trigger_checkpoint(step=r)
            assert rec["ok"], rec
            lat.append(time.perf_counter() - t0)
        stop.set()
        for t in threads:
            t.join(timeout=5)
        coord.close()
        detail[n] = {"mean_s": float(np.mean(lat)), "p_max_s": float(np.max(lat))}
    base = detail[worker_counts[0]]["mean_s"]
    for n in worker_counts:
        rows.append({
            "name": f"coordinator_barrier_w{n}",
            "us_per_call": detail[n]["mean_s"] * 1e6,
            "derived": f"vs_1worker={detail[n]['mean_s']/base:.2f}x "
                       f"max={detail[n]['p_max_s']*1e3:.1f}ms",
        })
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "coordinator.json").write_text(json.dumps(detail, indent=1))
    return rows
