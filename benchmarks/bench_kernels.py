"""Kernel-layer microbenchmarks on the CPU execution paths.

Pallas timing on CPU-interpret is meaningless (Python loop), so wall numbers
come from the jit'd XLA paths (naive vs blockwise attention, sequential-scan vs
chunked SSD/WKV) — the same algorithmic contrast the TPU kernels implement —
plus checkpoint-substrate throughput (serialize / crc / checksum-op).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

BENCH_KEYS = ()     # prints rows only; owns no BENCH_ckpt_io.json keys


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def run(results_dir: Path | None = None, smoke: bool = False):
    from repro.checkpoint import serialization as SER
    from repro.kernels import ops, ref
    from repro.kernels.rwkv6_scan import wkv6_chunked_xla
    from repro.kernels.ssd_scan import ssd_chunked_xla
    from repro.kernels.xla_attention import causal_blockwise

    rng = np.random.default_rng(0)
    rows = []

    # smoke mode (CI): same contrasts on toy sizes, just proving the
    # benchmark paths execute end to end
    S_attn = 256 if smoke else 2048
    blk = 128 if smoke else 512
    # attention: naive (S^2 materialized) vs blockwise (flash-structured)
    B, S, H, Dh = 1, S_attn, 4, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh), np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, Dh), np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, Dh), np.float32))
    naive = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    block = jax.jit(lambda q, k, v: causal_blockwise(q, k, v, block_q=blk, block_k=blk))
    tn, tb = _time(naive, q, k, v), _time(block, q, k, v)
    flops = 2 * 2 * B * H * S * S * Dh / 2  # causal
    rows.append({"name": "attn_naive_2k", "us_per_call": tn * 1e6,
                 "derived": f"{flops/tn/1e9:.1f}GFLOP/s"})
    rows.append({"name": "attn_blockwise_2k", "us_per_call": tb * 1e6,
                 "derived": f"{flops/tb/1e9:.1f}GFLOP/s speedup={tn/tb:.2f}x"})

    # SSD: sequential scan vs chunked
    B, S, Hh, P, N = 1, (256 if smoke else 2048), 8, 64, 64
    x = jnp.asarray(rng.standard_normal((B, S, Hh, P), np.float32)) * 0.3
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, Hh))).astype(np.float32))
    Al = jnp.asarray(rng.standard_normal((Hh,)).astype(np.float32) * 0.3)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32) * 0.3)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32) * 0.3)
    Dp = jnp.ones((Hh,), jnp.float32)
    seq = jax.jit(lambda *a: ref.ssd(*a))
    chk = jax.jit(lambda *a: ssd_chunked_xla(*a, chunk=128))
    ts = _time(seq, x, dt, Al, Bm, Cm, Dp)
    tc = _time(chk, x, dt, Al, Bm, Cm, Dp)
    rows.append({"name": "ssd_sequential_2k", "us_per_call": ts * 1e6,
                 "derived": f"tokens/s={B*S/ts:.0f}"})
    rows.append({"name": "ssd_chunked_2k", "us_per_call": tc * 1e6,
                 "derived": f"tokens/s={B*S/tc:.0f} speedup={ts/tc:.2f}x"})

    # WKV6: sequential vs chunked
    r_ = jnp.asarray(rng.standard_normal((B, S, Hh, P), np.float32)) * 0.3
    k_ = jnp.asarray(rng.standard_normal((B, S, Hh, P), np.float32)) * 0.3
    v_ = jnp.asarray(rng.standard_normal((B, S, Hh, P), np.float32)) * 0.3
    w_ = jnp.asarray(rng.uniform(0.9, 0.999, (B, S, Hh, P)).astype(np.float32))
    u_ = jnp.asarray(rng.standard_normal((Hh, P)).astype(np.float32) * 0.3)
    seqw = jax.jit(lambda *a: ref.wkv6(*a))
    chkw = jax.jit(lambda *a: wkv6_chunked_xla(*a, chunk=128))
    ts = _time(seqw, r_, k_, v_, w_, u_)
    tc = _time(chkw, r_, k_, v_, w_, u_)
    rows.append({"name": "wkv6_sequential_2k", "us_per_call": ts * 1e6,
                 "derived": f"tokens/s={B*S/ts:.0f}"})
    rows.append({"name": "wkv6_chunked_2k", "us_per_call": tc * 1e6,
                 "derived": f"tokens/s={B*S/tc:.0f} speedup={ts/tc:.2f}x"})

    # checkpoint substrate throughput
    nb = 2_000_000 if smoke else 16_000_000
    arr = rng.standard_normal(nb // 4).astype(np.float32)
    t0 = time.perf_counter()
    data = SER.write_shard_bytes([("w", arr)])
    t_ser = time.perf_counter() - t0
    t0 = time.perf_counter()
    SER.read_shard_bytes(data)
    t_de = time.perf_counter() - t0
    rows.append({"name": "ckpt_serialize_16MB", "us_per_call": t_ser * 1e6,
                 "derived": f"{len(data)/t_ser/1e9:.2f}GB/s"})
    rows.append({"name": "ckpt_verify_read_16MB", "us_per_call": t_de * 1e6,
                 "derived": f"{len(data)/t_de/1e9:.2f}GB/s"})

    words = jnp.asarray(rng.integers(0, 2**32, nb // 4, dtype=np.uint32))
    ck = jax.jit(lambda w: ops.checksum(w))
    t_ck = _time(ck, words)
    rows.append({"name": "device_checksum_16MB", "us_per_call": t_ck * 1e6,
                 "derived": f"{words.nbytes/t_ck/1e9:.2f}GB/s"})
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "kernels.json").write_text(json.dumps(rows, indent=1))
    return rows
