"""Delta-checkpoint plane (shard v3): bytes-written-per-step and peer-fetch
bytes vs change rate.

Two artifact rows:

  delta_save        full (non-delta) save vs a delta save where <10% of the
                    chunks changed — the paper's core cost is checkpoint
                    SIZE, and content-addressed chunking makes the per-step
                    write proportional to the change rate instead of the
                    model size (CRIU's dirty-page pre-dump, applied to the
                    framework's shard plane).
  delta_peer_fetch  a warm-but-stale node restores the newer step: unchanged
                    chunks come from its own stale promoted cache, the delta
                    comes from a peer — shared-filesystem bytes collapse to
                    ~the delta size (verified via RestoreStats.bytes_by_tier).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

# keys this module owns in BENCH_ckpt_io.json (run.py prunes stale ones)
BENCH_KEYS = ("delta_save", "delta_peer_fetch")


def _mutate(tree: dict, frac_leaves: float, elems: int) -> dict:
    """Touch a small slice of the first ``frac_leaves`` of the leaves — the
    optimizer-only / frozen-embedding churn pattern the delta plane targets."""
    out = dict(tree)
    names = sorted(out)
    for name in names[:max(1, int(len(names) * frac_leaves))]:
        a = out[name].copy()
        a[:elems] += 1.0
        out[name] = a
    return out


def _delta_save_detail(payload_mb: int, n_leaves: int = 8,
                       chunk_bytes: int = 256 << 10, steps: int = 4) -> dict:
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}
    payload_bytes = sum(a.nbytes for a in tree.values())

    with tempfile.TemporaryDirectory() as d:
        # full (non-delta) baseline: every step writes the whole shard
        store = TieredStore(Path(d) / "full", seed=0)
        m = CheckpointManager(store, replicas=1)
        t0 = time.perf_counter()
        m.save(1, tree)
        m.commit(1)
        full_s = time.perf_counter() - t0
        full_bytes = store.size("shared", "ckpt/step_0000000001/shard_w00000.bin")
        m.close()

        # delta chain: step 1 is the baseline, steps 2.. mutate <10% of chunks
        store = TieredStore(Path(d) / "delta", seed=0)
        m = CheckpointManager(store, replicas=1, delta=True,
                              chunk_bytes=chunk_bytes)
        p = m.save(1, tree)
        m.commit(1)
        base_written = p["delta"]["bytes_written"]
        cur = tree
        per_step = []
        for s in range(2, 2 + steps):
            cur = _mutate(cur, 1.0 / n_leaves, chunk_bytes // 8)
            t0 = time.perf_counter()
            p = m.save(s, cur)
            m.commit(s)
            dt = time.perf_counter() - t0
            per_step.append({"step": s, "wall_s": dt,
                             "bytes_written": p["delta"]["bytes_written"],
                             "chunks_written": p["delta"]["chunks_written"],
                             "chunks_total": p["delta"]["chunks_total"]})
        m.close()

    mean_delta = float(np.mean([r["bytes_written"] for r in per_step]))
    return {
        "payload_mb": payload_bytes / 1e6,
        "chunk_bytes": chunk_bytes,
        "full_shard_bytes": full_bytes,
        "full_save_s": full_s,
        "baseline_bytes_written": base_written,
        "delta_steps": per_step,
        "delta_mean_bytes_written": mean_delta,
        "bytes_ratio_delta_vs_full": mean_delta / max(full_bytes, 1),
        "changed_chunk_fraction": float(np.mean(
            [r["chunks_written"] / r["chunks_total"] for r in per_step])),
    }


def _delta_peer_fetch_detail(payload_mb: int, n_leaves: int = 8,
                             chunk_bytes: int = 256 << 10) -> dict:
    """Warm-but-stale requeue: nodeB promoted step N, the frontier moved to
    N+1 (small delta), nodeB restores N+1 — unchanged chunks from its own
    stale cache, delta chunks from the warm peer, ~zero shared bytes."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.store import TieredStore, node_local_tier_roots

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}

    with tempfile.TemporaryDirectory() as d:
        root = Path(d)

        def store_for(node: str, sim: float = 0.0) -> TieredStore:
            return TieredStore(
                root / "ck", sim_io_factor=sim, seed=0,
                tier_roots=node_local_tier_roots(root / "nodes" / node))

        w = CheckpointManager(store_for("peerA"), replicas=1, delta=True,
                              chunk_bytes=chunk_bytes, promote="eager",
                              node="peerA")
        w.save(1, tree)
        w.commit(1)
        w.wait_promotions()

        # nodeB warms its cache at step 1, then goes away (preempted)
        b = CheckpointManager(store_for("nodeB"), replicas=1, delta=True,
                              chunk_bytes=chunk_bytes, promote="on_restore",
                              node="nodeB")
        b.restore(tree)
        b.wait_promotions()
        b.close()

        # frontier moves: peerA commits step 2 with a small delta (and its
        # eager promotion keeps its own cache warm at step 2)
        tree2 = _mutate(tree, 1.0 / n_leaves, chunk_bytes // 8)
        p = w.save(2, tree2)
        w.commit(2)
        w.wait_promotions()
        w.close()
        delta_bytes = p["delta"]["bytes_written"]

        # requeued nodeB restores step 2 with peerA as a peer source
        b2 = CheckpointManager(store_for("nodeB", sim=1.0), replicas=1,
                               delta=True, chunk_bytes=chunk_bytes,
                               promote="off", node="nodeB",
                               peer_roots={"peerA": root / "nodes" / "peerA"})
        t0 = time.perf_counter()
        b2.restore(tree)
        stale_s = time.perf_counter() - t0
        st = b2.last_restore_stats or {}
        b2.close()

        # contrast: a fully cold node pays the whole payload to shared
        c = CheckpointManager(store_for("cold", sim=1.0), replicas=1,
                              delta=True, chunk_bytes=chunk_bytes)
        t0 = time.perf_counter()
        c.restore(tree)
        cold_s = time.perf_counter() - t0
        cold_st = c.last_restore_stats or {}
        c.close()

    by_tier = st.get("bytes_by_tier") or {}
    remote = sum(n for t, n in by_tier.items() if t != "local")
    return {
        "payload_mb": sum(a.nbytes for a in tree.values()) / 1e6,
        "chunk_bytes": chunk_bytes,
        "delta_bytes_committed": delta_bytes,
        "stale_restore_s": stale_s,
        "cold_restore_s": cold_s,
        "speedup_vs_cold": cold_s / max(stale_s, 1e-9),
        "bytes_by_tier": by_tier,
        "cold_bytes_by_tier": cold_st.get("bytes_by_tier"),
        "remote_bytes": remote,
        "remote_vs_delta_ratio": remote / max(delta_bytes, 1),
        "local_bytes": by_tier.get("local", 0),
        "shared_bytes": by_tier.get("shared", 0),
    }


def run(results_dir: Path | None = None, smoke: bool = False):
    from benchmarks.bench_startup import merge_bench_ckpt_io

    payload_mb = 8 if smoke else 64
    detail_save = _delta_save_detail(payload_mb)
    detail_peer = _delta_peer_fetch_detail(payload_mb)
    merge_bench_ckpt_io({"delta_save": detail_save,
                         "delta_peer_fetch": detail_peer})
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "delta.json").write_text(json.dumps(
            {"delta_save": detail_save, "delta_peer_fetch": detail_peer},
            indent=1))
    rows = [
        {
            "name": "ckpt_delta_save",
            "us_per_call": float(np.mean(
                [r["wall_s"] for r in detail_save["delta_steps"]])) * 1e6,
            "derived": (
                f"full={detail_save['full_shard_bytes']} "
                f"delta={detail_save['delta_mean_bytes_written']:.0f} "
                f"ratio={detail_save['bytes_ratio_delta_vs_full']:.3f} "
                f"changed={detail_save['changed_chunk_fraction']:.3f}"),
        },
        {
            "name": "ckpt_delta_peer_fetch",
            "us_per_call": detail_peer["stale_restore_s"] * 1e6,
            "derived": (
                f"remote_bytes={detail_peer['remote_bytes']} "
                f"delta_bytes={detail_peer['delta_bytes_committed']} "
                f"shared={detail_peer['shared_bytes']} "
                f"speedup_vs_cold={detail_peer['speedup_vs_cold']:.1f}x"),
        },
    ]
    return rows
