"""Delta-checkpoint plane (shard v3): bytes-written-per-step, peer-fetch
bytes vs change rate, and the save-stall anatomy.

Three artifact rows:

  delta_save          full (non-delta) save vs a delta save where <10% of
                      the chunks changed — the paper's core cost is
                      checkpoint SIZE, and content-addressed chunking makes
                      the per-step write proportional to the change rate
                      instead of the model size.  Per-phase timing
                      (``fp_s``/``hash_s``/``diff_s``/``write_s``/
                      ``stall_s``) comes straight from the manager — the
                      parallel hash engine plus the fingerprint pre-filter
                      should leave ``hash_s`` a small fraction of
                      ``write_s``.
  delta_save_overlap  synchronous delta save vs pre-dump + residual save
                      (CRIU's pre-dump, applied to the shard plane): the
                      step-visible pause of ``precommit(); ...train...;
                      save()`` against a plain ``save()`` on the same
                      mutation pattern.
  delta_peer_fetch    a warm-but-stale node restores the newer step:
                      unchanged chunks come from its own stale promoted
                      cache, the delta comes from a peer —
                      shared-filesystem bytes collapse to ~the delta size
                      (verified via RestoreStats.bytes_by_tier).
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

# keys this module owns in BENCH_ckpt_io.json (run.py prunes stale ones)
BENCH_KEYS = ("delta_save", "delta_save_overlap", "delta_peer_fetch",
              "delta_save_device", "delta_predump_iterative")

# workers ≥ 4 per the hash-engine acceptance bar; forced explicitly so the
# row measures the parallel engine even on a small CI/container CPU budget
HASH_WORKERS = 4

# the save rows write against the SIMULATED shared-filesystem tier (same
# convention as the peer-fetch row, scaled to keep smoke runtime in budget):
# tmpfs/page-cache writes complete in microseconds and would make every
# write_s meaninglessly small — the paper's cost model is a parallel
# filesystem with ~20ms per-op latency, which is exactly what
# ``TieredStore(sim_io_factor=...)`` models
SIM_IO = 0.5


def _mutate(tree: dict, frac_leaves: float, elems: int) -> dict:
    """Touch a small slice of the first ``frac_leaves`` of the leaves — the
    optimizer-only / frozen-embedding churn pattern the delta plane targets."""
    out = dict(tree)
    names = sorted(out)
    for name in names[:max(1, int(len(names) * frac_leaves))]:
        a = out[name].copy()
        a[:elems] += 1.0
        out[name] = a
    return out


def _delta_save_detail(payload_mb: int, n_leaves: int = 8,
                       chunk_bytes: int = 256 << 10, steps: int = 4) -> dict:
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}
    payload_bytes = sum(a.nbytes for a in tree.values())

    with tempfile.TemporaryDirectory() as d:
        # full (non-delta) baseline: every step writes the whole shard
        store = TieredStore(Path(d) / "full", seed=0, sim_io_factor=SIM_IO)
        m = CheckpointManager(store, CheckpointPolicy(replicas=1))
        t0 = time.perf_counter()
        m.save(1, tree)
        m.commit(1)
        full_s = time.perf_counter() - t0
        full_bytes = store.size("shared", "ckpt/step_0000000001/shard_w00000.bin")
        m.close()

        # delta chain: step 1 is the baseline, steps 2.. mutate <10% of
        # chunks.  Fingerprint pre-filter + parallel hash engine on: the
        # blake2b pass inside the stall should collapse to the dirty chunks
        store = TieredStore(Path(d) / "delta", seed=0, sim_io_factor=SIM_IO)
        m = CheckpointManager(store,
                              CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes,
                                               fingerprint=True, hash_workers=HASH_WORKERS))
        p = m.save(1, tree)
        m.commit(1)
        base_written = p["delta"]["bytes_written"]
        cur = tree
        per_step = []
        # one unrecorded warm-up delta step: the lazy hash-pool spin-up and
        # numpy/blake2b first-call costs are engine startup, not the
        # steady-state stall anatomy the row reports
        for i, s in enumerate(range(2, 3 + steps)):
            cur = _mutate(cur, 1.0 / n_leaves, chunk_bytes // 8)
            t0 = time.perf_counter()
            p = m.save(s, cur)
            m.commit(s)
            dt = time.perf_counter() - t0
            if i == 0:
                continue
            d_ = p["delta"]
            per_step.append({"step": s, "wall_s": dt,
                             "bytes_written": d_["bytes_written"],
                             "chunks_written": d_["chunks_written"],
                             "chunks_total": d_["chunks_total"],
                             "chunks_hashed": d_["chunks_hashed"],
                             "chunks_fp_clean": d_["chunks_fp_clean"],
                             "fp_s": d_["fp_s"], "hash_s": d_["hash_s"],
                             "diff_s": d_["diff_s"],
                             "write_s": d_["write_s"],
                             "stall_s": d_["stall_s"]})
        hash_workers = m.hash_engine.workers
        m.close()

    mean_delta = float(np.mean([r["bytes_written"] for r in per_step]))
    mean = lambda k: float(np.mean([r[k] for r in per_step]))  # noqa: E731
    hash_s, write_s = mean("hash_s"), mean("write_s")
    return {
        "payload_mb": payload_bytes / 1e6,
        "chunk_bytes": chunk_bytes,
        "full_shard_bytes": full_bytes,
        "full_save_s": full_s,
        "baseline_bytes_written": base_written,
        "delta_steps": per_step,
        "delta_mean_bytes_written": mean_delta,
        "bytes_ratio_delta_vs_full": mean_delta / max(full_bytes, 1),
        "changed_chunk_fraction": float(np.mean(
            [r["chunks_written"] / r["chunks_total"] for r in per_step])),
        # per-phase means over the delta steps (the steady-state stall
        # anatomy; the baseline full-hash step is reported via full_save_s)
        "fp_s": mean("fp_s"),
        "hash_s": hash_s,
        "diff_s": mean("diff_s"),
        "write_s": write_s,
        "stall_s": mean("stall_s"),
        "hash_vs_write_ratio": hash_s / max(write_s, 1e-12),
        "hash_workers": hash_workers,
    }


def _delta_overlap_detail(payload_mb: int, n_leaves: int = 8,
                          chunk_bytes: int = 256 << 10,
                          steps: int = 3) -> dict:
    """Step-visible pause: synchronous delta save vs pre-dump + residual
    save on the SAME mutation pattern (every leaf dirties one chunk — the
    optimizer-churn case where the pre-dump has real work to absorb).

    Synchronous arm: mutate, then ``save()`` — the stall covers the full
    hash+diff+write pass.  Overlapped arm: mutate, ``precommit()`` (visible
    cost: the snapshot), sleep one simulated training step while
    fingerprint/hash/pre-write run on the background pool, then ``save()``
    — the stall covers the snapshot, the live-fingerprint comparison and
    whatever was dirtied after the pre-dump (here: nothing, the CRIU
    pre-dump best case; the residual-dirty case is delta_save's per-phase
    rows).  ``commit()`` runs in both arms but is excluded from both stalls:
    its manifest write + gc reads are byte-identical work either way.  The
    simulated training step is self-calibrated to 1.2x the sync arm's mean
    save wall — pre-dump only hides work when a training step is at least
    as long as the work it hides, and the knob the operator actually has
    (``--ckpt-predump-lead``) exists precisely to buy that window."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}

    sync_walls, overlap_stalls, overlap_rows = [], [], []
    with tempfile.TemporaryDirectory() as d:
        store = TieredStore(Path(d) / "sync", seed=0, sim_io_factor=SIM_IO)
        m = CheckpointManager(store,
                              CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes,
                                               hash_workers=HASH_WORKERS))
        m.save(1, tree)
        m.commit(1)
        cur = tree
        # warm-up delta step (unrecorded) mirrors _delta_save_detail
        for i, s in enumerate(range(2, 3 + steps)):
            cur = _mutate(cur, 1.0, chunk_bytes // 8)
            t0 = time.perf_counter()
            m.save(s, cur)
            wall = time.perf_counter() - t0
            m.commit(s)
            if i > 0:
                sync_walls.append(wall)
        m.close()

        train_s = 1.2 * float(np.mean(sync_walls))
        store = TieredStore(Path(d) / "overlap", seed=0, sim_io_factor=SIM_IO)
        m = CheckpointManager(store,
                              CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes,
                                               hash_workers=HASH_WORKERS))
        m.save(1, tree)
        m.commit(1)
        cur = tree
        for i, s in enumerate(range(2, 3 + steps)):
            cur = _mutate(cur, 1.0, chunk_bytes // 8)
            t0 = time.perf_counter()
            m.precommit(s, cur)
            pre_s = time.perf_counter() - t0
            time.sleep(train_s)          # the next training step runs here
            t0 = time.perf_counter()
            p = m.save(s, cur)
            save_s = time.perf_counter() - t0
            m.commit(s)
            if i == 0:
                continue
            overlap_stalls.append(pre_s + save_s)
            overlap_rows.append({"step": s, "precommit_s": pre_s,
                                 "save_s": save_s,
                                 "chunks_hashed": p["delta"]["chunks_hashed"],
                                 "chunks_predumped":
                                     p["delta"]["chunks_predumped"]})
        m.close()

    sync_s = float(np.mean(sync_walls))
    overlap_s = float(np.mean(overlap_stalls))
    return {
        "payload_mb": sum(a.nbytes for a in tree.values()) / 1e6,
        "chunk_bytes": chunk_bytes,
        "train_s": train_s,
        "hash_workers": HASH_WORKERS,
        "sync_save_s": sync_s,
        "sync_walls": sync_walls,
        "overlap_stall_s": overlap_s,
        "overlap_stalls": overlap_stalls,
        "overlap_steps": overlap_rows,
        "stall_ratio_overlap_vs_sync": overlap_s / max(sync_s, 1e-12),
    }


def _delta_peer_fetch_detail(payload_mb: int, n_leaves: int = 8,
                             chunk_bytes: int = 256 << 10) -> dict:
    """Warm-but-stale requeue: nodeB promoted step N, the frontier moved to
    N+1 (small delta), nodeB restores N+1 — unchanged chunks from its own
    stale cache, delta chunks from the warm peer, ~zero shared bytes."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore, node_local_tier_roots

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}

    with tempfile.TemporaryDirectory() as d:
        root = Path(d)

        def store_for(node: str, sim: float = 0.0) -> TieredStore:
            return TieredStore(
                root / "ck", sim_io_factor=sim, seed=0,
                tier_roots=node_local_tier_roots(root / "nodes" / node))

        w = CheckpointManager(store_for("peerA"),
                              CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes,
                                               promote="eager"), node="peerA")
        w.save(1, tree)
        w.commit(1)
        w.wait_promotions()

        # nodeB warms its cache at step 1, then goes away (preempted)
        b = CheckpointManager(store_for("nodeB"),
                              CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes,
                                               promote="on_restore"), node="nodeB")
        b.restore(tree)
        b.wait_promotions()
        b.close()

        # frontier moves: peerA commits step 2 with a small delta (and its
        # eager promotion keeps its own cache warm at step 2)
        tree2 = _mutate(tree, 1.0 / n_leaves, chunk_bytes // 8)
        p = w.save(2, tree2)
        w.commit(2)
        w.wait_promotions()
        w.close()
        delta_bytes = p["delta"]["bytes_written"]

        # requeued nodeB restores step 2 with peerA as a peer source
        b2 = CheckpointManager(
            store_for("nodeB", sim=1.0),
            CheckpointPolicy(replicas=1, delta=True,
                             chunk_bytes=chunk_bytes, promote="off"),
            node="nodeB", peer_roots={"peerA": root / "nodes" / "peerA"})
        t0 = time.perf_counter()
        b2.restore(tree)
        stale_s = time.perf_counter() - t0
        st = b2.last_restore_stats or {}
        b2.close()

        # contrast: a fully cold node pays the whole payload to shared
        c = CheckpointManager(store_for("cold", sim=1.0),
                              CheckpointPolicy(replicas=1, delta=True, chunk_bytes=chunk_bytes))
        t0 = time.perf_counter()
        c.restore(tree)
        cold_s = time.perf_counter() - t0
        cold_st = c.last_restore_stats or {}
        c.close()

    by_tier = st.get("bytes_by_tier") or {}
    remote = sum(n for t, n in by_tier.items() if t != "local")
    return {
        "payload_mb": sum(a.nbytes for a in tree.values()) / 1e6,
        "chunk_bytes": chunk_bytes,
        "delta_bytes_committed": delta_bytes,
        "stale_restore_s": stale_s,
        "cold_restore_s": cold_s,
        "speedup_vs_cold": cold_s / max(stale_s, 1e-9),
        "bytes_by_tier": by_tier,
        "cold_bytes_by_tier": cold_st.get("bytes_by_tier"),
        "remote_bytes": remote,
        "remote_vs_delta_ratio": remote / max(delta_bytes, 1),
        "local_bytes": by_tier.get("local", 0),
        "shared_bytes": by_tier.get("shared", 0),
    }


def _delta_save_device_detail(payload_mb: int, n_leaves: int = 8,
                              chunk_bytes: int = 256 << 10,
                              steps: int = 3) -> dict:
    """Device-resident dirty detection vs the host delta path on the SAME
    mutation pattern (one dirty chunk per interval).  The host path
    snapshots the whole tree before diffing — ``d2h_bytes`` ≈ the payload
    every step (ratio ~1.0).  The device_fp path fingerprints the live
    leaves first and gathers only fp-dirty chunk runs, so its
    ``d2h_bytes / bytes_total`` should track the churn fraction, not the
    model size.  Byte-identity of the two paths is a TEST
    (tests/test_device_fp.py); this row measures the D2H bill."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}

    def arm(root: Path, device_fp: bool) -> list[dict]:
        store = TieredStore(root, seed=0, sim_io_factor=SIM_IO)
        m = CheckpointManager(store, CheckpointPolicy(
            replicas=1, delta=True, chunk_bytes=chunk_bytes,
            fingerprint=True, device_fp=device_fp,
            hash_workers=HASH_WORKERS))
        m.save(1, tree)
        m.commit(1)
        cur, rows = tree, []
        for i, s in enumerate(range(2, 3 + steps)):
            cur = _mutate(cur, 1.0 / n_leaves, chunk_bytes // 8)
            t0 = time.perf_counter()
            p = m.save(s, cur)
            wall = time.perf_counter() - t0
            m.commit(s)
            if i == 0:
                continue               # warm-up, as in _delta_save_detail
            d_ = p["delta"]
            rows.append({"step": s, "wall_s": wall,
                         "bytes_total": d_["bytes_total"],
                         "chunks_total": d_["chunks_total"],
                         "chunks_hashed": d_["chunks_hashed"],
                         "chunks_clean_device": d_["chunks_clean_device"],
                         "d2h_bytes": d_["d2h_bytes"],
                         "d2h_s": d_["d2h_s"],
                         "fp_device_s": d_["fp_device_s"],
                         "stall_s": d_["stall_s"]})
        m.close()
        return rows

    with tempfile.TemporaryDirectory() as d:
        host_rows = arm(Path(d) / "host", False)
        dev_rows = arm(Path(d) / "device", True)

    mean = lambda rows, k: float(np.mean([r[k] for r in rows]))  # noqa: E731
    ratio = lambda rows: float(np.mean(                          # noqa: E731
        [r["d2h_bytes"] / max(r["bytes_total"], 1) for r in rows]))
    churn = float(np.mean([r["chunks_hashed"] / max(r["chunks_total"], 1)
                           for r in dev_rows]))
    return {
        "payload_mb": sum(a.nbytes for a in tree.values()) / 1e6,
        "chunk_bytes": chunk_bytes,
        "host_steps": host_rows,
        "device_steps": dev_rows,
        "host_d2h_bytes_ratio": ratio(host_rows),
        "d2h_bytes_ratio": ratio(dev_rows),
        "churn_chunk_fraction": churn,
        "fp_device_s": mean(dev_rows, "fp_device_s"),
        "d2h_s": mean(dev_rows, "d2h_s"),
        "host_stall_s": mean(host_rows, "stall_s"),
        "device_stall_s": mean(dev_rows, "stall_s"),
    }


def _delta_predump_iterative_detail(payload_mb: int, n_leaves: int = 8,
                                    chunk_bytes: int = 256 << 10) -> dict:
    """Iterative pre-copy (CRIU): two pre-dump leads before the save, the
    second using the first as its fingerprint reference.  Churn pattern:
    a BIG dirtying between the parent and lead 1 (two chunks per leaf), a
    small one between the leads (one chunk in two leaves), nothing after
    lead 2.  Lead 1 hashes the big churn, lead 2 only the small one, the
    save ~nothing — against a single early pre-dump, where the save itself
    pays for everything dirtied after it."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore

    rng = np.random.default_rng(0)
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}
    big = chunk_bytes // 2                    # elems: dirties 2 chunks/leaf
    small = chunk_bytes // 8

    def arm(root: Path, leads: int) -> dict:
        store = TieredStore(root, seed=0, sim_io_factor=SIM_IO)
        m = CheckpointManager(store, CheckpointPolicy(
            replicas=1, delta=True, chunk_bytes=chunk_bytes,
            fingerprint=True, hash_workers=HASH_WORKERS))
        m.save(1, tree)
        m.commit(1)
        cur = _mutate(tree, 1.0, big)
        lead_stats = []
        m.precommit(2, cur)                   # lead N-2 (or the only lead)
        lead_stats.append(m.wait_predump())
        cur = _mutate(cur, 2.0 / n_leaves, small)
        if leads > 1:
            m.precommit(3, cur)               # lead N-1: only the small churn
            lead_stats.append(m.wait_predump())
        t0 = time.perf_counter()
        p = m.save(4, cur)
        stall = time.perf_counter() - t0
        m.commit(4)
        m.close()
        return {"leads": lead_stats, "save_stall_s": stall,
                "save_chunks_hashed": p["delta"]["chunks_hashed"],
                "save_chunks_predumped": p["delta"]["chunks_predumped"]}

    with tempfile.TemporaryDirectory() as d:
        single = arm(Path(d) / "single", 1)
        iterative = arm(Path(d) / "iter", 2)

    return {
        "payload_mb": sum(a.nbytes for a in tree.values()) / 1e6,
        "chunk_bytes": chunk_bytes,
        "single": single,
        "iterative": iterative,
        "lead1_chunks_hashed": iterative["leads"][0]["chunks_hashed"],
        "lead2_chunks_hashed": iterative["leads"][1]["chunks_hashed"],
        "single_save_chunks_hashed": single["save_chunks_hashed"],
        "iter_save_chunks_hashed": iterative["save_chunks_hashed"],
        "single_save_stall_s": single["save_stall_s"],
        "iter_save_stall_s": iterative["save_stall_s"],
    }


def run(results_dir: Path | None = None, smoke: bool = False):
    from benchmarks.bench_startup import merge_bench_ckpt_io, stamp_run_meta
    from repro.checkpoint.serialization import (ENV_HASH_WORKERS,
                                                auto_hash_workers)

    payload_mb = 8 if smoke else 64
    detail_save = _delta_save_detail(payload_mb)
    detail_overlap = _delta_overlap_detail(payload_mb)
    detail_peer = _delta_peer_fetch_detail(payload_mb)
    detail_device = _delta_save_device_detail(payload_mb)
    detail_iter = _delta_predump_iterative_detail(payload_mb)
    run_meta = stamp_run_meta({
        "hash_workers": detail_save["hash_workers"],
        "hash_workers_auto": auto_hash_workers(),
        ENV_HASH_WORKERS: os.environ.get(ENV_HASH_WORKERS),
    })
    merge_bench_ckpt_io({"delta_save": detail_save,
                         "delta_save_overlap": detail_overlap,
                         "delta_peer_fetch": detail_peer,
                         "delta_save_device": detail_device,
                         "delta_predump_iterative": detail_iter,
                         "run_meta": run_meta})
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "delta.json").write_text(json.dumps(
            {"delta_save": detail_save,
             "delta_save_overlap": detail_overlap,
             "delta_peer_fetch": detail_peer,
             "delta_save_device": detail_device,
             "delta_predump_iterative": detail_iter},
            indent=1))
    rows = [
        {
            "name": "ckpt_delta_save",
            "us_per_call": float(np.mean(
                [r["wall_s"] for r in detail_save["delta_steps"]])) * 1e6,
            "derived": (
                f"full={detail_save['full_shard_bytes']} "
                f"delta={detail_save['delta_mean_bytes_written']:.0f} "
                f"ratio={detail_save['bytes_ratio_delta_vs_full']:.3f} "
                f"changed={detail_save['changed_chunk_fraction']:.3f} "
                f"hash={detail_save['hash_s']*1e3:.2f}ms "
                f"write={detail_save['write_s']*1e3:.2f}ms "
                f"hash/write={detail_save['hash_vs_write_ratio']:.3f}"),
        },
        {
            "name": "ckpt_delta_save_overlap",
            "us_per_call": detail_overlap["overlap_stall_s"] * 1e6,
            "derived": (
                f"sync={detail_overlap['sync_save_s']*1e3:.2f}ms "
                f"overlap={detail_overlap['overlap_stall_s']*1e3:.2f}ms "
                f"ratio={detail_overlap['stall_ratio_overlap_vs_sync']:.3f}"),
        },
        {
            "name": "ckpt_delta_peer_fetch",
            "us_per_call": detail_peer["stale_restore_s"] * 1e6,
            "derived": (
                f"remote_bytes={detail_peer['remote_bytes']} "
                f"delta_bytes={detail_peer['delta_bytes_committed']} "
                f"shared={detail_peer['shared_bytes']} "
                f"speedup_vs_cold={detail_peer['speedup_vs_cold']:.1f}x"),
        },
        {
            "name": "ckpt_delta_save_device",
            "us_per_call": detail_device["device_stall_s"] * 1e6,
            "derived": (
                f"d2h_ratio={detail_device['d2h_bytes_ratio']:.3f} "
                f"host_d2h_ratio={detail_device['host_d2h_bytes_ratio']:.3f} "
                f"churn={detail_device['churn_chunk_fraction']:.3f} "
                f"fp_device={detail_device['fp_device_s']*1e3:.2f}ms"),
        },
        {
            "name": "ckpt_delta_predump_iterative",
            "us_per_call": detail_iter["iter_save_stall_s"] * 1e6,
            "derived": (
                f"lead1_hashed={detail_iter['lead1_chunks_hashed']} "
                f"lead2_hashed={detail_iter['lead2_chunks_hashed']} "
                f"save_hashed={detail_iter['iter_save_chunks_hashed']} "
                f"single_save_hashed="
                f"{detail_iter['single_save_chunks_hashed']}"),
        },
    ]
    return rows
