"""Paper Fig. 4 analogue: runtime + memory across three C/R regimes.

Regimes: no-C/R baseline | checkpoint-only | checkpoint+restart (preemption at
mid-run, restore, finish).  Plus the beyond-paper async-checkpoint mode, to
quantify how much of the paper's checkpoint stall the double-buffered writer
hides.  Memory is RSS sampled every step (the paper's LDMS traces).

Also benchmarks the checkpoint I/O plane itself (``run_ckpt_io``): the legacy
double-copy v1 writer vs the zero-copy streaming v2 engine, reporting save /
restore GB/s and peak extra memory, emitted to ``BENCH_ckpt_io.json`` at the
repo root so the perf trajectory is tracked PR-over-PR.

Paper claims reproduced (see EXPERIMENTS.md): checkpointing adds a small
runtime overhead and ~sub-percent memory overhead; checkpoint+restart completes
with total compute ~= baseline + restart cost instead of recomputing from
scratch.
"""
from __future__ import annotations

import json
import threading
import time
import tracemalloc
from pathlib import Path

import jax
import numpy as np

# keys this module owns in BENCH_ckpt_io.json (run.py prunes stale ones):
# run_ckpt_io merge-writes its whole results dict into the artifact
BENCH_KEYS = (
    "payload_mb", "n_leaves", "replicas", "tmpfs",
    "save_legacy", "save_stream",
    "restore_full_legacy", "restore_full_stream", "restore_one_leaf_ranged",
    "save_speedup", "save_peak_mem_ratio", "restore_engine",
    "restore_engine_io",
)


def _rss_mb() -> float:
    for line in open("/proc/self/status"):
        if line.startswith("VmRSS"):
            return int(line.split()[1]) / 1024.0
    return 0.0


class _RssSampler:
    """Background max-RSS sampler (the paper's LDMS trace, at ~1 ms)."""

    def __init__(self):
        self._stop = threading.Event()
        self.base_mb = 0.0
        self.peak_mb = 0.0

    def __enter__(self):
        self.base_mb = self.peak_mb = _rss_mb()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()
        return self

    def _run(self):
        while not self._stop.is_set():
            self.peak_mb = max(self.peak_mb, _rss_mb())
            time.sleep(0.001)

    def __exit__(self, *exc):
        self._stop.set()
        self._t.join()
        self.peak_mb = max(self.peak_mb, _rss_mb())

    @property
    def extra_mb(self) -> float:
        return self.peak_mb - self.base_mb


def run_restore_engine(payload_mb: int = 64, n_shards: int = 8,
                       workers_list=(1, 2, 4, 8), repeats: int = 3,
                       smoke: bool = False) -> dict:
    """Parallel multi-shard restore engine: restore GB/s vs reader count
    under the simulated shared-parallel-FS latency (per-op latency is what a
    thread pool hides), plus the cold-vs-promoted restart contrast — the
    paper's Fig.-2 container-image-cache effect as shared->local promotion."""
    import os
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore

    if smoke:
        payload_mb, workers_list, repeats = 8, (1, 4), 1
    tmp_root = "/dev/shm" if os.path.isdir("/dev/shm") else None
    rng = np.random.default_rng(0)
    n_leaves = n_shards * 4
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    tree = {f"l{i:03d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}
    payload_bytes = sum(a.nbytes for a in tree.values())
    out: dict = {"payload_mb": payload_bytes / 1e6, "n_shards": n_shards}
    with tempfile.TemporaryDirectory(dir=tmp_root) as d:
        store = TieredStore(Path(d), sim_io_factor=1.0, seed=0)
        pol = CheckpointPolicy(replicas=1)
        for w in range(n_shards):
            CheckpointManager(store, pol, worker_id=w,
                              num_workers=n_shards).save(1, tree)
        CheckpointManager(store, pol,
                          num_workers=n_shards).commit(1, num_workers=n_shards)

        curve: dict = {}
        for wk in workers_list:
            m = CheckpointManager(store, CheckpointPolicy(restore_workers=wk))
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                m.restore(tree)
                best = min(best, time.perf_counter() - t0)
            curve[str(wk)] = {"wall_s": best,
                              "gb_per_s": payload_bytes / best / 1e9,
                              "tasks": (m.last_restore_stats or {}).get("tasks")}
        out["restore_gbps_vs_workers_sim_shared"] = curve
        hi = str(workers_list[-1])
        out["parallel_restore_speedup"] = (curve["1"]["wall_s"]
                                           / curve[hi]["wall_s"])

        # restart curve: cold (shared FS) vs promoted (node-local tier)
        m = CheckpointManager(store, CheckpointPolicy(promote="on_restore"))
        t0 = time.perf_counter()
        m.restore(tree)
        cold_s = time.perf_counter() - t0
        m.wait_promotions()
        m2 = CheckpointManager(store, CheckpointPolicy(promote="on_restore"))
        t0 = time.perf_counter()
        m2.restore(tree)
        promoted_s = time.perf_counter() - t0
        out["restart_curve"] = {
            "cold_shared_s": cold_s,
            "promoted_local_s": promoted_s,
            "promotion_speedup": cold_s / max(promoted_s, 1e-9),
            "served_promoted": bool((m2.last_restore_stats or {}).get("promoted")),
        }
        m.close()
        m2.close()
    return out


def run_restore_engine_io(payload_mb: int = 32, workers: int = 8,
                          io_batch: int = 16, compress_level: int = 3,
                          repeats: int = 3, smoke: bool = False) -> dict:
    """The honest-I/O-plane contrast, apples-to-apples on one chunk plan:

    * **batched vs per-range** — the SAME delta checkpoint restored through
      the same worker pool, once with ``io_batch=1`` (the legacy per-range
      submission, one simulated latency per chunk) and once batched (one
      submission per ``io_batch`` chunks).  Under the simulated shared-FS
      cost model the batch amortizes the per-op latency, which is exactly
      the io_uring/preadv story on real hardware.
    * **compressed vs raw cold-tier bytes** — the same tree saved twice,
      frameless and zstd/zlib-framed; the restore stats count FILE bytes per
      tier, so the ratio of shared-tier bytes moved is the honest measure of
      what compression saves the cold tier (hashes stay over raw bytes, so
      the plans are identical).
    """
    import os
    import tempfile

    from repro.checkpoint import serialization as SER
    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore

    if smoke:
        payload_mb, workers, repeats = 8, 4, 1
    tmp_root = "/dev/shm" if os.path.isdir("/dev/shm") else None
    rng = np.random.default_rng(0)
    n_leaves = 16
    elems = payload_mb * (1 << 20) // 4 // n_leaves
    # low-entropy payload (small-integer lattice as float32): three of every
    # four bytes are zero, so even zlib level-3 bites — the compress_ratio
    # row measures the PLANE, not this box's entropy luck
    tree = {f"l{i:03d}": rng.integers(0, 8, elems).astype(np.float32)
            for i in range(n_leaves)}
    payload_bytes = sum(a.nbytes for a in tree.values())

    def timed_restore(store, pol):
        best, stats = float("inf"), None
        for _ in range(repeats):
            m = CheckpointManager(store, pol)
            t0 = time.perf_counter()
            m.restore(tree)
            best = min(best, time.perf_counter() - t0)
            stats = m.last_restore_stats or {}
            m.close()
        return best, stats

    out: dict = {"payload_mb": payload_bytes / 1e6, "workers": workers,
                 "io_batch": io_batch, "compress_level": compress_level,
                 "codec": "zstd" if SER.zstd_available() else "zlib"}
    with tempfile.TemporaryDirectory(dir=tmp_root) as d:
        store = TieredStore(Path(d), sim_io_factor=1.0, seed=0)
        save_pol = CheckpointPolicy(delta=True, replicas=1)
        m = CheckpointManager(store, save_pol)
        m.save(1, tree)
        m.commit(1, num_workers=1)
        m.close()

        per_s, _ = timed_restore(store, CheckpointPolicy(
            delta=True, restore_workers=workers, io_batch=1))
        bat_s, raw_stats = timed_restore(store, CheckpointPolicy(
            delta=True, restore_workers=workers, io_batch=io_batch))
        out["per_range_gbps"] = payload_bytes / per_s / 1e9
        out["batched_gbps"] = payload_bytes / bat_s / 1e9
        out["batched_speedup"] = per_s / bat_s
        raw_shared = (raw_stats.get("bytes_by_tier") or {}).get("shared", 0)

        # same tree, compressed plane, separate prefix: plans are identical
        # (hashes over raw bytes), only the stored frames differ
        zpol = CheckpointPolicy(delta=True, replicas=1, prefix="zckpt",
                                compress=compress_level)
        mz = CheckpointManager(store, zpol)
        mz.save(1, tree)
        mz.commit(1, num_workers=1)
        mz.close()
        z_s, z_stats = timed_restore(store, CheckpointPolicy(
            delta=True, prefix="zckpt", restore_workers=workers,
            io_batch=io_batch, compress=compress_level))
        z_shared = (z_stats.get("bytes_by_tier") or {}).get("shared", 0)
        out["compressed_gbps"] = payload_bytes / z_s / 1e9
        out["cold_bytes_ratio"] = z_shared / max(raw_shared, 1)

        man = CheckpointManager(store, zpol).read_manifest(1)
        raw_b = framed_b = 0
        for e in man["leaves"]:
            for c in e.get("chunks") or ():
                raw_b += c["nbytes"]
                framed_b += c.get("cbytes", c["nbytes"])
        out["compress_ratio"] = framed_b / max(raw_b, 1)
    return out


def run_ckpt_io(results_dir: Path | None = None, payload_mb: int = 96,
                n_leaves: int = 12, replicas: int = 2, repeats: int = 5,
                smoke: bool = False) -> list[dict]:
    """Old-vs-new checkpoint I/O plane: save/restore GB/s + peak extra memory.

    legacy  = v1 writer (per-leaf ``tobytes`` + whole-shard BytesIO) + k full
              serial ``put`` writes; whole-shard read-back on restore.
    stream  = CRC-once zero-copy ``write_shard_stream`` through ``put_stream``
              (write once, OS-copy k-1 replicas); ranged single-leaf restore.

    The store root lives on tmpfs (/dev/shm) when available so the numbers
    measure the ENGINE's overhead — copies, CRC passes, replica fan-out —
    rather than this box's disk, whose bandwidth varies run to run (the
    paper's node-local container-cache tier is the same idea).  The shared
    tier's replica placement is randomized, so each save clears its prefix
    first — repeats don't accumulate stale full-payload copies in tmpfs.
    """
    import os
    import tempfile

    from repro.checkpoint import serialization as SER
    from repro.checkpoint.store import TieredStore

    if smoke:
        payload_mb, repeats = 8, 2
    tmp_root = "/dev/shm" if os.path.isdir("/dev/shm") else None
    rng = np.random.default_rng(0)
    leaf_elems = payload_mb * (1 << 20) // 4 // n_leaves
    records = [(f"leaf{i:02d}", rng.standard_normal(leaf_elems).astype(np.float32))
               for i in range(n_leaves)]
    payload_bytes = sum(a.nbytes for _, a in records)

    def measure(fn):
        best_s, peaks_buf, peaks_rss = float("inf"), [], []
        out = None
        for _ in range(repeats):
            tracemalloc.start()
            tracemalloc.reset_peak()
            with _RssSampler() as rss:
                t0 = time.perf_counter()
                out = fn()
                dt = time.perf_counter() - t0
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            best_s = min(best_s, dt)
            peaks_buf.append(peak)
            peaks_rss.append(rss.extra_mb)
        return {"wall_s": best_s,
                "gb_per_s": payload_bytes / best_s / 1e9,
                "peak_buffered_mb": float(np.median(peaks_buf)) / 1e6,
                "peak_extra_rss_mb": float(np.median(peaks_rss)),
                "out": out}

    results: dict = {"payload_mb": payload_bytes / 1e6, "n_leaves": n_leaves,
                     "replicas": replicas, "tmpfs": tmp_root is not None}
    with tempfile.TemporaryDirectory(dir=tmp_root) as d:
        store = TieredStore(Path(d))

        def save_legacy():
            # the seed path verbatim: double-copy serialization, then k FULL
            # serial writes of the payload (the current store.put would
            # OS-copy replicas, which is already part of the new engine)
            store.delete_prefix("shared", "legacy")
            data = SER.write_shard_bytes(records, meta={"step": 0})
            for i in range(replicas):
                p = Path(d) / "shared" / f"node{i}" / "legacy" / "shard.bin"
                p.parent.mkdir(parents=True, exist_ok=True)
                tmp = p.with_suffix(p.suffix + ".tmp")
                tmp.write_bytes(data)
                tmp.rename(p)

        def save_stream():
            # CRC folds chunk-by-chunk inside the stream, overlapped with the
            # replica writer threads — the non-incremental manager save path
            store.delete_prefix("shared", "stream")
            store.put_stream(
                "shared", "stream/shard.bin",
                lambda fp: SER.write_shard_stream(fp, records, meta={"step": 0}),
                replicas=replicas)

        # legacy replica fan-out re-wrote the payload k times from memory; the
        # new engine serializes once and OS-copies, so both timings include
        # the full k-replica durability cost.
        results["save_legacy"] = measure(save_legacy)
        results["save_stream"] = measure(save_stream)

        results["restore_full_legacy"] = measure(
            lambda: store.get_verified("shared", "legacy/shard.bin"))
        results["restore_full_stream"] = measure(
            lambda: store.get_verified("shared", "stream/shard.bin"))

        one = records[n_leaves // 2][0]
        ranged = measure(
            lambda: store.read_shard_leaves("shared", "stream/shard.bin", [one]))
        ranged["gb_per_s"] = (payload_bytes / n_leaves) / ranged["wall_s"] / 1e9
        results["restore_one_leaf_ranged"] = ranged

    for r in results.values():
        if isinstance(r, dict):
            r.pop("out", None)
    results["save_speedup"] = (results["save_legacy"]["wall_s"]
                               / results["save_stream"]["wall_s"])
    results["save_peak_mem_ratio"] = (
        results["save_legacy"]["peak_buffered_mb"]
        / max(results["save_stream"]["peak_buffered_mb"], 1e-9))
    results["restore_engine"] = eng = run_restore_engine(smoke=smoke)
    results["restore_engine_io"] = eio = run_restore_engine_io(smoke=smoke)

    # merge into the tracking artifact: bench_startup contributes its
    # placement_requeue key to the same file, whichever module runs last
    from benchmarks.bench_startup import merge_bench_ckpt_io, stamp_run_meta

    # restore-pool provenance next to the numbers it shaped: which worker
    # counts the curve swept and what the io-plane contrast ran at
    results["run_meta"] = stamp_run_meta({
        "restore_workers_list": [1, 4] if smoke else [1, 2, 4, 8],
        "io_workers": eio["workers"],
        "io_batch": eio["io_batch"],
    })
    merge_bench_ckpt_io(results)
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "ckpt_io.json").write_text(json.dumps(results, indent=1))

    rows = []
    for name in ("save_legacy", "save_stream", "restore_full_stream",
                 "restore_one_leaf_ranged"):
        r = results[name]
        rows.append({
            "name": f"ckpt_io_{name}",
            "us_per_call": r["wall_s"] * 1e6,
            "derived": (f"{r['gb_per_s']:.2f}GB/s "
                        f"peak_buf={r['peak_buffered_mb']:.1f}MB "
                        f"peak_rss=+{r['peak_extra_rss_mb']:.1f}MB"),
        })
    rows.append({
        "name": "ckpt_io_summary",
        "us_per_call": 0.0,
        "derived": (f"save_speedup={results['save_speedup']:.2f}x "
                    f"peak_mem_ratio={results['save_peak_mem_ratio']:.1f}x"),
    })
    serial_wall = eng["restore_gbps_vs_workers_sim_shared"]["1"]["wall_s"]
    for wk, r in eng["restore_gbps_vs_workers_sim_shared"].items():
        rows.append({
            "name": f"ckpt_restore_parallel_w{wk}",
            "us_per_call": r["wall_s"] * 1e6,
            "derived": (f"{r['gb_per_s']:.2f}GB/s tasks={r['tasks']} "
                        f"vs_serial={serial_wall / r['wall_s']:.2f}x"),
        })
    rc = eng["restart_curve"]
    rows.append({
        "name": "ckpt_restore_promotion",
        "us_per_call": rc["promoted_local_s"] * 1e6,
        "derived": (f"cold={rc['cold_shared_s']*1e3:.1f}ms "
                    f"promoted={rc['promoted_local_s']*1e3:.1f}ms "
                    f"speedup={rc['promotion_speedup']:.1f}x "
                    f"served_promoted={rc['served_promoted']}"),
    })
    rows.append({
        "name": "ckpt_restore_engine_io",
        "us_per_call": 0.0,
        "derived": (f"batched={eio['batched_gbps']:.2f}GB/s "
                    f"per_range={eio['per_range_gbps']:.2f}GB/s "
                    f"({eio['batched_speedup']:.2f}x) "
                    f"compress_ratio={eio['compress_ratio']:.2f} "
                    f"cold_bytes_ratio={eio['cold_bytes_ratio']:.2f} "
                    f"codec={eio['codec']}"),
    })
    return rows


def run(results_dir: Path | None = None, steps: int = 40, ckpt_every: int = 8,
        smoke: bool = False):
    if smoke:
        steps, ckpt_every = 6, 2
    from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
    from repro.checkpoint.store import TieredStore
    from repro.configs.base import get_config, reduced
    from repro.core.virtualization import fetch_tree, place_tree
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.parallel.mesh_rules import Rules
    from repro.train import step as TS
    import tempfile

    cfg = reduced(get_config("qwen2-0.5b")).replace(
        num_layers=4, d_model=256, d_ff=1024, vocab_size=8192)
    oc = adamw.OptConfig(warmup_steps=5, decay_steps=steps)
    mesh = make_host_mesh()
    rules = Rules(mesh)
    step_fn, *_ = TS.make_train_step(cfg, mesh, oc, rules=rules, donate=False)

    # JIT warmup outside all regimes so the first regime doesn't eat compile
    _pipe = SyntheticTokens(cfg, 8, 256, seed=0)
    _state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    jax.block_until_ready(step_fn(_state, next(_pipe))[1]["loss"])
    del _pipe, _state

    from repro.utils.tree import tree_bytes

    def regime(mode: str) -> dict:
        pipe = SyntheticTokens(cfg, 8, 256, seed=0)
        state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
        state_mb = tree_bytes(state) / 1e6
        trace = []
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(TieredStore(Path(d)),
                                    CheckpointPolicy(mode=("async" in mode and "async") or "sync"))
            t_start = time.perf_counter()
            step = 0
            restarted = False
            while step < steps:
                t0 = time.perf_counter()
                state, m = step_fn(state, next(pipe))
                jax.block_until_ready(m["loss"])
                ck = 0.0
                if mode != "none" and step and step % ckpt_every == 0:
                    tc = time.perf_counter()
                    mgr.save(step, fetch_tree(state))
                    if "sync" in mode or "restart" in mode:
                        mgr.wait_writes()
                    mgr.commit(step)
                    ck = time.perf_counter() - tc
                trace.append({"step": step, "t": time.perf_counter() - t_start,
                              "step_s": time.perf_counter() - t0,
                              "ckpt_s": ck, "rss_mb": _rss_mb()})
                step += 1
                if mode == "restart" and step == steps // 2 and not restarted:
                    # preemption: drop state, restore from last checkpoint
                    restarted = True
                    tr = time.perf_counter()
                    host, man = mgr.restore(TS.abstract_train_state(cfg, oc))
                    state = place_tree(host, TS.state_logical_axes(cfg), rules)
                    pipe.restore(pipe.state().__class__(0, man["step"] + 1))
                    step = man["step"] + 1
                    trace.append({"step": step, "restore_s": time.perf_counter() - tr,
                                  "rss_mb": _rss_mb(),
                                  "t": time.perf_counter() - t_start})
            mgr.close()
            total = time.perf_counter() - t_start
        return {"mode": mode, "total_s": total, "trace": trace,
                "mean_step_s": float(np.mean([x["step_s"] for x in trace if "step_s" in x])),
                "ckpt_s_sum": float(np.sum([x.get("ckpt_s", 0) for x in trace])),
                "state_mb": state_mb,
                "peak_rss_mb": max(x["rss_mb"] for x in trace)}

    out = [regime("none"), regime("sync"), regime("async"), regime("restart")]
    base = out[0]
    rows = []
    for r in out:
        # checkpoint memory overhead: the paper reports ~0.8% node-memory bump
        # (LDMS).  Process RSS on this allocator is too noisy per-step, so we
        # report the STRUCTURAL bound — the double-buffered host snapshot
        # (one host copy of the train state) relative to steady RSS.
        steady = float(np.median([x["rss_mb"] for x in r["trace"]
                                  if "rss_mb" in x]))
        snap_pct = (r["state_mb"] / steady * 100) if r["mode"] != "none" else 0.0
        rows.append({
            "name": f"cr_overhead_{r['mode']}",
            "us_per_call": r["mean_step_s"] * 1e6,
            "derived": (f"total={r['total_s']:.2f}s "
                        f"(+{100*(r['total_s']/base['total_s']-1):.1f}%) "
                        f"ckpt={r['ckpt_s_sum']:.2f}s "
                        f"snapshot_mem=+{snap_pct:.1f}%_of_rss"),
        })
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "cr_overhead.json").write_text(json.dumps(out, indent=1))
    rows.extend(run_ckpt_io(results_dir, smoke=smoke))
    return rows


if __name__ == "__main__":
    import sys
    _root = Path(__file__).resolve().parents[1]
    for _p in (str(_root / "src"), str(_root)):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    # standalone: just the I/O-plane comparison (fast, no model training)
    for row in run_ckpt_io():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
