"""Paper Fig. 4 analogue: runtime + memory across three C/R regimes.

Regimes: no-C/R baseline | checkpoint-only | checkpoint+restart (preemption at
mid-run, restore, finish).  Plus the beyond-paper async-checkpoint mode, to
quantify how much of the paper's checkpoint stall the double-buffered writer
hides.  Memory is RSS sampled every step (the paper's LDMS traces).

Paper claims reproduced (see EXPERIMENTS.md): checkpointing adds a small
runtime overhead and ~sub-percent memory overhead; checkpoint+restart completes
with total compute ~= baseline + restart cost instead of recomputing from
scratch.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np


def _rss_mb() -> float:
    for line in open("/proc/self/status"):
        if line.startswith("VmRSS"):
            return int(line.split()[1]) / 1024.0
    return 0.0


def run(results_dir: Path | None = None, steps: int = 40, ckpt_every: int = 8):
    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.store import TieredStore
    from repro.configs.base import get_config, reduced
    from repro.core.virtualization import fetch_tree, place_tree
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.parallel.mesh_rules import Rules
    from repro.train import step as TS
    import tempfile

    cfg = reduced(get_config("qwen2-0.5b")).replace(
        num_layers=4, d_model=256, d_ff=1024, vocab_size=8192)
    oc = adamw.OptConfig(warmup_steps=5, decay_steps=steps)
    mesh = make_host_mesh()
    rules = Rules(mesh)
    step_fn, *_ = TS.make_train_step(cfg, mesh, oc, rules=rules, donate=False)

    # JIT warmup outside all regimes so the first regime doesn't eat compile
    _pipe = SyntheticTokens(cfg, 8, 256, seed=0)
    _state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    jax.block_until_ready(step_fn(_state, next(_pipe))[1]["loss"])
    del _pipe, _state

    from repro.utils.tree import tree_bytes

    def regime(mode: str) -> dict:
        pipe = SyntheticTokens(cfg, 8, 256, seed=0)
        state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
        state_mb = tree_bytes(state) / 1e6
        trace = []
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(
                TieredStore(Path(d)),
                mode=("async" in mode and "async") or "sync")
            t_start = time.perf_counter()
            step = 0
            restarted = False
            while step < steps:
                t0 = time.perf_counter()
                state, m = step_fn(state, next(pipe))
                jax.block_until_ready(m["loss"])
                ck = 0.0
                if mode != "none" and step and step % ckpt_every == 0:
                    tc = time.perf_counter()
                    mgr.save(step, fetch_tree(state))
                    if "sync" in mode or "restart" in mode:
                        mgr.wait_writes()
                    mgr.commit(step)
                    ck = time.perf_counter() - tc
                trace.append({"step": step, "t": time.perf_counter() - t_start,
                              "step_s": time.perf_counter() - t0,
                              "ckpt_s": ck, "rss_mb": _rss_mb()})
                step += 1
                if mode == "restart" and step == steps // 2 and not restarted:
                    # preemption: drop state, restore from last checkpoint
                    restarted = True
                    tr = time.perf_counter()
                    host, man = mgr.restore(TS.abstract_train_state(cfg, oc))
                    state = place_tree(host, TS.state_logical_axes(cfg), rules)
                    pipe.restore(pipe.state().__class__(0, man["step"] + 1))
                    step = man["step"] + 1
                    trace.append({"step": step, "restore_s": time.perf_counter() - tr,
                                  "rss_mb": _rss_mb(),
                                  "t": time.perf_counter() - t_start})
            mgr.close()
            total = time.perf_counter() - t_start
        return {"mode": mode, "total_s": total, "trace": trace,
                "mean_step_s": float(np.mean([x["step_s"] for x in trace if "step_s" in x])),
                "ckpt_s_sum": float(np.sum([x.get("ckpt_s", 0) for x in trace])),
                "state_mb": state_mb,
                "peak_rss_mb": max(x["rss_mb"] for x in trace)}

    out = [regime("none"), regime("sync"), regime("async"), regime("restart")]
    base = out[0]
    rows = []
    for r in out:
        # checkpoint memory overhead: the paper reports ~0.8% node-memory bump
        # (LDMS).  Process RSS on this allocator is too noisy per-step, so we
        # report the STRUCTURAL bound — the double-buffered host snapshot
        # (one host copy of the train state) relative to steady RSS.
        steady = float(np.median([x["rss_mb"] for x in r["trace"]
                                  if "rss_mb" in x]))
        snap_pct = (r["state_mb"] / steady * 100) if r["mode"] != "none" else 0.0
        rows.append({
            "name": f"cr_overhead_{r['mode']}",
            "us_per_call": r["mean_step_s"] * 1e6,
            "derived": (f"total={r['total_s']:.2f}s "
                        f"(+{100*(r['total_s']/base['total_s']-1):.1f}%) "
                        f"ckpt={r['ckpt_s_sum']:.2f}s "
                        f"snapshot_mem=+{snap_pct:.1f}%_of_rss"),
        })
    if results_dir:
        results_dir.mkdir(parents=True, exist_ok=True)
        (results_dir / "cr_overhead.json").write_text(json.dumps(out, indent=1))
    return rows
