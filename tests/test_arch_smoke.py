"""Per-assigned-architecture smoke tests: REDUCED same-family config, one
forward/train step on CPU, asserting output shapes and no NaNs (the FULL
configs are exercised via the dry-run only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced, shapes_for
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.mesh_rules import Rules
from repro.train import step as TS


def _batch_for(cfg, rng, B=2, S=32):
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)}
    if cfg.num_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model), np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, rng):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    B, S = batch["tokens"].shape[:2]

    h, _, aux = M.forward_full(params, cfg, batch, moe_groups=2, remat=False)
    assert h.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all(), f"{arch}: NaN in hidden"

    oc = adamw.OptConfig(warmup_steps=1, decay_steps=4)
    mesh = make_host_mesh()
    jitted, *_ = TS.make_train_step(cfg, mesh, oc, rules=Rules(mesh), donate=False)
    state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    state, metrics = jitted(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert int(state["step"]) == 1
    # grads actually applied
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_shape_cells(arch):
    """Every arch declares its assigned shape set; long_500k only sub-quadratic."""
    cfg = get_config(arch)
    names = [s.name for s in shapes_for(cfg)]
    assert names[:3] == ["train_4k", "prefill_32k", "decode_32k"]
    if arch in ("zamba2-1.2b", "rwkv6-1.6b"):
        assert "long_500k" in names
    else:
        assert "long_500k" not in names


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "deepseek-v3-671b", "zamba2-1.2b",
                                  "rwkv6-1.6b", "musicgen-large"])
def test_decode_consistency(arch, rng):
    """Prefill + token-by-token decode == full forward (per family)."""
    cfg = reduced(get_config(arch)).replace(capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S, P = 1, 16, 8
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)
    h, _, _ = M.forward_full(params, cfg, {"tokens": tokens}, moe_groups=1,
                             remat=False)
    full_logits = M.logits_fn(params, cfg, h)
    logits_p, cache = M.prefill(params, cfg, {"tokens": tokens[:, :P]},
                                max_seq=S, moe_groups=1)
    errs = [float(np.abs(np.asarray(logits_p) - np.asarray(full_logits[:, P - 1])).max())]
    for t in range(P, S):
        lg, cache = M.decode_step(params, cfg, tokens[:, t], cache)
        errs.append(float(np.abs(np.asarray(lg) - np.asarray(full_logits[:, t])).max()))
    assert max(errs) < 2e-3, (arch, errs)


def test_param_counts_plausible():
    """Full configs should land near their nameplate sizes."""
    expect = {
        "qwen2-0.5b": (0.35e9, 0.8e9),
        "granite-8b": (7e9, 9.5e9),
        "qwen3-4b": (3e9, 5e9),
        "llama3.2-1b": (1.0e9, 1.7e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "granite-moe-3b-a800m": (2e9, 4e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "musicgen-large": (2.8e9, 3.8e9),   # musicgen-large is the 3.3B model
        "rwkv6-1.6b": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
