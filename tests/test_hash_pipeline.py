"""Parallel chunk hash/CRC engine, CRC32 combining, fingerprint pre-filter
and the pre-dump (precommit) save path.

The load-bearing contract: whatever the engine parallelizes, reuses or
pre-computes, the produced (entries, views, leaf_crc) — and therefore the
bytes a restore returns — are byte-identical to the serial ``chunk_leaf``
path with no shortcuts."""
import logging
import zlib

import numpy as np
import pytest

from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import TieredStore, chunk_rel

CHUNK = 1 << 16


def _tree(rng, n_leaves=4, elems=70_000):
    return {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}


def _mutate(tree, names, elems=100):
    out = dict(tree)
    for n in names:
        a = out[n].copy()
        a[:elems] += 1.0
        out[n] = a
    return out


def _assert_trees_equal(got, want):
    for k, a in want.items():
        assert np.array_equal(np.asarray(got[k]), np.asarray(a)), k


# ---------------------------------------------------------------------------
# crc32_combine
# ---------------------------------------------------------------------------

def test_crc32_combine_matches_zlib_on_concatenation(rng):
    data = rng.integers(0, 256, size=100_000, dtype=np.uint8).tobytes()
    for split in (0, 1, 17, 4096, 65_536, len(data) - 1, len(data)):
        a, b = data[:split], data[split:]
        got = SER.crc32_combine(zlib.crc32(a), zlib.crc32(b), len(b))
        assert got == zlib.crc32(data), split


def test_crc32_combine_multi_piece_fold(rng):
    pieces = [rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
              for n in (1, 7, 333, 65_536, 70_001, 0)]
    crc = 0
    for p in pieces:
        crc = SER.crc32_combine(crc, zlib.crc32(p), len(p))
    assert crc == zlib.crc32(b"".join(pieces))


def test_crc32_combine_zero_length_is_identity():
    assert SER.crc32_combine(0xDEADBEEF, 0x123, 0) == 0xDEADBEEF


# ---------------------------------------------------------------------------
# parallel engine == serial chunk_leaf, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbytes", [0, 1, CHUNK - 1, CHUNK, CHUNK + 1,
                                    3 * CHUNK + 17])
def test_parallel_chunk_leaf_identical_to_serial(rng, nbytes):
    arr = rng.integers(0, 256, size=nbytes, dtype=np.uint8)
    want_entries, want_views, want_crc = SER.chunk_leaf(arr, CHUNK)
    eng = SER.ChunkHashEngine(workers=4)
    try:
        entries, views, crc = eng.chunk_leaf(arr, CHUNK)
    finally:
        eng.close()
    assert entries == want_entries
    assert crc == want_crc
    assert [bytes(v) for v in views] == [bytes(v) for v in want_views]


def test_parallel_chunk_records_many_leaves(rng):
    items = [(f"l{i}", rng.standard_normal(50_000 + i * 7).astype(np.float32))
             for i in range(6)]
    eng = SER.ChunkHashEngine(workers=4)
    try:
        out, stats = eng.chunk_records(items, CHUNK)
    finally:
        eng.close()
    total = 0
    for name, arr in items:
        entries, views, crc = out[name]
        w_entries, _, w_crc = SER.chunk_leaf(arr, CHUNK)
        assert entries == w_entries and crc == w_crc, name
        total += len(entries)
    assert stats["chunks_hashed"] == total and stats["chunks_known"] == 0


def test_chunk_records_known_entries_skip_hashing(rng):
    items = [("a", rng.standard_normal(60_000).astype(np.float32))]
    eng = SER.ChunkHashEngine(workers=1)
    try:
        fresh, _ = eng.chunk_records(items, CHUNK)
        known = {"a": dict(enumerate(fresh["a"][0]))}
        again, stats = eng.chunk_records(items, CHUNK, known=known)
    finally:
        eng.close()
    assert stats["chunks_hashed"] == 0
    assert stats["chunks_known"] == len(fresh["a"][0])
    assert again["a"][0] == fresh["a"][0] and again["a"][2] == fresh["a"][2]


def test_chunk_records_stamps_fingerprints(rng):
    arr = rng.standard_normal(40_000).astype(np.float32)
    fp = SER.fingerprint_chunks(SER.as_byte_view(arr), CHUNK)
    eng = SER.ChunkHashEngine(workers=1)
    try:
        out, _ = eng.chunk_records([("a", arr)], CHUNK, fps={"a": fp})
    finally:
        eng.close()
    assert [e["fp"] for e in out["a"][0]] == [int(x) for x in fp]


# ---------------------------------------------------------------------------
# host fingerprints: semantics + agreement with the device kernels
# ---------------------------------------------------------------------------

def test_fingerprint_chunks_basic_shape_and_sensitivity(rng):
    data = rng.integers(0, 256, size=5 * CHUNK + 100, dtype=np.uint8)
    fp = SER.fingerprint_chunks(data, CHUNK)
    assert fp.dtype == np.uint32 and len(fp) == 6
    flipped = data.copy()
    flipped[3 * CHUNK + 5] ^= 1
    fp2 = SER.fingerprint_chunks(flipped, CHUNK)
    assert fp2[3] != fp[3]
    assert np.array_equal(np.delete(fp2, 3), np.delete(fp, 3))


def test_fingerprint_is_position_independent_within_leaf(rng):
    chunk = rng.integers(0, 256, size=CHUNK, dtype=np.uint8)
    rep = np.concatenate([chunk, chunk, chunk])
    fp = SER.fingerprint_chunks(rep, CHUNK)
    assert fp[0] == fp[1] == fp[2]


def test_fingerprint_chunks_rejects_unaligned_chunk_bytes():
    with pytest.raises(ValueError):
        SER.fingerprint_chunks(b"\0" * 16, 6)
    assert len(SER.fingerprint_chunks(b"", CHUNK)) == 0


@pytest.mark.parametrize("n,chunk_words", [(4096, 1024), (5000, 1024),
                                           (40, 8), (8, 8)])
def test_fingerprint_host_vs_device_impls(rng, n, chunk_words):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels import ops

    words = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    host = SER.fingerprint_chunks(words.tobytes(), 4 * chunk_words)
    dev_ref = np.asarray(ops.chunk_fingerprints(
        jnp.asarray(words), chunk_words=chunk_words, impl="ref"))
    dev_pl = np.asarray(ops.chunk_fingerprints(
        jnp.asarray(words), chunk_words=chunk_words,
        impl="pallas_interpret"))
    assert np.array_equal(host, dev_ref)
    assert np.array_equal(host, dev_pl)


# ---------------------------------------------------------------------------
# REPRO_HASH_WORKERS env knob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", ["not-a-number", "-3", "0", "2.5"])
def test_auto_hash_workers_invalid_env_falls_back_with_warning(
        monkeypatch, caplog, bad):
    monkeypatch.setenv(SER.ENV_HASH_WORKERS, bad)
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.serialization"):
        n = SER.auto_hash_workers(cap=4)
    assert 1 <= n <= 4                       # auto sizing, never ValueError
    assert any(SER.ENV_HASH_WORKERS in r.message for r in caplog.records)


def test_auto_hash_workers_valid_env_still_wins(monkeypatch):
    monkeypatch.setenv(SER.ENV_HASH_WORKERS, "3")
    assert SER.auto_hash_workers(cap=1) == 3


def test_engine_workers_resolved_from_env(monkeypatch):
    monkeypatch.setenv(SER.ENV_HASH_WORKERS, "5")
    assert SER.ChunkHashEngine().workers == 5
    assert SER.ChunkHashEngine(workers=2).workers == 2


# ---------------------------------------------------------------------------
# manager: fingerprint pre-filter + pre-dump save paths
# ---------------------------------------------------------------------------

def test_fingerprint_prefilter_skips_clean_chunks_and_restores(rng, tmp_path):
    tree = _tree(rng)
    store = TieredStore(tmp_path / "ck", seed=0)
    m = CheckpointManager(store,
                          CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK,
                                           fingerprint=True, hash_workers=2))
    m.save(1, tree)
    m.commit(1)
    tree2 = _mutate(tree, ["l00"])
    p = m.save(2, tree2)
    m.commit(2)
    d = p["delta"]
    assert d["chunks_fp_clean"] > 0
    assert d["chunks_hashed"] + d["chunks_fp_clean"] == d["chunks_total"]
    assert d["chunks_hashed"] <= 2           # only the dirtied chunk (+slack)
    m.close()
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree2)


def test_precommit_requires_delta_mode(rng, tmp_path):
    m = CheckpointManager(TieredStore(tmp_path / "ck", seed=0), CheckpointPolicy(replicas=1))
    with pytest.raises(ValueError):
        m.precommit(1, _tree(rng, n_leaves=1, elems=10))
    m.close()


def test_predump_then_save_skips_hash_and_write(rng, tmp_path):
    tree = _tree(rng)
    store = TieredStore(tmp_path / "ck", seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK, hash_workers=2))
    m.save(1, tree)
    m.commit(1)
    tree2 = _mutate(tree, ["l00"])
    info = m.precommit(2, tree2)
    assert info["step"] == 2 and info["snapshot_s"] >= 0
    p = m.save(2, tree2)            # consumes the pre-dump (waits the pool)
    m.commit(2)
    d = p["delta"]
    assert d["predump_step"] == 2
    assert d["chunks_hashed"] == 0           # everything pre-hashed
    assert d["chunks_predumped"] >= 1        # dirty chunk pre-written
    assert d["chunks_written"] == 0          # ...so save re-wrote nothing
    m.close()
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree2)


def test_predump_with_mutation_after_is_still_byte_exact(rng, tmp_path):
    """CRIU's pre-dump contract: bytes dirtied AFTER the pre-dump are caught
    by the live fingerprint comparison and re-hashed/re-written — the
    committed state is the save-time tree, never the pre-dump snapshot."""
    tree = _tree(rng)
    store = TieredStore(tmp_path / "ck", seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK, hash_workers=2))
    m.save(1, tree)
    m.commit(1)
    tree2 = _mutate(tree, ["l00"])
    m.precommit(2, tree2)
    tree3 = _mutate(tree2, ["l00", "l01"], elems=50)   # dirtied after predump
    p = m.save(2, tree3)
    m.commit(2)
    assert p["delta"]["chunks_hashed"] >= 1
    m.close()
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree3)


def test_predump_orphan_chunks_are_swept(rng, tmp_path):
    """A pre-written chunk whose content was re-dirtied before the save must
    not leak in the dedup store: it is unreferenced by any manifest."""
    tree = _tree(rng)
    store = TieredStore(tmp_path / "ck", seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK, hash_workers=1))
    m.save(1, tree)
    m.commit(1)
    tree2 = _mutate(tree, ["l00"])
    m.precommit(2, tree2)
    m.wait_predump()
    # the predumped dirty chunk of tree2's l00
    orphan = SER.chunk_leaf(tree2["l00"], CHUNK)[0][0]["hash"]
    assert store.exists("shared", chunk_rel("ckpt", orphan))
    tree3 = _mutate(tree2, ["l00"])          # re-dirty the same chunk
    m.save(2, tree3)
    m.commit(2)
    assert not store.exists("shared", chunk_rel("ckpt", orphan))
    m.close()
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree3)


def test_predump_sweep_spares_chunks_of_older_kept_manifests(rng, tmp_path):
    """A pre-written chunk whose content recurs from an older RETAINED step
    (hash absent from the parent manifest) must survive the orphan sweep:
    the old step's manifest still resolves through that chunk file, and
    deleting it would tear a restorable checkpoint."""
    tree = _tree(rng)
    store = TieredStore(tmp_path / "ck", seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK, hash_workers=1,
        keep_last=3))
    m.save(1, tree)
    m.commit(1)
    tree2 = _mutate(tree, ["l00"])
    m.save(2, tree2)
    m.commit(2)
    # pre-dump a state whose l00 chunk 0 REVERTS to step 1's content: not in
    # the parent (step 2) manifest, so the pre-dump writes it — onto the
    # very file step 1 still references
    m.precommit(3, tree)
    m.wait_predump()
    shared = SER.chunk_leaf(tree["l00"], CHUNK)[0][0]["hash"]
    assert store.exists("shared", chunk_rel("ckpt", shared))
    tree3 = _mutate(tree2, ["l00"], elems=30)    # dirtied again before save
    m.save(3, tree3)
    m.commit(3)
    assert store.exists("shared", chunk_rel("ckpt", shared))
    got, _ = m.restore(tree, step=1)             # step 1 must still restore
    _assert_trees_equal(got, tree)
    got, _ = m.restore(tree)
    _assert_trees_equal(got, tree3)
    m.close()


def test_second_precommit_merges_superseded_predump_writes(rng, tmp_path):
    """Re-pre-dumping before the consuming save must not orphan the FIRST
    pre-dump's chunk writes: no manifest references them, so only the
    consuming save's sweep can reclaim them."""
    tree = _tree(rng)
    store = TieredStore(tmp_path / "ck", seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK, hash_workers=1))
    m.save(1, tree)
    m.commit(1)
    tree2 = _mutate(tree, ["l00"])
    m.precommit(2, tree2)
    m.wait_predump()
    orphan1 = SER.chunk_leaf(tree2["l00"], CHUNK)[0][0]["hash"]
    assert store.exists("shared", chunk_rel("ckpt", orphan1))
    tree3 = _mutate(tree2, ["l00"])
    m.precommit(2, tree3)                        # supersedes the first
    m.wait_predump()
    orphan2 = SER.chunk_leaf(tree3["l00"], CHUNK)[0][0]["hash"]
    tree4 = _mutate(tree3, ["l00"])              # dirty once more: neither
    m.save(2, tree4)                             # pre-written chunk is final
    m.commit(2)
    assert not store.exists("shared", chunk_rel("ckpt", orphan1))
    assert not store.exists("shared", chunk_rel("ckpt", orphan2))
    m.close()
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree4)


def test_manager_rejects_unaligned_chunk_bytes_in_delta_mode(tmp_path):
    store = TieredStore(tmp_path / "ck", seed=0)
    with pytest.raises(ValueError, match="multiple of 4"):
        CheckpointManager(store, CheckpointPolicy(replicas=1, delta=True, chunk_bytes=6))
    # non-delta managers never fingerprint: unaligned sizes stay legal
    CheckpointManager(store, CheckpointPolicy(replicas=1, chunk_bytes=6)).close()


def test_predump_boundary_schedule():
    from repro.train.step import predump_boundary

    fires = [s for s in range(12) if predump_boundary(s, 5, lead=1)]
    assert fires == [4, 9]                   # one step before 5, 10
    # lead>1 opens a WINDOW: every step in the last `lead` before the
    # boundary pre-dumps (iterative pre-copy)
    fires = [s for s in range(12) if predump_boundary(s, 5, lead=2)]
    assert fires == [3, 4, 8, 9]
    # lead clamped below the interval; interval=1 never pre-dumps
    assert [s for s in range(6) if predump_boundary(s, 2, lead=7)] == [1, 3, 5]
    assert not any(predump_boundary(s, 1) for s in range(6))
    assert not any(predump_boundary(s, 0) for s in range(6))
