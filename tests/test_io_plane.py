"""Honest I/O plane: batched ranged-read submission (``pread_batch`` /
``get_ranges``), direct-I/O aligned reads, the per-chunk compression frame,
calibrated tier profiles, and the shared atomic-write/env-knob helpers."""
import json
import logging
import os
import zlib
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import calibrate as CAL
from repro.checkpoint import io_backend as IOB
from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.restore_engine import (ENV_IO_BATCH, DEFAULT_IO_BATCH,
                                             ParallelRestorer, auto_io_batch)
from repro.checkpoint.store import TieredStore
from repro.utils.atomic import atomic_write_bytes, atomic_write_json
from tests.faults import ByteCountingStoreMixin, PreadFaults


def _edge_tree(rng):
    """Leaves exercising every alignment corner: zero-byte, sub-alignment,
    unaligned tails, and a few normal multi-chunk leaves."""
    return {
        "zero": np.zeros(0, dtype=np.float32),
        "tiny": rng.standard_normal(3).astype(np.float64),      # 24 bytes
        "tail": rng.standard_normal(33_333).astype(np.float32),  # odd tail
        "big0": rng.standard_normal(80_000).astype(np.float32),
        "big1": rng.integers(0, 8, 80_000).astype(np.float32),   # compressible
    }


def _assert_trees_equal(got, want):
    for k, a in want.items():
        assert np.asarray(got[k]).dtype == np.asarray(a).dtype, k
        assert np.array_equal(np.asarray(got[k]), np.asarray(a)), k


# ---------------------------------------------------------------------------
# chunk frame codec
# ---------------------------------------------------------------------------

def test_frame_round_trip_all_corners():
    for data in (b"", b"x", b"hello " * 4096, os.urandom(10_000)):
        for level in (1, 3, 9):
            blob = SER.frame_chunk(data, level)
            assert blob[:3] == SER.CHUNK_FRAME_MAGIC
            out = SER.unframe_chunk(blob, len(data), crc32=zlib.crc32(data))
            assert out == data
        # legacy frameless blobs pass through untouched
        assert SER.unframe_chunk(data, len(data),
                                 crc32=zlib.crc32(data)) == data


def test_frame_stores_raw_when_compression_does_not_pay():
    data = os.urandom(4096)          # incompressible: deflate would GROW it
    blob = SER.frame_chunk(data, 9)
    assert blob[3] == SER.CODEC_RAW
    assert len(blob) == len(data) + SER.CHUNK_FRAME_LEN
    assert SER.unframe_chunk(blob, len(data)) == data


def test_frame_ambiguity_corner_crc_arbiter():
    # a LEGACY chunk whose raw content starts with the magic and whose length
    # could parse either way: the CRC must arbitrate, never the guess
    legacy = SER.CHUNK_FRAME_MAGIC + bytes([SER.CODEC_RAW]) + b"\x07" * 96
    out = SER.unframe_chunk(legacy, len(legacy), crc32=zlib.crc32(legacy))
    assert out == legacy
    # and the framed reading of the same bytes wins when ITS payload matches
    payload = legacy[SER.CHUNK_FRAME_LEN:]
    out = SER.unframe_chunk(legacy, len(payload), crc32=zlib.crc32(payload))
    assert out == payload


def test_frame_corruption_raises_checksum_error():
    data = b"payload " * 512
    blob = bytearray(SER.frame_chunk(data, 3))
    blob[10] ^= 0xFF
    with pytest.raises(SER.ChecksumError):
        SER.unframe_chunk(bytes(blob), len(data), crc32=zlib.crc32(data))


# ---------------------------------------------------------------------------
# io_backend: batched submission + direct I/O
# ---------------------------------------------------------------------------

def _scatter_files(tmp_path, rng):
    files = {}
    for name, n in (("a.bin", 100_000), ("b.bin", 4096), ("c.bin", 1)):
        p = tmp_path / name
        p.write_bytes(bytes(rng.integers(0, 256, n, dtype=np.uint8)))
        files[name] = p
    return files


def _mixed_requests(files):
    a, b, c = files["a.bin"], files["b.bin"], files["c.bin"]
    return [
        (a, 0, 10),            # head
        (a, 99_990, 10),       # exact tail
        (a, 4095, 2),          # straddles an alignment boundary
        (a, 50_000, 0),        # zero-byte range
        (b, 0, 4096),          # whole file
        (b, 1, 17),            # sub-alignment offset AND length
        (c, 0, 1),             # one-byte file
        (a, 12_345, 4321),
    ]


def test_read_ranges_buffered_matches_slices(tmp_path, rng):
    files = _scatter_files(tmp_path, rng)
    reqs = _mixed_requests(files)
    got = IOB.read_ranges(reqs)
    for (p, off, n), out in zip(reqs, got):
        assert out == p.read_bytes()[off:off + n]


def test_read_ranges_direct_io_matches_buffered(tmp_path, rng):
    align = IOB.probe_direct_io(tmp_path)
    if align is None:
        pytest.skip("filesystem rejects O_DIRECT")
    files = _scatter_files(tmp_path, rng)
    reqs = _mixed_requests(files)
    direct = IOB.read_ranges(reqs, direct_align=align)
    buffered = IOB.read_ranges(reqs)
    assert direct == buffered


def test_read_ranges_direct_falls_back_cleanly(tmp_path, rng, monkeypatch):
    """An O_DIRECT open failing mid-batch must degrade to buffered for that
    file — same results, no exception slots."""
    files = _scatter_files(tmp_path, rng)
    reqs = _mixed_requests(files)
    real_open = os.open

    def no_direct(path, flags, *a, **kw):
        if flags & getattr(os, "O_DIRECT", 0):
            raise OSError(22, "injected: O_DIRECT unsupported")
        return real_open(path, flags, *a, **kw)

    monkeypatch.setattr(os, "open", no_direct)
    got = IOB.read_ranges(reqs, direct_align=4096)
    for (p, off, n), out in zip(reqs, got):
        assert out == p.read_bytes()[off:off + n]


def test_read_ranges_missing_file_fails_per_slot(tmp_path, rng):
    ok = tmp_path / "ok.bin"
    ok.write_bytes(b"k" * 64)
    got = IOB.read_ranges([(ok, 0, 8), (tmp_path / "gone.bin", 0, 8)])
    assert got[0] == b"k" * 8
    assert isinstance(got[1], OSError)


def test_probe_direct_io_cached_per_directory(tmp_path, monkeypatch):
    IOB.reset_direct_io_cache()
    calls = {"n": 0}
    real_open = os.open

    def counting(path, flags, *a, **kw):
        if flags & getattr(os, "O_DIRECT", 0):
            calls["n"] += 1
        return real_open(path, flags, *a, **kw)

    monkeypatch.setattr(os, "open", counting)
    first = IOB.probe_direct_io(tmp_path)
    again = IOB.probe_direct_io(tmp_path)
    assert first == again
    assert calls["n"] <= 1          # second call served from the cache
    IOB.reset_direct_io_cache()


# ---------------------------------------------------------------------------
# store: pread_batch / get_ranges
# ---------------------------------------------------------------------------

def _ranged_store(tmp_path, rng, cls=TieredStore):
    store = cls(tmp_path, seed=0)
    store.put("shared", "f/a.bin",
              bytes(rng.integers(0, 256, 60_000, dtype=np.uint8)),
              replicas=2)
    store.put("shared", "f/b.bin", b"B" * 5000, replicas=1)
    return store


def test_get_ranges_matches_get_range(tmp_path, rng):
    store = _ranged_store(tmp_path, rng)
    reqs = [("f/a.bin", 0, 100), ("f/a.bin", 59_990, 10),
            ("f/b.bin", 4096, 904), ("f/a.bin", 500, 0),
            ("f/b.bin", 3, 17)]
    assert store.get_ranges("shared", reqs) == [
        store.get_range("shared", r, o, n) for r, o, n in reqs]
    store.close()


def test_pread_batch_whole_file_and_missing(tmp_path, rng):
    store = _ranged_store(tmp_path, rng)
    p = store.replica_paths("shared", "f/b.bin")[0]
    out = store.pread_batch("shared", [(p, 0, None), (p, 4000, None),
                                       (tmp_path / "nope.bin", 0, None)])
    assert out[0] == b"B" * 5000
    assert out[1] == b"B" * 1000
    assert isinstance(out[2], Exception)
    store.close()


def test_get_ranges_replica_fallback_on_fault(tmp_path, rng):
    store = _ranged_store(tmp_path, rng)
    want = [store.get_range("shared", "f/a.bin", o, n)
            for o, n in ((0, 64), (1000, 512))]
    # first replica's reads die; get_ranges must fall back per-range
    victim = store.replica_paths("shared", "f/a.bin")[0]
    with PreadFaults(store, lambda p, off, n: Path(p) == Path(victim)):
        got = store.get_ranges("shared", [("f/a.bin", 0, 64),
                                          ("f/a.bin", 1000, 512)])
    assert got == want
    store.close()


def test_pread_batch_composes_with_pread_hooks(tmp_path, rng):
    """Instrumented stores override ``_pread``; the batch plane must degrade
    to per-range reads through the hook so every byte stays observed."""

    class Counting(ByteCountingStoreMixin, TieredStore):
        pass

    store = _ranged_store(tmp_path, rng, cls=Counting)
    got = store.get_ranges("shared", [("f/a.bin", 0, 1000),
                                      ("f/b.bin", 0, 5000)])
    assert [len(b) for b in got] == [1000, 5000]
    assert store.read_by_tier.get("shared") == 6000
    store.close()


def test_direct_io_mode_switch(tmp_path, rng):
    store = _ranged_store(tmp_path, rng)
    store.direct_io = False
    assert store._direct_alignment("shared",
                                   store.replica_paths("shared",
                                                       "f/a.bin")[0]) is None
    store.direct_io = True          # probe every tier, even hot ones
    p = store.replica_paths("shared", "f/a.bin")[0]
    align = store._direct_alignment("shared", p)
    if align is not None:           # host-dependent; correctness either way
        got = store.get_ranges("shared", [("f/a.bin", 1, 17)])
        assert got == [store.get_range("shared", "f/a.bin", 1, 17)]
    store.close()


# ---------------------------------------------------------------------------
# restore engine: batched + compressed byte-identity (v2 AND v3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compress", [0, 3])
def test_batched_restore_identity_v3(tmp_path, rng, compress):
    tree = _edge_tree(rng)
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        delta=True, replicas=2, chunk_bytes=1 << 16, compress=compress))
    m.save(1, tree)
    m.commit(1, num_workers=1)
    m.close()
    # serial raw-path reference (io_batch=1, one worker) vs batched pool
    serial = CheckpointManager(store, CheckpointPolicy(
        delta=True, restore_workers=1, io_batch=1))
    batched = CheckpointManager(store, CheckpointPolicy(
        delta=True, restore_workers=4, io_batch=16))
    out_s = serial.restore(tree)
    out_b = batched.restore(tree)
    named_s = out_s[0] if isinstance(out_s, tuple) else out_s
    named_b = out_b[0] if isinstance(out_b, tuple) else out_b
    _assert_trees_equal(named_s, tree)
    _assert_trees_equal(named_b, tree)
    for k in tree:
        assert np.asarray(named_b[k]).tobytes() == \
            np.asarray(named_s[k]).tobytes()
    serial.close()
    batched.close()
    store.close()


def test_batched_restore_identity_v2(tmp_path, rng):
    tree = _edge_tree(rng)
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(replicas=2))
    m.save(1, tree)
    m.commit(1, num_workers=1)
    m.close()
    for workers, io_batch in ((1, 1), (4, 16)):
        mr = CheckpointManager(store, CheckpointPolicy(
            restore_workers=workers, io_batch=io_batch))
        out = mr.restore(tree)
        named = out[0] if isinstance(out, tuple) else out
        _assert_trees_equal(named, tree)
        mr.close()
    store.close()


def test_compressed_manifest_records_cbytes_and_carries_them(tmp_path, rng):
    tree = _edge_tree(rng)
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        delta=True, replicas=1, chunk_bytes=1 << 16, compress=3))
    m.save(1, tree)
    m.commit(1, num_workers=1)
    man1 = m.read_manifest(1)
    chunks1 = [c for e in man1["leaves"] for c in e["chunks"]]
    assert all("cbytes" in c for c in chunks1)
    # the compressible leaf must actually shrink on disk
    assert sum(c["cbytes"] for c in chunks1) < sum(c["nbytes"]
                                                   for c in chunks1)
    # a delta step reuses the parent's chunks and CARRIES their cbytes
    tree2 = dict(tree)
    tree2["big0"] = tree["big0"] + 1.0
    m.save(2, tree2)
    m.commit(2, num_workers=1)
    man2 = m.read_manifest(2)
    by_hash1 = {c["hash"]: c["cbytes"] for c in chunks1}
    reused = [c for e in man2["leaves"] for c in e["chunks"]
              if c["hash"] in by_hash1]
    assert reused and all(c["cbytes"] == by_hash1[c["hash"]] for c in reused)
    m.close()
    store.close()


def test_compressed_promotion_restore(tmp_path, rng):
    """Promotion copies the FRAMED file; the verify must speak the frame."""
    tree = _edge_tree(rng)
    store = TieredStore(tmp_path, seed=0)
    pol = CheckpointPolicy(delta=True, replicas=1, chunk_bytes=1 << 16,
                           compress=3, promote="on_restore")
    m = CheckpointManager(store, pol)
    m.save(1, tree)
    m.commit(1, num_workers=1)
    out = m.restore(tree)
    m.wait_promotions()
    assert not m.promote_failures
    m2 = CheckpointManager(store, pol)
    out2 = m2.restore(tree)
    named = out2[0] if isinstance(out2, tuple) else out2
    _assert_trees_equal(named, tree)
    assert (m2.last_restore_stats or {}).get("promoted")
    m.close()
    m2.close()
    store.close()


# ---------------------------------------------------------------------------
# env knob + policy validation
# ---------------------------------------------------------------------------

def test_io_batch_env_knob(monkeypatch):
    monkeypatch.setenv(ENV_IO_BATCH, "7")
    assert auto_io_batch() == 7
    monkeypatch.delenv(ENV_IO_BATCH)
    assert auto_io_batch() == DEFAULT_IO_BATCH


@pytest.mark.parametrize("bad", ["zero?", "0", "-3", "1.5"])
def test_io_batch_env_knob_invalid_warns_and_falls_back(monkeypatch, caplog,
                                                        bad):
    monkeypatch.setenv(ENV_IO_BATCH, bad)
    with caplog.at_level(logging.WARNING):
        assert auto_io_batch() == DEFAULT_IO_BATCH
    assert any(ENV_IO_BATCH in r.message for r in caplog.records)


def test_io_batch_env_whitespace_is_unset(monkeypatch, caplog):
    monkeypatch.setenv(ENV_IO_BATCH, "  ")
    with caplog.at_level(logging.WARNING):
        assert auto_io_batch() == DEFAULT_IO_BATCH
    assert not caplog.records          # empty = unset, not a typo


def test_policy_validates_compress_and_io_batch():
    with pytest.raises(ValueError):
        CheckpointPolicy(compress=-1)
    with pytest.raises(ValueError):
        CheckpointPolicy(compress=23)
    with pytest.raises(ValueError):
        CheckpointPolicy(io_batch=-1)
    assert CheckpointPolicy(compress=22, io_batch=1)


def test_engine_io_batch_plumbing(tmp_path, monkeypatch):
    store = TieredStore(tmp_path, seed=0)
    assert ParallelRestorer(store, io_batch=5).io_batch == 5
    monkeypatch.setenv(ENV_IO_BATCH, "9")
    assert ParallelRestorer(store).io_batch == 9
    store.close()


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibrate_tiers_measures_applies_and_caches(tmp_path, monkeypatch):
    store = TieredStore(tmp_path, seed=0)
    before = {t: s.bandwidth_gbps for t, s in store.tiers.items()}
    prof = CAL.calibrate_tiers(store, file_bytes=1 << 18, ranges=4)
    assert (tmp_path / CAL.CALIB_FILENAME).exists()
    for t, spec in store.tiers.items():
        assert CAL._MIN_CONC <= spec.concurrency <= CAL._MAX_CONC
        assert spec.bandwidth_gbps > 0 and spec.latency_s > 0
    assert {t for t in before} == set(store.tiers)
    # second call must serve the cache, not re-measure
    calls = {"n": 0}
    real = CAL._measure_root

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(CAL, "_measure_root", counting)
    prof2 = CAL.calibrate_tiers(store, file_bytes=1 << 18, ranges=4)
    assert calls["n"] == 0
    assert prof2["roots"] == prof["roots"]
    # force re-measures
    CAL.calibrate_tiers(store, force=True, file_bytes=1 << 18, ranges=4)
    assert calls["n"] >= 1
    store.close()


def test_calibrate_skips_peer_tiers(tmp_path):
    store = TieredStore(tmp_path / "me", seed=0)
    peer_tier = store.add_peer("other", tmp_path / "other")
    spec_before = store.tiers[peer_tier]
    CAL.calibrate_tiers(store, file_bytes=1 << 18, ranges=4)
    assert store.tiers[peer_tier] is spec_before
    assert not (tmp_path / "other").exists()    # no cross-node side effects
    store.close()


# ---------------------------------------------------------------------------
# atomic write helper
# ---------------------------------------------------------------------------

def test_atomic_write_bytes_and_json(tmp_path):
    p = tmp_path / "deep" / "rec.json"
    atomic_write_json(p, {"a": 1})
    assert json.loads(p.read_text()) == {"a": 1}
    atomic_write_bytes(p, b"raw")
    assert p.read_bytes() == b"raw"
    # no tmp litter after successful writes
    assert [f.name for f in p.parent.iterdir()] == ["rec.json"]


def test_atomic_write_failure_leaves_no_litter(tmp_path, monkeypatch):
    p = tmp_path / "rec.json"
    atomic_write_json(p, {"keep": True})

    def boom(src, dst):
        raise OSError("injected replace failure")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError, match="injected"):
        atomic_write_bytes(p, b"clobber")
    monkeypatch.undo()
    assert json.loads(p.read_text()) == {"keep": True}
    assert [f.name for f in tmp_path.iterdir()] == ["rec.json"]
