"""Train-step factory: microbatch-accumulation equivalence, optimizer
behaviour, schedule, global-norm clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.parallel.mesh_rules import Rules
from repro.train import step as TS


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("llama3.2-1b"))
    oc = adamw.OptConfig(warmup_steps=2, decay_steps=10)
    mesh = make_host_mesh()
    rules = Rules(mesh)
    state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
    return cfg, oc, mesh, rules, state, batch


def test_microbatch_equivalence(setup):
    cfg, oc, mesh, rules, state, batch = setup
    s1, _, _ = TS.make_train_step(cfg, mesh, oc, microbatches=1, rules=rules,
                                  donate=False)
    s4, _, _ = TS.make_train_step(cfg, mesh, oc, microbatches=4, rules=rules,
                                  donate=False)
    n1, m1 = s1(state, batch)
    n4, m4 = s4(state, batch)
    # same data, same update — up to accumulation-order float noise
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        n1["params"], n4["params"])
    worst = max(jax.tree_util.tree_leaves(d))
    assert worst < 5e-5, worst


def test_grad_clip_bounds_update(setup):
    cfg, oc, mesh, rules, state, batch = setup
    oc_clip = adamw.OptConfig(warmup_steps=0, decay_steps=10, grad_clip=1e-8)
    s, _, _ = TS.make_train_step(cfg, mesh, oc_clip, rules=rules, donate=False)
    new_state, metrics = s(state, batch)
    # with a near-zero clip, params barely move beyond weight decay
    delta = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), new_state["params"], state["params"])
    assert max(jax.tree_util.tree_leaves(delta)) < 1e-2


def test_schedule_warmup_and_decay():
    oc = adamw.OptConfig(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(oc, jnp.asarray(0)))
    lr5 = float(adamw.schedule(oc, jnp.asarray(5)))
    lr10 = float(adamw.schedule(oc, jnp.asarray(10)))
    lr100 = float(adamw.schedule(oc, jnp.asarray(100)))
    assert lr0 == 0.0 and 0 < lr5 < lr10 <= 1.0
    assert abs(lr100 - 0.1) < 1e-6


def test_moment_dtype_bf16():
    cfg = reduced(get_config("qwen2-0.5b"))
    oc = adamw.OptConfig(moment_dtype="bfloat16")
    state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_leaves(state["opt"])
    assert all(x.dtype == jnp.bfloat16 for x in leaves)
