"""Reusable fault injectors for checkpoint/restore/placement chaos tests.

File-level faults (operate on a concrete replica file):
  * ``flip_byte``      — CRC-visible single-byte corruption (bit rot);
  * ``corrupt_range``  — XOR a byte range (torn page / partial overwrite);
  * ``truncate_file``  — truncated shard (a copy or node died mid-write);
  * ``tear_json``      — torn-write marker: a JSON file cut mid-object, as a
    crash between ``write`` and ``rename`` (or a non-atomic writer) leaves it.

Store-level faults:
  * ``replica_file``   — resolve the i-th replica path of ``tier:rel``;
  * ``PreadFaults``    — wrap a ``TieredStore``'s positional-read choke point
    so ranged reads matching a predicate raise ``OSError`` after the first
    ``after`` matching reads succeed (the "replica goes dark mid-restore"
    fault) — replaces the ad-hoc ``_pread`` monkeypatching tests used to do.

All injectors are deterministic; none of them require the store to be idle.
"""
from __future__ import annotations

import os
import threading
from pathlib import Path
from typing import Callable, Optional


def flip_byte(path: Path, offset: Optional[int] = None) -> int:
    """XOR one byte with 0xFF.  Default offset: the middle of the file —
    payload territory for a v2 (footer-last) shard, so headers still parse
    and the corruption is only catchable by a payload CRC check.  Returns
    the offset flipped."""
    path = Path(path)
    size = path.stat().st_size
    if offset is None:
        offset = size // 2
    assert 0 <= offset < size, (offset, size)
    with open(path, "r+b") as fp:
        fp.seek(offset)
        b = fp.read(1)
        fp.seek(offset)
        fp.write(bytes([b[0] ^ 0xFF]))
    return offset


def corrupt_range(path: Path, offset: int, nbytes: int, xor: int = 0xFF) -> None:
    """XOR ``nbytes`` starting at ``offset`` (a torn page / partial rewrite)."""
    with open(path, "r+b") as fp:
        fp.seek(offset)
        raw = fp.read(nbytes)
        fp.seek(offset)
        fp.write(bytes(c ^ xor for c in raw))


def truncate_file(path: Path, keep: Optional[int] = None,
                  frac: float = 0.5) -> int:
    """Truncate to ``keep`` bytes (default: ``frac`` of the current size).
    Returns the new size."""
    path = Path(path)
    size = path.stat().st_size
    keep = int(size * frac) if keep is None else keep
    with open(path, "r+b") as fp:
        fp.truncate(keep)
    return keep


def tear_json(path: Path, keep_frac: float = 0.5) -> None:
    """Make a JSON file look torn mid-write: keep only a prefix, guaranteed
    to be unparseable (a valid-JSON prefix would defeat the point)."""
    path = Path(path)
    raw = path.read_bytes()
    keep = max(1, int(len(raw) * keep_frac))
    torn = raw[:keep]
    if not torn.rstrip().endswith((b"{", b",", b":")):
        torn += b'{"torn'        # force a parse error whatever the cut point
    path.write_bytes(torn)


def replica_file(store, tier: str, rel: str, idx: int = 0) -> Path:
    """The ``idx``-th existing replica file of ``tier:rel`` (placement
    order); raises if there is no such replica."""
    paths = store.replica_paths(tier, rel)
    return paths[idx]


class PreadFaults:
    """Inject ``OSError`` into a ``TieredStore``'s positional reads.

    ``match(path, offset, nbytes)`` selects the reads at risk; the first
    ``after`` matching reads succeed, every later match raises (at most
    ``times`` raises when given).  Usable as a context manager; ``fired``
    counts injected errors.

        with PreadFaults(store, lambda p, off, n: n > 4096):
            ...                      # every payload-sized read now fails
    """

    def __init__(self, store, match: Callable[[Path, int, int], bool], *,
                 error: Optional[Exception] = None, after: int = 0,
                 times: Optional[int] = None):
        self.store = store
        self.match = match
        self.error = error if error is not None else OSError("injected fault")
        self.after = after
        self.times = times
        self.fired = 0
        self._matched = 0
        # parallel restore pools call _pread concurrently: the after/times
        # bookkeeping must be atomic or the N-th-read semantics go flaky
        self._lock = threading.Lock()
        self._orig = None
        self._installed = None

    def install(self) -> "PreadFaults":
        assert self._installed is None, "already installed"
        # compose with whatever _pread is visible now — an instance-level
        # wrapper (counting stores) or the class method
        had_instance = "_pread" in self.store.__dict__
        self._orig = (self.store.__dict__["_pread"] if had_instance
                      else None)
        orig = self.store._pread        # bound: instance attr or class method
        self._had_instance = had_instance

        def faulty(path, offset, nbytes):
            if self.match(Path(path), offset, nbytes):
                with self._lock:
                    self._matched += 1
                    fire = self._matched > self.after and (
                        self.times is None or self.fired < self.times)
                    if fire:
                        self.fired += 1
                if fire:
                    raise self.error
            return orig(path, offset, nbytes)

        self._installed = faulty
        self.store._pread = faulty
        return self

    def uninstall(self) -> None:
        if getattr(self, "_installed", None) is None:
            return
        if self._had_instance:
            self.store._pread = self._orig
        else:
            self.store.__dict__.pop("_pread", None)
        self._installed = None
        self._orig = None

    def __enter__(self) -> "PreadFaults":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()


class ByteCountingStoreMixin:
    """Mix in over ``TieredStore`` (mixin first in the MRO): counts every
    byte actually fetched, keyed by tier, at both the ranged-read choke
    point (``_pread``) and whole-file ``get`` — the evidence for
    zero-shared-bytes placement assertions.  tier_roots-aware: the owning
    tier is resolved through ``_node_dirs``, not path prefixes."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.read_by_tier: dict = {}

    def _tier_of(self, path: Path) -> str:
        path = Path(path)
        for tier in self.tiers:
            for nd in self._node_dirs(tier):
                if nd in path.parents:
                    return tier
        return "?"

    def _count(self, path, n: int) -> None:
        t = self._tier_of(path)
        self.read_by_tier[t] = self.read_by_tier.get(t, 0) + n

    def _pread(self, path, offset, nbytes):
        data = super()._pread(path, offset, nbytes)
        self._count(path, len(data))
        return data

    def get(self, tier, rel):
        data = super().get(tier, rel)
        self.read_by_tier[tier] = self.read_by_tier.get(tier, 0) + len(data)
        return data

    def reset(self) -> None:
        self.read_by_tier = {}


def kill_self(exit_code: int = 85) -> None:
    """Die NOW — no atexit, no thread joins, no flushing — the closest thing
    to a node loss a test subprocess can do to itself."""
    os._exit(exit_code)
