"""Zero-copy streaming checkpoint I/O engine: v2 format, ranged restore,
CRC-once, replica copy fan-out, bounded buffering, v1 read-compat."""
import io
import tracemalloc
import zlib
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import TieredStore


def _tree(rng):
    return {
        "w": rng.standard_normal((64, 32)).astype(np.float32),
        "b": rng.standard_normal((256,)).astype(np.float32),
        "step": np.int32(7),
        "scalar": np.float64(2.5),
    }


class CountingStore(TieredStore):
    """Counts payload bytes actually fetched through the ranged-read choke
    point (`_pread`)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.bytes_read = 0

    def _pread(self, path, offset, nbytes):
        data = super()._pread(path, offset, nbytes)
        self.bytes_read += len(data)
        return data


# ---------------------------------------------------------------------------
# format v2 + v1 read-compat
# ---------------------------------------------------------------------------

def test_v2_roundtrip(rng):
    tree = _tree(rng)
    recs = SER.tree_to_records(tree)
    data = SER.write_shard_bytes_v2(recs, meta={"k": 2})
    assert data[:8] == SER.MAGIC2 and data[-8:] == SER.MAGIC2
    named, meta = SER.read_shard_bytes(data)
    assert meta == {"k": 2}
    out = SER.restore_tree(tree, named)
    for name, a in SER.flatten_with_names(tree):
        b = dict(SER.flatten_with_names(out))[name]
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_v1_files_read_through_new_reader(rng):
    """Bytes produced by the seed-era v1 writer parse through every new
    reader: whole-buffer, ranged header, and leaf-granular store read."""
    tree = _tree(rng)
    recs = SER.tree_to_records(tree)
    v1 = SER.write_shard_bytes(recs, meta={"v": 1})
    assert v1[:8] == SER.MAGIC
    named, meta = SER.read_shard_bytes(v1)
    assert meta == {"v": 1}
    assert np.array_equal(named["w"], tree["w"])

    # ranged header read on v1 normalizes offsets to absolute
    def read_at(off, n):
        return v1[off:off + n]
    header = SER.read_shard_header(read_at, len(v1))
    assert header["format"] == 1
    got, _ = SER.read_shard_leaves(read_at, len(v1), ["b"])
    assert np.array_equal(got["b"], tree["b"])


def test_v1_shard_payload_ending_in_v2_magic_still_parses():
    """A v1 shard whose LAST payload bytes coincidentally equal the v2
    trailer magic must not be misread by the tail-probe fast path — the
    leading magic disambiguates, and the v1 parse still succeeds."""
    payload = b"x" * 24 + SER.MAGIC2
    arr = np.frombuffer(payload, dtype=np.uint8).copy()
    data = SER.write_shard_bytes([("a", arr)])
    assert data[:8] == SER.MAGIC and data[-8:] == SER.MAGIC2   # the collision
    named, _ = SER.read_shard_bytes(data)
    assert named["a"].tobytes() == payload

    def read_at(off, n):
        return data[off:off + n]
    header = SER.read_shard_header(read_at, len(data))
    assert header["format"] == 1


def test_v1_checkpoint_restores_through_new_manager(tmp_path, rng):
    """A checkpoint written via the legacy v1 path (seed byte layout) restores
    through the new ranged-read manager."""
    store = TieredStore(tmp_path)
    m1 = CheckpointManager(store, CheckpointPolicy(shard_format=1))
    tree = _tree(rng)
    m1.save(3, tree)
    m1.commit(3)
    shard = next(tmp_path.rglob("shard_*.bin"))
    assert shard.read_bytes()[:8] == SER.MAGIC   # really v1 on disk
    m2 = CheckpointManager(store)                # default v2 reader/writer
    out, man = m2.restore(tree)
    assert man["step"] == 3
    assert np.array_equal(out["w"], tree["w"])


def test_ranged_read_equals_full_read(rng):
    tree = _tree(rng)
    data = SER.write_shard_bytes_v2(SER.tree_to_records(tree))

    def read_at(off, n):
        return data[off:off + n]

    full, _ = SER.read_shard_leaves(read_at, len(data), None)
    for name in full:
        one, _ = SER.read_shard_leaves(read_at, len(data), [name])
        assert set(one) == {name}
        assert np.array_equal(one[name], full[name])
        assert one[name].dtype == full[name].dtype


def test_ranged_read_detects_corruption(rng):
    tree = _tree(rng)
    data = bytearray(SER.write_shard_bytes_v2(SER.tree_to_records(tree)))

    def read_at(off, n):
        return bytes(data[off:off + n])

    header = SER.read_shard_header(read_at, len(data))
    t0 = header["tensors"][0]
    data[t0["offset"] + 2] ^= 0xFF           # corrupt the first leaf's payload
    with pytest.raises(SER.ChecksumError):
        SER.read_shard_leaves(read_at, len(data), [t0["path"]])
    # untouched leaves still read clean through ranged access
    other = header["tensors"][-1]["path"]
    got, _ = SER.read_shard_leaves(read_at, len(data), [other])
    assert other in got


# ---------------------------------------------------------------------------
# CRC exactly once per leaf on the save path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("incremental", [False, True])
def test_crc_computed_once_per_leaf(tmp_path, rng, monkeypatch, incremental):
    """Exactly one CRC pass per leaf per save: folded inside the streaming
    writer (plain mode) or pre-computed as the diff key and trusted by the
    writer (incremental mode) — never both."""
    store = TieredStore(tmp_path)
    m = CheckpointManager(store, CheckpointPolicy(
        replicas=2, incremental=incremental, keep_last=10))
    tree = _tree(rng)
    n_leaves = len(SER.flatten_with_names(tree))
    if incremental:
        m.save(1, tree)       # establish a prev manifest so save 2 diffs
        m.commit(1)
        tree = dict(tree)
        tree["w"] = tree["w"] + 1

    calls = {"crc32": 0}
    real_crc32 = zlib.crc32

    def counting_crc32(buf, start=0):
        calls["crc32"] += 1
        return real_crc32(buf, start)

    monkeypatch.setattr(SER.zlib, "crc32", counting_crc32)
    try:
        m.save(2, tree)
    finally:
        monkeypatch.undo()
    # every leaf is small (< one chunk), so any double-CRC — e.g. the writer
    # re-hashing what leaf_checksum already hashed — would show as > n_leaves
    assert calls["crc32"] == n_leaves


def test_writer_trusts_precomputed_crcs(rng):
    arr = rng.standard_normal((8, 8)).astype(np.float32)
    fake_crc = 0xDEADBEEF
    buf = io.BytesIO()
    footer = SER.write_shard_stream(buf, [("w", arr)], crcs={"w": fake_crc})
    assert footer["tensors"][0]["crc32"] == fake_crc


# ---------------------------------------------------------------------------
# replica fan-out: serialize once, OS-copy k-1 times, byte-identical
# ---------------------------------------------------------------------------

def test_replica_fanout_writes_once_and_is_byte_identical(tmp_path, rng):
    store = TieredStore(tmp_path)
    n_stream_calls = {"n": 0}
    tree = _tree(rng)
    recs = SER.tree_to_records(tree)

    def write_fn(fp):
        n_stream_calls["n"] += 1
        return SER.write_shard_stream(fp, recs)

    paths = store.put_stream("shared", "ck/s.bin", write_fn, replicas=3)
    assert n_stream_calls["n"] == 1          # payload serialized exactly once
    assert len(paths) == 3
    blobs = [(tmp_path / p).read_bytes() for p in paths]
    assert all(b == blobs[0] for b in blobs)
    # hardlink-free copies: corrupting one replica must not corrupt the rest
    inodes = {(tmp_path / p).stat().st_ino for p in paths}
    assert len(inodes) == 3


def test_put_fanout_byte_identical(tmp_path):
    store = TieredStore(tmp_path)
    paths = store.put("shared", "a/b.json", b"{\"x\": 1}", replicas=3)
    assert len(paths) == 3
    blobs = [(tmp_path / p).read_bytes() for p in paths]
    assert all(b == b"{\"x\": 1}" for b in blobs)


def test_stale_replica_missing_leaf_falls_back(tmp_path, rng):
    """A replica that parses fine but lacks a requested leaf (stale write) is
    treated like any damaged replica: fall back to the intact one."""
    store = TieredStore(tmp_path)
    recs = SER.tree_to_records(_tree(rng))
    paths = store.put_stream(
        "shared", "ck/s.bin", lambda fp: SER.write_shard_stream(fp, recs),
        replicas=2)
    stale = SER.write_shard_bytes_v2(recs[:1])       # valid shard, fewer leaves
    (tmp_path / paths[0]).write_bytes(stale)
    want = recs[-1][0]
    got, _ = store.read_shard_leaves("shared", "ck/s.bin", [want])
    assert np.array_equal(got[want], dict(recs)[want])


def test_get_range(tmp_path):
    store = TieredStore(tmp_path)
    store.put("shared", "f.bin", b"0123456789", replicas=2)
    assert store.get_range("shared", "f.bin", 3, 4) == b"3456"
    # a range past EOF is a truncated read, never silently-shorter data
    with pytest.raises(FileNotFoundError, match="short read"):
        store.get_range("shared", "f.bin", 8, 100)


def test_async_writer_bounds_inflight_tasks():
    import threading as th

    from repro.checkpoint.async_writer import AsyncWriter

    w = AsyncWriter(max_inflight=2)
    gate = th.Event()
    running = []

    def task():
        running.append(1)
        gate.wait(5)

    w.submit(task)
    w.submit(task)
    # third submit must block (2 unfinished tasks pinned) until one finishes
    t = th.Thread(target=lambda: w.submit(task), daemon=True)
    t.start()
    t.join(0.3)
    assert t.is_alive(), "submit exceeded the inflight bound"
    gate.set()
    t.join(5)
    assert not t.is_alive()
    w.close()
    assert len(running) == 3


def test_get_falls_back_on_oserror(tmp_path, monkeypatch):
    store = TieredStore(tmp_path)
    paths = store.put("shared", "f.bin", b"payload", replicas=2)
    bad = tmp_path / paths[0]
    real_read_bytes = Path.read_bytes

    def flaky_read_bytes(self):
        if self == bad:
            raise OSError("simulated torn replica")
        return real_read_bytes(self)

    monkeypatch.setattr(Path, "read_bytes", flaky_read_bytes)
    assert store.get("shared", "f.bin") == b"payload"


# ---------------------------------------------------------------------------
# ranged restore reads strictly fewer bytes than the full shard
# ---------------------------------------------------------------------------

def test_single_leaf_restore_reads_fewer_bytes(tmp_path, rng):
    store = CountingStore(tmp_path)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1))
    tree = _tree(rng)
    m.save(1, tree)
    m.commit(1)
    shard_rel = next(e["file"] for e in m.read_manifest(1)["leaves"])
    full_size = store.size("shared", shard_rel)

    store.bytes_read = 0
    one, _ = store.read_shard_leaves("shared", shard_rel, ["step"])
    assert int(one["step"]) == 7
    assert 0 < store.bytes_read < full_size


def test_incremental_restore_skips_stale_base_leaves(tmp_path, rng):
    """The MxN/incremental path: restoring a manifest whose entries point at
    an old base shard must not re-read the base wholesale — the superseded
    (stale) byte ranges in the base are never fetched."""
    store = CountingStore(tmp_path)
    m = CheckpointManager(store, CheckpointPolicy(incremental=True, keep_last=10, replicas=1))
    tree = _tree(rng)
    tree["big"] = rng.standard_normal((256, 1024)).astype(np.float32)  # 1 MB
    m.save(1, tree)
    m.commit(1)
    tree2 = dict(tree)
    tree2["big"] = tree["big"] + 1           # the BIG leaf changes
    m.save(2, tree2)
    man2 = m.commit(2)
    base_rel = next(e["file"] for e in man2["leaves"] if e.get("reused"))
    delta_rel = next(e["file"] for e in man2["leaves"] if not e.get("reused"))
    total = store.size("shared", base_rel) + store.size("shared", delta_rel)

    store.bytes_read = 0
    out, _ = m.restore(tree, step=2)
    assert np.array_equal(out["big"], tree2["big"])
    assert np.array_equal(out["w"], tree["w"])
    # the old reader fetched base+delta in full (~2 MB); the ranged reader
    # skips the stale 1 MB "big" payload inside the base shard
    assert store.bytes_read < 0.7 * total, (store.bytes_read, total)


# ---------------------------------------------------------------------------
# streaming save: peak extra buffering bounded by one chunk
# ---------------------------------------------------------------------------

def test_streaming_save_bounded_buffering(tmp_path, rng):
    payload_mb = 32
    arr = rng.standard_normal((payload_mb * 1024 * 1024 // 4,)).astype(np.float32)
    recs = [("big", arr)]

    class NullSink(io.RawIOBase):
        def writable(self):
            return True

        def write(self, b):
            return len(b)

    tracemalloc.start()
    tracemalloc.reset_peak()
    SER.write_shard_stream(NullSink(), recs)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # legacy path buffered ~2x the payload (tobytes + BytesIO); streaming
    # must stay under one chunk (+ slack for the footer/index objects)
    assert peak < SER.CHUNK_BYTES + (1 << 20), f"peak={peak}"


# ---------------------------------------------------------------------------
# elastic GC: retired steps written under a different worker count
# ---------------------------------------------------------------------------

def test_gc_cleans_parts_from_different_worker_count(tmp_path, rng):
    store = TieredStore(tmp_path)
    tree = _tree(rng)
    # step 1 written by THREE workers
    for w in range(3):
        mw = CheckpointManager(store, CheckpointPolicy(incremental=True, keep_last=2), worker_id=w,
                               num_workers=3)
        mw.save(1, tree)
    m3 = CheckpointManager(store, CheckpointPolicy(incremental=True, keep_last=2), worker_id=0,
                           num_workers=3)
    m3.commit(1, num_workers=3)
    # elastic restart: ONE worker continues incrementally, reusing step-1 files
    m1 = CheckpointManager(store, CheckpointPolicy(incremental=True, keep_last=2), worker_id=0,
                           num_workers=1)
    m1.restore(tree)
    for s in (2, 3, 4):
        t = dict(tree)
        t["step"] = np.int32(s)
        m1.save(s, t)
        man = m1.commit(s)
    assert any(e.get("reused") for e in man["leaves"])   # still referencing base
    assert m1.steps() == [3, 4]
    # step 1 was retired while referenced: its manifest AND all 3 wpart files
    # (written under num_workers=3) must be gone, shard data kept
    sdir = "ckpt/step_0000000001"
    leftovers = [r for r in store.list_prefix("shared", sdir)
                 if Path(r).name.startswith(("wpart_", "MANIFEST"))]
    assert leftovers == [], leftovers
    assert any(Path(r).name.startswith("shard_")
               for r in store.list_prefix("shared", sdir))
    # and the referenced base leaves still restore
    out, _ = m1.restore(tree, step=4)
    assert np.array_equal(out["w"], tree["w"])


# ---------------------------------------------------------------------------
# async writer pool still serializes correctly under overlap
# ---------------------------------------------------------------------------

def test_async_pool_save_commit_restore(tmp_path, rng):
    store = TieredStore(tmp_path)
    m = CheckpointManager(store, CheckpointPolicy(mode="async", keep_last=10))
    tree = _tree(rng)
    for s in (1, 2, 3):
        t = dict(tree)
        t["step"] = np.int32(s)
        m.save(s, t)
        m.commit(s)
    out, man = m.restore(tree)
    assert man["step"] == 3
    assert int(out["step"]) == 3
    m.close()
