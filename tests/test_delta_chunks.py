"""Content-addressed chunk plane (shard v3): delta saves, dedup chunk store,
refcount-aware GC, stale-cache/peer delta fetch, and the satellite hardening
(auto_workers env parsing, TieredStore close idempotency, bench-artifact key
pruning)."""
import json
import logging
import os
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import (CheckpointManager, CheckpointPolicy, is_chunked_manifest,
                                      manifest_payload_map)
from repro.checkpoint.restore_engine import (ENV_RESTORE_WORKERS,
                                             ParallelRestorer, auto_workers)
from repro.checkpoint.store import (TieredStore, chunk_refcounts,
                                    manifest_chunk_hashes,
                                    node_local_tier_roots)
from repro.sched.cache_registry import CacheRegistry

ROOT = Path(__file__).resolve().parents[1]

CHUNK = 1 << 16          # small chunks so a few-MB tree spans many of them


def _tree(rng, n_leaves=4, elems=70_000):
    return {f"l{i:02d}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}


def _mutate(tree, names, elems=100):
    out = dict(tree)
    for n in names:
        a = out[n].copy()
        a[:elems] += 1.0
        out[n] = a
    return out


def _assert_trees_equal(got, want):
    for k, a in want.items():
        b = got[k]
        assert np.asarray(b).dtype == np.asarray(a).dtype, k
        assert np.array_equal(np.asarray(b), np.asarray(a)), k


# ---------------------------------------------------------------------------
# serialization: chunking + v3 index format
# ---------------------------------------------------------------------------

def test_chunk_leaf_single_pass_consistency(rng):
    arr = rng.standard_normal(50_000).astype(np.float32)
    entries, views, leaf_crc = SER.chunk_leaf(arr, CHUNK)
    assert leaf_crc == SER.leaf_checksum(arr)
    assert sum(e["nbytes"] for e in entries) == arr.nbytes
    assert [v.nbytes for v in views] == [e["nbytes"] for e in entries]
    # content addressing: identical bytes -> identical hash, a flipped byte
    # -> a different hash for exactly that chunk
    entries2, _, _ = SER.chunk_leaf(arr.copy(), CHUNK)
    assert [e["hash"] for e in entries] == [e["hash"] for e in entries2]
    mut = arr.copy()
    mut[0] += 1.0
    entries3, _, _ = SER.chunk_leaf(mut, CHUNK)
    assert entries3[0]["hash"] != entries[0]["hash"]
    assert [e["hash"] for e in entries3[1:]] == [e["hash"] for e in entries[1:]]


def test_v3_index_roundtrip_and_assembly(rng):
    tree = _tree(rng, n_leaves=2)
    chunk_store = {}
    tensors = []
    for name, arr in SER.tree_to_records(tree):
        entries, views, leaf_crc = SER.chunk_leaf(arr, CHUNK)
        for e, v in zip(entries, views):
            chunk_store[e["hash"]] = bytes(v)
        tensors.append({"path": name, "dtype": str(arr.dtype),
                        "shape": list(arr.shape), "nbytes": arr.nbytes,
                        "crc32": leaf_crc, "chunks": entries})
    data = SER.write_chunk_index_bytes(tensors, meta={"step": 9},
                                       chunk_bytes=CHUNK)
    assert data[:8] == SER.MAGIC3 and data[-8:] == SER.MAGIC3

    def read_at(off, n):
        return data[off:off + n]

    header = SER.read_shard_header(read_at, len(data))
    assert header["format"] == 3 and header["chunk_bytes"] == CHUNK
    named, meta = SER.read_chunked_leaves(
        header, lambda c: chunk_store[c["hash"]])
    assert meta == {"step": 9}
    _assert_trees_equal(named, tree)

    # a torn chunk is detected before any bytes are served
    bad = dict(chunk_store)
    h = tensors[0]["chunks"][0]["hash"]
    bad[h] = b"\x00" * len(bad[h])
    with pytest.raises(SER.ChecksumError):
        SER.read_chunked_leaves(header, lambda c: bad[c["hash"]])


def test_v3_index_rejected_by_payload_readers(rng):
    """A v3 index holds no payload: the ranged/whole-buffer readers must
    refuse it loudly instead of misparsing."""
    data = SER.write_chunk_index_bytes([], meta={})
    with pytest.raises(ValueError, match="chunk plane"):
        SER.read_shard_bytes(data)


# ---------------------------------------------------------------------------
# manager: delta save / chain / restore
# ---------------------------------------------------------------------------

def test_delta_save_writes_only_changed_chunks(rng, tmp_path):
    tree = _tree(rng)
    full_store = TieredStore(tmp_path / "full", seed=0)
    CheckpointManager(full_store, CheckpointPolicy(replicas=1)).save(1, tree)
    full_bytes = full_store.size(
        "shared", "ckpt/step_0000000001/shard_w00000.bin")

    store = TieredStore(tmp_path / "delta", seed=0)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    p1 = m.save(1, tree)
    man1 = m.commit(1)
    assert man1["manifest_version"] == 2
    assert man1["delta"] == {"baseline": 1, "parent": None, "chain": [1],
                             "chunk_bytes": CHUNK}
    assert p1["delta"]["chunks_written"] == p1["delta"]["chunks_total"]

    # <10% of chunks mutated -> far under 20% of the full-shard bytes
    tree2 = _mutate(tree, ["l00"])
    p2 = m.save(2, tree2)
    man2 = m.commit(2)
    assert man2["delta"]["chain"] == [1, 2] and man2["delta"]["parent"] == 1
    written = p2["delta"]["bytes_written"]
    assert 0 < written < 0.2 * full_bytes
    assert p2["delta"]["chunks_written"] <= 2   # one touched chunk (+ slack)

    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree2)
    m.close()


def test_delta_restore_byte_identical_to_full_shard_restore(rng, tmp_path):
    """The acceptance contract: whatever the chunk plane does internally, a
    delta restore returns exactly the bytes a full (non-delta) v2 restore of
    the same tree returns."""
    tree = _tree(rng)
    tree2 = _mutate(tree, ["l01", "l03"])
    d_store = TieredStore(tmp_path / "d", seed=0)
    f_store = TieredStore(tmp_path / "f", seed=0)
    dm = CheckpointManager(d_store, CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    fm = CheckpointManager(f_store, CheckpointPolicy(replicas=1))
    for step, t in ((1, tree), (2, tree2)):
        dm.save(step, t)
        dm.commit(step)
        fm.save(step, t)
        fm.commit(step)
    got_d, man_d = CheckpointManager(d_store, CheckpointPolicy(replicas=1)).restore(tree)
    got_f, man_f = CheckpointManager(f_store, CheckpointPolicy(replicas=1)).restore(tree)
    assert man_d["step"] == man_f["step"] == 2
    for k in tree:
        a, b = np.asarray(got_d[k]), np.asarray(got_f[k])
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), k
    dm.close()
    fm.close()


def test_delta_chain_rebaselines_at_limit(rng, tmp_path):
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK, rebase_every=3,
        keep_last=10))
    tree = _tree(rng, n_leaves=2)
    chains = []
    for step in range(1, 6):
        tree = _mutate(tree, ["l00"])
        m.save(step, tree)
        chains.append(m.commit(step)["delta"]["chain"])
    assert chains == [[1], [1, 2], [1, 2, 3], [4], [4, 5]]
    m.close()


def test_delta_worker_baseline_tracks_committed_frontier(rng, tmp_path):
    """A distributed worker saves but never commits (the coordinator does):
    its delta diff must chase the latest COMMITTED manifest, not stay pinned
    at whatever it last restored — else per-step deltas grow with total
    drift and can reference retired chunks."""
    store = TieredStore(tmp_path, seed=0)
    worker = CheckpointManager(store, CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    committer = CheckpointManager(store,
                                  CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK,
                                                   keep_last=2))
    tree = _tree(rng)
    worker.save(1, tree)
    committer.commit(1)
    for step, leaf in ((2, "l01"), (3, "l02"), (4, "l03")):
        tree = _mutate(tree, [leaf])
        p = worker.save(step, tree)
        committer.commit(step)
        # one mutated chunk per step — against the frontier, not step 1
        assert p["delta"]["parent_step"] == step - 1, p["delta"]
        assert p["delta"]["chunks_new"] == 1, p["delta"]
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree)
    worker.close()
    committer.close()


def test_v1_v2_and_nondelta_saves_still_restore(rng, tmp_path):
    """Flipping delta on for new steps must not break reading older
    full-shard checkpoints (v1 or v2) from the same store."""
    tree = _tree(rng, n_leaves=2)
    store = TieredStore(tmp_path, seed=0)
    CheckpointManager(store, CheckpointPolicy(replicas=1, shard_format=1)).save(1, tree)
    CheckpointManager(store, CheckpointPolicy(replicas=1, shard_format=1)).commit(1)
    got1, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree, step=1)
    _assert_trees_equal(got1, tree)

    tree2 = _mutate(tree, ["l00"])
    m = CheckpointManager(store,
                          CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK, keep_last=10))
    m.save(2, tree2)
    man2 = m.commit(2)
    assert is_chunked_manifest(man2)
    got1, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree, step=1)
    _assert_trees_equal(got1, tree)
    got2, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree, step=2)
    _assert_trees_equal(got2, tree2)
    m.close()


def test_multi_worker_delta_dedups_across_workers(rng, tmp_path):
    """Two workers of one step share the chunk namespace: a chunk two leaves
    happen to share is written once (put_chunk dedup)."""
    base = rng.standard_normal(40_000).astype(np.float32)
    tree = {"a": base, "b": base.copy(), "c": rng.standard_normal(
        40_000).astype(np.float32)}
    store = TieredStore(tmp_path, seed=0)
    for w in range(2):
        CheckpointManager(store,
                          CheckpointPolicy(replicas=1, delta=True,
                                           chunk_bytes=CHUNK),
                          worker_id=w, num_workers=2).save(1, tree)
    man = CheckpointManager(store, CheckpointPolicy(replicas=1, delta=True),
                            num_workers=2).commit(1, num_workers=2)
    hashes = manifest_chunk_hashes(man)
    # identical leaves -> identical chunk lists -> dedup'd on disk
    assert len(store.chunk_digests("shared", "ckpt")) == len(hashes)
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree)


def test_delta_roundtrips_zero_size_and_scalar_leaves(rng, tmp_path):
    """A zero-byte leaf has an EMPTY chunk list — it must still round-trip
    through the chunk plane (shape, dtype and all), not silently vanish
    from the restore."""
    tree = {
        "empty": np.zeros((0,), dtype=np.float32),
        "empty2d": np.zeros((0, 4), dtype=np.int64),
        "scalar": np.int32(7),
        "normal": rng.standard_normal(10_000).astype(np.float32),
    }
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    m.save(1, tree)
    man = m.commit(1)
    assert is_chunked_manifest(man)
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    for k, a in tree.items():
        b = got[k]
        assert np.asarray(b).dtype == np.asarray(a).dtype, k
        assert np.asarray(b).shape == np.asarray(a).shape, k
        assert np.array_equal(np.asarray(b), np.asarray(a)), k
    m.close()


# ---------------------------------------------------------------------------
# GC: refcount-aware chunk reaping
# ---------------------------------------------------------------------------

def test_gc_reaps_only_dead_chunks(rng, tmp_path):
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store,
                          CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK, keep_last=1))
    tree = _tree(rng)
    m.save(1, tree)
    man1 = m.commit(1)
    h1 = manifest_chunk_hashes(man1)
    tree2 = _mutate(tree, ["l00"])
    m.save(2, tree2)
    man2 = m.commit(2)       # commit() gc's: step 1 manifest retired
    h2 = manifest_chunk_hashes(man2)
    present = store.chunk_digests("shared", "ckpt")
    assert present == h2                     # live chunks exactly
    assert h1 - h2                           # something WAS reaped
    assert chunk_refcounts([man2]) == {h: 1 for h in h2}
    got, _ = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    _assert_trees_equal(got, tree2)
    m.close()


def test_gc_never_reaps_chunks_of_uncommitted_save(rng, tmp_path):
    """The file plane never touches uncommitted step dirs; the chunk plane
    must match: chunks already written for a step whose manifest is not yet
    committed survive a concurrent gc, and the commit then restores."""
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store,
                          CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK, keep_last=1))
    tree = _tree(rng, n_leaves=2)
    m.save(1, tree)
    m.commit(1)
    tree2 = _mutate(tree, ["l00"])
    m.save(2, tree2)
    m.commit(2)
    # a worker has saved step 3 (new chunks on disk) but NOT committed yet
    tree3 = _mutate(tree2, ["l01"], elems=300)
    w = CheckpointManager(store, CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    w.save(3, tree3)
    m.gc()                                   # interleaved gc
    man3 = w.commit(3)
    got, man = CheckpointManager(store, CheckpointPolicy(replicas=1)).restore(tree)
    assert man["step"] == man3["step"] == 3
    _assert_trees_equal(got, tree3)
    m.close()
    w.close()


def test_gc_race_property_save_gc_restore_peer_fetch(rng, tmp_path):
    """Property-style sweep (satellite): interleave save -> gc -> restore ->
    peer fetch over a delta chain with aggressive keep_last and assert, at
    every point, that (a) no chunk referenced by a kept manifest is ever
    reaped and (b) restored bytes are byte-identical to a full-shard restore
    of the same state."""
    for seed in range(4):
        prng = np.random.default_rng(seed)
        root = tmp_path / f"seed{seed}"

        def store_for(node):
            return TieredStore(root / "ck", seed=0,
                               tier_roots=node_local_tier_roots(
                                   root / "nodes" / node))

        writer = CheckpointManager(
            store_for("writer"),
            CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK,
                             keep_last=2, rebase_every=3, promote="eager"),
            node="writer")
        full_store = TieredStore(root / "full", seed=0)
        full = CheckpointManager(full_store, CheckpointPolicy(replicas=1, keep_last=2))
        tree = _tree(prng, n_leaves=3)
        for step in range(1, 7):
            touched = [f"l{i:02d}" for i in range(3)
                       if prng.random() < 0.5] or ["l00"]
            tree = _mutate(tree, touched, elems=int(prng.integers(1, 200)))
            writer.save(step, tree)
            man = writer.commit(step)        # gc interleaves here
            full.save(step, tree)
            full.commit(step)
            # (a) every chunk referenced by ANY kept manifest survived gc
            kept = [writer.read_manifest(s) for s in writer.steps()]
            live = set(chunk_refcounts(kept))
            present = writer.store.chunk_digests("shared", "ckpt")
            assert live <= present, f"live chunk reaped at step {step}"
            # (b) chunked restore == full-shard restore, byte for byte
            got_d, _ = CheckpointManager(
                store_for("writer"),
                CheckpointPolicy(replicas=1)).restore(tree)
            got_f, _ = CheckpointManager(
                full_store, CheckpointPolicy(replicas=1)).restore(tree)
            for k in tree:
                assert (np.asarray(got_d[k]).tobytes()
                        == np.asarray(got_f[k]).tobytes()), (seed, step, k)
            # peer fetch from the writer's warm cache, every other step
            if step % 2 == 0:
                writer.wait_promotions()
                cold = CheckpointManager(store_for(f"cold{step}"), CheckpointPolicy(replicas=1),
                                         node=f"cold{step}",
                                         peer_roots={"writer": root / "nodes" / "writer"})
                got_p, man_p = cold.restore(tree)
                assert man_p["step"] == man["step"]
                for k in tree:
                    assert (np.asarray(got_p[k]).tobytes()
                            == np.asarray(got_f[k]).tobytes()), (seed, step, k)
                cold.close()
        writer.close()
        full.close()


# ---------------------------------------------------------------------------
# stale-cache + peer delta fetch
# ---------------------------------------------------------------------------

def test_warm_but_stale_node_fetches_only_delta(rng, tmp_path):
    """The tentpole's acceptance scenario: a node whose promoted cache is one
    step behind restores the newer step reading ~delta bytes from the shared
    tier and everything else from its own stale local cache."""
    def store_for(node):
        return TieredStore(tmp_path / "ck", seed=0,
                           tier_roots=node_local_tier_roots(
                               tmp_path / "nodes" / node))

    tree = _tree(rng)
    w = CheckpointManager(store_for("writer"),
                          CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    w.save(1, tree)
    w.commit(1)
    # nodeB warms at step 1
    b = CheckpointManager(store_for("nodeB"), CheckpointPolicy(replicas=1, promote="on_restore"),
                          node="nodeB")
    b.restore(tree)
    b.wait_promotions()
    b.close()
    # frontier moves one small delta ahead
    tree2 = _mutate(tree, ["l00"])
    p = w.save(2, tree2)
    w.commit(2)
    w.close()
    delta_bytes = p["delta"]["bytes_written"]
    total_bytes = sum(a.nbytes for a in tree.values())
    assert delta_bytes < 0.2 * total_bytes

    b2 = CheckpointManager(store_for("nodeB"), CheckpointPolicy(replicas=1, promote="on_restore"),
                           node="nodeB")
    got, man = b2.restore(tree)
    st = b2.last_restore_stats
    _assert_trees_equal(got, tree2)
    assert man["step"] == 2 and st["mode"] == "chunked"
    by_tier = st["bytes_by_tier"]
    assert by_tier.get("shared", 0) <= delta_bytes
    assert by_tier.get("local", 0) >= total_bytes - delta_bytes
    b2.close()


def test_stale_peer_serves_delta_chunks(rng, tmp_path):
    """A cold node with NO local cache sources unchanged chunks from a
    stale peer (cached step N) and only the delta from the shared tier when
    restoring step N+1 — stale peers are useless to the shard fabric but
    first-class chunk sources."""
    def store_for(node):
        return TieredStore(tmp_path / "ck", seed=0,
                           tier_roots=node_local_tier_roots(
                               tmp_path / "nodes" / node))

    tree = _tree(rng)
    w = CheckpointManager(store_for("writer"),
                          CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK,
                                           promote="eager"), node="writer")
    w.save(1, tree)
    w.commit(1)
    w.wait_promotions()          # writer's cache warm at step 1
    w.close()
    # a DIFFERENT manager (no promotion) commits step 2, so the writer's
    # cache goes stale at step 1
    tree2 = _mutate(tree, ["l00"])
    w2 = CheckpointManager(store_for("writer2"),
                           CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    p = w2.save(2, tree2)
    w2.commit(2)
    w2.close()
    delta_bytes = p["delta"]["bytes_written"]

    cold = CheckpointManager(store_for("cold"), CheckpointPolicy(replicas=1), node="cold",
                             peer_roots={"writer": tmp_path / "nodes" / "writer"})
    got, man = cold.restore(tree)
    st = cold.last_restore_stats
    _assert_trees_equal(got, tree2)
    assert man["step"] == 2 and st.get("peer")
    by_tier = st["bytes_by_tier"]
    assert by_tier.get("shared", 0) <= delta_bytes
    assert by_tier.get("peer:writer", 0) > 0
    cold.close()


def test_stale_peer_sources_ordered_by_lag_and_bounded(tmp_path):
    """_peer_sources buckets exact/stale in one marker sweep, orders stale
    peers nearest-cached-step-first (largest expected chunk overlap), and
    drops peers staler than STALE_PEER_MAX_LAG."""
    from repro.checkpoint.manager import STALE_PEER_MAX_LAG

    def write_marker(node, step):
        p = (tmp_path / "nodes" / node / "local" / "node0" / "ckpt"
             / "PROMOTED.json")
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps({"step": step, "files": []}))

    target = STALE_PEER_MAX_LAG + 20
    write_marker("far", target - 4)
    write_marker("near", target - 1)
    write_marker("exact", target)
    write_marker("ancient", target - STALE_PEER_MAX_LAG - 5)
    m = CheckpointManager(TieredStore(tmp_path / "ck", seed=0), CheckpointPolicy(replicas=1),
                          node="me",
                          peer_roots={n: tmp_path / "nodes" / n
                    for n in ("far", "near", "exact", "ancient")})
    exact, stale = m._peer_sources(target)
    assert exact == ["peer:exact"]
    assert stale == ["peer:near", "peer:far"]    # nearest first, ancient out
    m.close()


def test_registry_near_peers_and_chunk_inventory(tmp_path):
    reg = CacheRegistry(tmp_path / "reg")
    reg.publish("n1", step=5, files=["ckpt/chunks/ab/abcd"],
                local_root="/x", baseline_step=3, chunk_count=1)
    reg.publish("n2", step=7, files=[], local_root="/y")
    reg.publish("n3", step=4, files=[], local_root="/z")
    e = reg.entries()["n1"]
    assert e["baseline_step"] == 3 and e["chunk_count"] == 1
    assert sorted(reg.warm_peers(5)) == ["n1"]
    near = reg.near_peers(5)
    assert list(near) == ["n3", "n2"]        # nearest cached step first
    assert sorted(reg.near_peers(5, exclude=("n3",))) == ["n2"]
    assert sorted(reg.near_peers(5, max_lag=1)) == ["n3"]


def test_promoted_cache_validates_chunked_manifest(rng, tmp_path):
    """validate_promoted_cache / cache_inventory understand chunk-based
    manifests: warm after an eager delta promotion, stale after the next
    commit."""
    store = TieredStore(tmp_path / "ck", seed=0,
                        tier_roots=node_local_tier_roots(tmp_path / "node"))
    m = CheckpointManager(store,
                          CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK,
                                           promote="eager"), node="n0")
    tree = _tree(rng, n_leaves=2)
    man = None
    m.save(1, tree)
    man = m.commit(1)
    m.wait_promotions()
    inv = m.cache_inventory()
    assert inv["valid"] and inv["step"] == 1
    assert inv["files"] == len(manifest_payload_map(man, "ckpt"))
    # a newer commit (elsewhere) makes the inventory stale, not broken
    tree2 = _mutate(tree, ["l00"])
    w2 = CheckpointManager(TieredStore(tmp_path / "ck", seed=0),
                           CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    w2.save(2, tree2)
    w2.commit(2)
    w2.close()
    inv2 = m.cache_inventory()
    assert not inv2["valid"] and "stale" in inv2["reason"]
    m.close()


# ---------------------------------------------------------------------------
# satellites: auto_workers env hardening, store close/fd-cache, bench pruning
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", ["not-a-number", "-3", "0", "2.5"])
def test_auto_workers_invalid_env_falls_back_with_warning(
        monkeypatch, caplog, bad):
    monkeypatch.setenv(ENV_RESTORE_WORKERS, bad)
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.restore_engine"):
        n = auto_workers(cap=4)
    assert 1 <= n <= 4                       # auto sizing, never ValueError
    assert any(ENV_RESTORE_WORKERS in r.message for r in caplog.records)


def test_auto_workers_valid_env_still_wins(monkeypatch):
    monkeypatch.setenv(ENV_RESTORE_WORKERS, "3")
    assert auto_workers(cap=1) == 3


def test_store_close_is_idempotent_and_shutdown_safe(rng, tmp_path):
    store = TieredStore(tmp_path, seed=0)
    store.put("local", "a/f.bin", b"x" * 64)
    p = store.replica_paths("local", "a/f.bin")[0]
    assert store._pread(p, 0, 4) == b"xxxx"
    assert store._fds                        # descriptor cached
    store.close()
    assert not store._fds
    store.close()                            # second close: no-op, no raise
    # interpreter-teardown simulation: the close syscall itself is gone
    store._pread(p, 0, 4)
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(TieredStore, "_OS_CLOSE",
                   staticmethod(lambda fd: (_ for _ in ()).throw(TypeError())))
        store.close()                        # swallowed, not raised
    assert not store._fds
    store.close()
    del store                                # __del__ after close: silent


def test_fd_cache_releases_entry_on_pread_exception(tmp_path, monkeypatch):
    """The satellite contract: an exception INSIDE the positional read must
    release the cached descriptor's refcount, or eviction/invalidation would
    leak the fd forever."""
    if not hasattr(os, "pread"):
        pytest.skip("no os.pread on this platform")
    store = TieredStore(tmp_path, seed=0)
    store.put("local", "a/f.bin", b"y" * 128)
    p = store.replica_paths("local", "a/f.bin")[0]
    store._pread(p, 0, 8)                    # populate the cache

    def boom(fd, n, off):
        raise OSError("injected pread failure")

    monkeypatch.setattr(os, "pread", boom)
    with pytest.raises(OSError, match="injected"):
        store._pread(p, 0, 8)
    ent = store._fds[Path(p)]
    assert ent.refs == 0                     # released on the exception path
    monkeypatch.undo()
    assert store._pread(p, 0, 8) == b"y" * 8   # cache still serviceable
    store._fd_invalidate(Path(p))
    assert Path(p) not in store._fds         # and still evictable
    store.close()


def test_bench_artifact_prunes_stale_keys(tmp_path):
    if str(ROOT) not in sys.path:
        sys.path.insert(0, str(ROOT))
    from benchmarks import run as bench_run

    art = tmp_path / "BENCH.json"
    art.write_text(json.dumps({"delta_save": {}, "zombie_row": 1,
                               "run_meta": {}}))
    pruned = bench_run.prune_bench_ckpt_io(
        {"delta_save", "run_meta"}, path=art)
    assert pruned == ["zombie_row"]
    assert sorted(json.loads(art.read_text())) == ["delta_save", "run_meta"]
    # declared keys cover everything bench_delta merges
    from benchmarks import bench_delta
    assert set(bench_delta.BENCH_KEYS) == {"delta_save", "delta_save_overlap",
                                           "delta_peer_fetch",
                                           "delta_save_device",
                                           "delta_predump_iterative"}
    # and the io-plane row is declared so the pruner never reaps it
    from benchmarks import bench_cr_overhead
    assert "restore_engine_io" in bench_cr_overhead.BENCH_KEYS


# ---------------------------------------------------------------------------
# engine-level: source dedup + ordered resolution
# ---------------------------------------------------------------------------

def test_restore_chunked_dedups_sources_and_chunk_refs(rng, tmp_path):
    """Duplicate source tiers collapse; a chunk referenced twice (identical
    leaves) is fetched once."""
    base = rng.standard_normal(30_000).astype(np.float32)
    tree = {"a": base, "b": base.copy()}
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK))
    m.save(1, tree)
    man = m.commit(1)
    eng = ParallelRestorer(store)
    named, st = eng.restore_chunked(["shared", "shared"], man["leaves"],
                                    prefix="ckpt")
    _assert_trees_equal(named, tree)
    assert st.sources == ["shared"]          # dedup'd, order preserved
    assert st.chunk_refs == 2 * st.chunks    # two leaves share every chunk
    assert st.bytes_read == sum(a.nbytes for a in tree.values()) // 2
    m.close()
