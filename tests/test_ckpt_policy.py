"""CheckpointPolicy: validation, the legacy-kwarg deprecation shim, and the
unified restore entry (deprecated aliases + one stats schema for every path).

The tier-1 run treats the shim's DeprecationWarnings as ERRORS (pyproject
``filterwarnings``); the shim tests below opt in via ``pytest.warns``, which
is exactly the contract: new code never sees the warning, code exercising the
old surface must acknowledge it.
"""
import dataclasses

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.policy import PROMOTE_POLICIES
from repro.checkpoint.store import TieredStore
from repro.checkpoint import serialization as SER

CHUNK = 1 << 16


def _tree(rng, n_leaves=4, elems=50_000):
    return {f"l{i}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}


def _assert_trees_equal(got, want):
    flat_g = dict(SER.flatten_with_names(got))
    flat_w = dict(SER.flatten_with_names(want))
    assert set(flat_g) == set(flat_w)
    for k in flat_w:
        np.testing.assert_array_equal(flat_g[k], flat_w[k])


# ---------------------------------------------------------------------------
# validation: an invalid combination fails at construction, with a message
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,msg", [
    ({"mode": "turbo"}, "mode must be"),
    ({"shard_format": 9}, "shard_format must be"),
    ({"promote": "always"}, "promote must be"),
    ({"delta": True, "incremental": True}, "exclusive"),
    ({"rebase_every": 0}, "rebase_every"),
    ({"promote": "eager", "promote_tier": "shared"}, "must differ"),
    ({"delta": True, "chunk_bytes": 6}, "multiple of 4"),
    ({"delta": True, "chunk_bytes": 0}, "multiple of 4"),
])
def test_policy_validation_errors(kw, msg):
    with pytest.raises(ValueError, match=msg):
        CheckpointPolicy(**kw)


def test_policy_unaligned_chunk_bytes_ok_without_delta():
    # the word-stream constraint is the delta plane's; a non-delta manager
    # never fingerprints, so the same value must NOT fail there
    CheckpointPolicy(chunk_bytes=6)


def test_policy_is_frozen_and_promote_policies_exported():
    pol = CheckpointPolicy()
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.tier = "local"  # type: ignore[misc]
    assert pol.promote in PROMOTE_POLICIES
    assert set(CheckpointPolicy.field_names()) >= {
        "tier", "replicas", "prefix", "mode", "shard_format", "incremental",
        "delta", "chunk_bytes", "rebase_every", "fingerprint", "hash_workers",
        "keep_last", "restore_workers", "promote", "promote_tier"}


# ---------------------------------------------------------------------------
# deprecation shim: old flat kwargs behave exactly like the policy object
# ---------------------------------------------------------------------------

def test_legacy_kwargs_equal_policy_object(tmp_path, rng):
    tree = _tree(rng)
    with pytest.warns(DeprecationWarning, match="CheckpointPolicy"):
        old = CheckpointManager(TieredStore(tmp_path / "a", seed=0),
                                replicas=1, delta=True, chunk_bytes=CHUNK,
                                keep_last=5)
    new = CheckpointManager(
        TieredStore(tmp_path / "b", seed=0),
        CheckpointPolicy(replicas=1, delta=True, chunk_bytes=CHUNK,
                         keep_last=5))
    # the shim builds the SAME policy value...
    assert old.policy == new.policy
    for f in CheckpointPolicy.field_names():
        if f == "chunk_bytes":
            continue            # manager resolves None -> DELTA_CHUNK_BYTES
        assert getattr(old, f) == getattr(new, f), f
    # ...and the same behavior: identical manifests for identical input
    for m in (old, new):
        m.save(1, tree)
        man = m.commit(1)
        assert man["manifest_version"] == 2         # chunked (delta) plane
        out, _ = m.restore(tree)
        _assert_trees_equal(out, tree)
        m.close()


def test_legacy_kwargs_plus_policy_is_an_error(tmp_path):
    store = TieredStore(tmp_path, seed=0)
    with pytest.raises(TypeError, match="not both"):
        CheckpointManager(store, CheckpointPolicy(), replicas=1)


def test_unknown_kwarg_is_an_error_not_a_warning(tmp_path):
    store = TieredStore(tmp_path, seed=0)
    with pytest.raises(TypeError, match="unknown"):
        CheckpointManager(store, replicaz=1)


# ---------------------------------------------------------------------------
# unified restore: one entry point, one stats schema, deprecated aliases
# ---------------------------------------------------------------------------

# every restore path must populate last_restore_stats with AT LEAST these
STAT_KEYS = {"mode", "tier", "workers", "files", "bytes_read", "bytes_by_tier",
             "replica_fallbacks", "chunks", "chunk_refs", "sources",
             "promoted", "peer", "peer_tiers", "delta", "step",
             "manifest_version"}


def _committed(tmp_path, rng, **pol):
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, **pol))
    m.save(1, tree)
    m.commit(1)
    return store, tree, m


@pytest.mark.parametrize("pol", [
    {},                                            # v2 file plane, serial
    {"restore_workers": 4},                        # v2 file plane, parallel
    {"delta": True, "chunk_bytes": CHUNK},         # v3 chunk plane
])
def test_restore_stats_schema_is_uniform(tmp_path, rng, pol):
    _, tree, m = _committed(tmp_path, rng, **pol)
    out, man = m.restore(tree)
    _assert_trees_equal(out, tree)
    stats = m.last_restore_stats
    assert STAT_KEYS <= set(stats), STAT_KEYS - set(stats)
    assert stats["step"] == man["step"] == 1
    assert isinstance(stats["sources"], list) and stats["sources"]
    assert stats["manifest_version"] == man.get("manifest_version", 1)
    assert stats["delta"] == bool(pol.get("delta"))
    m.close()


def test_restore_explicit_sources(tmp_path, rng):
    _, tree, m = _committed(tmp_path, rng, delta=True, chunk_bytes=CHUNK)
    out, _ = m.restore(tree, sources="shared")      # string = one source
    _assert_trees_equal(out, tree)
    assert m.last_restore_stats["sources"] == ["shared"]
    out, _ = m.restore(tree, sources=["shared"])    # list form, same thing
    _assert_trees_equal(out, tree)
    with pytest.raises(ValueError):
        m.restore(tree, sources=[])
    m.close()


def test_deprecated_restore_aliases_still_work(tmp_path, rng):
    _, tree, m = _committed(tmp_path, rng, delta=True, chunk_bytes=CHUNK)
    want, want_man = m.restore(tree)
    with pytest.warns(DeprecationWarning, match="unified restore"):
        out, man = m.restore_chunked(tree)
    _assert_trees_equal(out, want)
    assert man["step"] == want_man["step"]
    with pytest.warns(DeprecationWarning, match="unified restore"):
        out2, man2 = m.restore_from_peers(tree)
    _assert_trees_equal(out2, want)
    assert man2["step"] == want_man["step"]
    m.close()
