"""Elastic (MxN) restart: a checkpoint taken under one mesh restores onto a
different mesh factorization with identical values — the framework analogue of
DMTCP's process virtualization.  Runs in subprocesses because the device count
must be forced before jax initializes (and must NOT leak into other tests)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

_SAVE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax, numpy as np
from pathlib import Path
from repro.configs.base import get_config, reduced
from repro.optim import adamw
from repro.train import step as TS
from repro.parallel.mesh_rules import Rules
from repro.checkpoint.store import TieredStore
from repro.checkpoint.manager import CheckpointManager
from repro.core.virtualization import fetch_tree, place_tree
from repro.data.pipeline import SyntheticTokens

mesh_shape = eval(sys.argv[1]); out = sys.argv[2]; mode = sys.argv[3]
cfg = reduced(get_config("llama3.2-1b"))
oc = adamw.OptConfig(warmup_steps=2, decay_steps=10)
mesh = jax.make_mesh(mesh_shape, ("data", "model")[:len(mesh_shape)] if len(mesh_shape)==2 else ("pod","data","model"))
rules = Rules(mesh)
step_fn, st_sh, bsf = TS.make_train_step(cfg, mesh, oc, rules=rules, donate=False)
store = TieredStore(Path(out))
mgr = CheckpointManager(store)
pipe = SyntheticTokens(cfg, 8, 32, seed=5)
if mode == "save":
    state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(3))
    state = place_tree(fetch_tree(state), TS.state_logical_axes(cfg), rules)
    with mesh:
        for _ in range(3):
            state, m = step_fn(state, next(pipe))
    mgr.save(2, fetch_tree(state)); mgr.commit(2)
    print("SAVED", float(m["loss"]))
else:
    host, man = mgr.restore(TS.abstract_train_state(cfg, oc))
    state = place_tree(host, TS.state_logical_axes(cfg), rules)
    with mesh:
        state, m = step_fn(state, pipe.batch_at(3))
    print("STEP4", repr(float(m["loss"])))
"""


@pytest.mark.slow
@pytest.mark.parametrize("restore_mesh", ["(2, 4)", "(8, 1)", "(1, 8)", "(2, 2, 2)"])
def test_elastic_restore_other_mesh(tmp_path, restore_mesh):
    env = {**os.environ, "PYTHONPATH": SRC}
    env.pop("XLA_FLAGS", None)

    def run(mesh, mode):
        r = subprocess.run(
            [sys.executable, "-c", _SAVE, mesh, str(tmp_path), mode],
            env=env, capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stdout + r.stderr
        return r.stdout

    run("(4, 2)", "save")
    base = run("(4, 2)", "restore")          # same mesh: reference next-step loss
    other = run(restore_mesh, "restore")     # different mesh factorization
    l1 = base.strip().splitlines()[-1]
    l2 = other.strip().splitlines()[-1]
    assert l1.startswith("STEP4") and l2.startswith("STEP4")
    a, b = float(l1.split()[1]), float(l2.split()[1])
    # same restored state, same batch; resharded execution may reassociate
    # reductions, so allow tiny numerical slack
    assert abs(a - b) < 5e-4, (a, b)
