"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional dev dependency (see pyproject.toml); when it is
absent this module skips instead of failing collection of the whole suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import serialization as SER
from repro.data.pipeline import PipelineState, SyntheticTokens
from repro.configs.base import get_config, reduced
from repro.kernels import ops
from repro.parallel.mesh_rules import Rules
from repro.train.step import effective_microbatches

# ----------------------------------------------------------------------------------
# serialization roundtrip for arbitrary leaf shapes/dtypes
# ----------------------------------------------------------------------------------
_DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_]


@st.composite
def _arrays(draw):
    dt = draw(st.sampled_from(_DTYPES))
    ndim = draw(st.integers(0, 4))
    shape = tuple(draw(st.integers(1, 5)) for _ in range(ndim))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dt is np.bool_:
        return rng.integers(0, 2, shape).astype(bool)
    if np.issubdtype(dt, np.integer):
        info = np.iinfo(dt)
        return rng.integers(info.min // 2, info.max // 2, shape).astype(dt)
    return rng.standard_normal(shape).astype(dt)


@given(st.dictionaries(st.text(st.characters(categories=["Ll"]), min_size=1, max_size=8),
                       _arrays(), min_size=1, max_size=6))
@settings(max_examples=30, deadline=None)
def test_serialization_roundtrip_any_tree(tree):
    data = SER.write_shard_bytes(SER.tree_to_records(tree))
    named, _ = SER.read_shard_bytes(data)
    out = SER.restore_tree(tree, named)
    for (p, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(tree)[0],
                              jax.tree_util.tree_flatten_with_path(out)[0]):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.array_equal(np.asarray(a), np.asarray(b)), p


# ----------------------------------------------------------------------------------
# mesh-rule invariants: no mesh axis reused, divisibility always holds
# ----------------------------------------------------------------------------------
_AXIS_NAMES = st.sampled_from(
    [None, "batch", "embed", "mlp", "heads", "kv_heads", "vocab", "expert",
     "layers", "heads_dim", "cache_seq", "seq", "ssm_inner"])


@given(st.lists(st.tuples(_AXIS_NAMES, st.integers(1, 4096)), min_size=1, max_size=5),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_rules_spec_invariants(dims, multi_pod):
    axes = tuple(a for a, _ in dims)
    shape = tuple(s for _, s in dims)

    class FakeMesh:  # avoid touching real devices: Rules only reads names/shape
        axis_names = ("pod", "data", "model") if multi_pod else ("data", "model")
        devices = np.empty((2, 16, 16) if multi_pod else (16, 16), object)

    rules = Rules(FakeMesh())
    spec = rules.spec(axes, shape)
    used = []
    for i, part in enumerate(spec):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        used.extend(names)
        size = int(np.prod([rules.axis_sizes[a] for a in names]))
        assert shape[i] % size == 0, (axes, shape, spec)
    assert len(used) == len(set(used)), f"mesh axis reused: {spec}"


# ----------------------------------------------------------------------------------
# microbatching: divisibility + shard coverage
# ----------------------------------------------------------------------------------
@given(st.integers(1, 4096), st.integers(1, 64), st.sampled_from([1, 8, 16, 32]))
@settings(max_examples=100, deadline=None)
def test_effective_microbatches_invariants(B, req, shards):
    m = effective_microbatches(B, req, shards)
    assert 1 <= m <= max(req, 1)
    assert B % m == 0
    assert (B // m) >= min(shards, B)


# ----------------------------------------------------------------------------------
# data pipeline: restore determinism from any state
# ----------------------------------------------------------------------------------
@given(st.integers(0, 2**20), st.integers(0, 500), st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_pipeline_restore_any_point(seed, start, n):
    cfg = reduced(get_config("qwen2-0.5b"))
    p1 = SyntheticTokens(cfg, 2, 16, seed=seed)
    p1.restore(PipelineState(seed, start))
    want = [next(p1)["tokens"] for _ in range(n)]
    p2 = SyntheticTokens(cfg, 2, 16, seed=123)          # different init
    p2.restore(PipelineState(seed, start))
    got = [next(p2)["tokens"] for _ in range(n)]
    for a, b in zip(want, got):
        assert np.array_equal(a, b)


# ----------------------------------------------------------------------------------
# checksum: pallas-interpret == oracle for arbitrary lengths; order sensitivity
# ----------------------------------------------------------------------------------
@given(st.integers(1, 10000), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_checksum_impls_agree(n, seed):
    rng = np.random.default_rng(seed)
    words = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    assert int(ops.checksum(words)) == int(ops.checksum(words, impl="pallas_interpret"))


@given(st.integers(2, 2000), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_checksum_order_sensitive(n, seed):
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    if len(set(words[:2].tolist())) < 2:
        words[0] ^= 1
    swapped = words.copy()
    swapped[[0, 1]] = swapped[[1, 0]]
    assert int(ops.checksum(jnp.asarray(words))) != int(
        ops.checksum(jnp.asarray(swapped)))
