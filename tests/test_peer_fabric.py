"""Peer-to-peer warm-cache restore fabric: a cold node sources its restore
from warm peers' promoted caches (multi-source ranged reads, round-robin
across peers) instead of the shared parallel filesystem; peer-fetched bytes
are teed into the local tier so one cold restart warms the node; every fault
(peer death mid-fetch, short read, CRC mismatch, stale inventory) falls back
per-range and converges byte-identically."""
from pathlib import Path

import numpy as np
import pytest

import faults
from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import (TieredStore, is_peer_tier,
                                    node_local_tier_roots)
from repro.sched.cache_registry import (CacheRegistry, format_peer_roots,
                                        parse_peer_roots)
from repro.sched.slurmsim import SlurmSim
from test_placement import _blocker_spec, _warm_node0, job_spec, reports


class CountingStore(faults.ByteCountingStoreMixin, TieredStore):
    """Counts every byte actually fetched, keyed by tier (peer tiers count
    under their ``peer:<node>`` name) — see faults.py."""


def _tree(rng, big_kb: int = 64):
    # two big leaves so that with 2 shards EVERY shard holds a payload run
    # large enough for the n>4096 fault predicates to see
    return {
        "w": rng.standard_normal((64, 32)).astype(np.float32),
        "b": rng.standard_normal((256,)).astype(np.float32),
        "big": rng.standard_normal((big_kb * 256,)).astype(np.float32),
        "big2": rng.standard_normal((big_kb * 256,)).astype(np.float32),
        "step": np.int32(7),
    }


def _assert_trees_equal(got, want):
    flat_g = dict(SER.flatten_with_names(got))
    flat_w = dict(SER.flatten_with_names(want))
    assert set(flat_g) == set(flat_w)
    for name in flat_w:
        a, b = np.asarray(flat_g[name]), np.asarray(flat_w[name])
        assert a.dtype == b.dtype, name
        assert a.tobytes() == b.tobytes(), name


def _commit_shared(ck, tree, step=1, n_shards=4):
    store = TieredStore(Path(ck), seed=0)
    pol = CheckpointPolicy(replicas=1)
    for w in range(n_shards):
        CheckpointManager(store, pol, worker_id=w,
                          num_workers=n_shards).save(step, tree)
    CheckpointManager(store, pol,
                      num_workers=n_shards).commit(step, num_workers=n_shards)


def _warm_peer(ck, peer_root, node, registry=None):
    """Promote the latest committed step into ``peer_root``'s local tier —
    the peer whose cache the cold node will read."""
    store = TieredStore(Path(ck), seed=0,
                        tier_roots=node_local_tier_roots(peer_root))
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="eager"), node=node,
                          registry=registry)
    m.prefetch_latest()
    m.wait_promotions()
    assert not m.promote_failures
    m.close()


def _cold_manager(ck, cold_root, peer_roots=None, registry=None,
                  promote="on_restore", **kw):
    store = CountingStore(Path(ck), seed=0,
                          tier_roots=node_local_tier_roots(cold_root))
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote=promote, **kw),
                          node="cold", peer_roots=peer_roots, registry=registry)
    return store, m


def _peer_bytes(read_by_tier: dict) -> int:
    return sum(v for t, v in read_by_tier.items() if is_peer_tier(t))


# ---------------------------------------------------------------------------
# headline: cold node + 1 warm peer -> zero shared bytes, then a warm local
# ---------------------------------------------------------------------------

def test_peer_fetch_zero_shared_bytes_and_warms_local(tmp_path, rng):
    tree = _tree(rng)
    _commit_shared(tmp_path / "ck", tree)
    _warm_peer(tmp_path / "ck", tmp_path / "peerA", "peerA")

    cold, m = _cold_manager(tmp_path / "ck", tmp_path / "cold",
                            peer_roots={"peerA": tmp_path / "peerA"})
    out, man = m.restore(tree)
    _assert_trees_equal(out, tree)
    assert man["step"] == 1
    stats = m.last_restore_stats
    assert stats["peer"] is True and stats["tier"] == "peer"
    assert stats["bytes_by_tier"].get("peer:peerA", 0) > 0
    assert "shared" not in stats["bytes_by_tier"]
    # zero shared-tier bytes END TO END: payload, headers, marker, manifest
    assert cold.read_by_tier.get("shared", 0) == 0, cold.read_by_tier
    assert _peer_bytes(cold.read_by_tier) > 0
    m.wait_promotions()
    assert not m.promote_failures

    # the write-behind tee warmed THIS node: the second restart on the same
    # cold node reads zero bytes from the shared tier AND from the peers
    cold2, m2 = _cold_manager(tmp_path / "ck", tmp_path / "cold",
                              peer_roots={"peerA": tmp_path / "peerA"})
    out2, _ = m2.restore(tree)
    _assert_trees_equal(out2, tree)
    assert m2.last_restore_stats.get("promoted") is True
    assert cold2.read_by_tier.get("shared", 0) == 0, cold2.read_by_tier
    assert _peer_bytes(cold2.read_by_tier) == 0, cold2.read_by_tier
    m.close()
    m2.close()


def test_registry_discovery_without_scheduler_hint(tmp_path, rng):
    """The decentralized path: the peer published its promotion into the
    CacheRegistry; a cold manager with NO scheduler hint finds it there."""
    reg = CacheRegistry(tmp_path / "ck" / "peer_registry")
    tree = _tree(rng)
    _commit_shared(tmp_path / "ck", tree)
    _warm_peer(tmp_path / "ck", tmp_path / "peerA", "peerA", registry=reg)
    ent = reg.entries()["peerA"]
    assert ent["step"] == 1 and ent["tier"] == "local"
    assert ent["local_root"] == str(tmp_path / "peerA")
    assert ent["files"]

    cold, m = _cold_manager(tmp_path / "ck", tmp_path / "cold", registry=reg)
    out, _ = m.restore(tree)
    _assert_trees_equal(out, tree)
    assert m.last_restore_stats["peer"] is True
    assert cold.read_by_tier.get("shared", 0) == 0, cold.read_by_tier
    m.wait_promotions()
    # ...and the freshly warmed cold node published ITSELF as a peer
    assert reg.entries()["cold"]["step"] == 1
    m.close()


def test_two_peers_round_robin_aggregate(tmp_path, rng):
    """With k warm peers the range tasks rotate across them — both peers
    serve payload bytes (the bandwidth-aggregation split), shared serves
    none, and the tree is exact."""
    tree = _tree(rng)
    _commit_shared(tmp_path / "ck", tree, n_shards=4)
    _warm_peer(tmp_path / "ck", tmp_path / "peerA", "peerA")
    _warm_peer(tmp_path / "ck", tmp_path / "peerB", "peerB")

    cold, m = _cold_manager(tmp_path / "ck", tmp_path / "cold",
                            peer_roots={"peerA": tmp_path / "peerA",
                                        "peerB": tmp_path / "peerB"})
    out, _ = m.restore(tree)
    _assert_trees_equal(out, tree)
    bt = m.last_restore_stats["bytes_by_tier"]
    assert bt.get("peer:peerA", 0) > 0, bt
    assert bt.get("peer:peerB", 0) > 0, bt
    assert "shared" not in bt
    assert cold.read_by_tier.get("shared", 0) == 0, cold.read_by_tier
    m.close()


# ---------------------------------------------------------------------------
# fault matrix: peer death mid-fetch / short read / CRC mismatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fault", ["oserror", "short_read", "crc"])
def test_peer_fault_mid_fetch_falls_back_per_range(tmp_path, rng, fault):
    """A peer failing mid-fetch — OSError after the first payload read, a
    short read, or corrupted payload bytes — must fall back per-range to the
    shared tier and still reassemble a byte-identical tree."""
    tree = _tree(rng, big_kb=128)
    _commit_shared(tmp_path / "ck", tree, n_shards=2)
    _warm_peer(tmp_path / "ck", tmp_path / "peerA", "peerA")
    peer_root = tmp_path / "peerA"

    cold, m = _cold_manager(tmp_path / "ck", tmp_path / "cold",
                            peer_roots={"peerA": peer_root})
    injector = None
    if fault == "oserror":
        injector = faults.PreadFaults(
            cold, lambda p, off, n: peer_root in p.parents and n > 4096,
            after=1, error=OSError("peer died mid-fetch"))
        injector.install()
    elif fault == "short_read":
        orig = cold._pread

        def short_pread(path, off, n):
            data = orig(path, off, n)
            if peer_root in Path(path).parents and n > 4096:
                return data[: max(1, n // 2)]
            return data

        cold._pread = short_pread
    else:   # crc: flip payload bytes in EVERY promoted peer shard
        shards = sorted(peer_root.glob("local/node0/ckpt/step_*/shard_*.bin"))
        assert shards
        for s in shards:
            faults.flip_byte(s)

    out, _ = m.restore(tree)
    if injector is not None:
        injector.uninstall()
        assert injector.fired > 0
    _assert_trees_equal(out, tree)
    stats = m.last_restore_stats
    assert stats["peer"] is True            # peer path was taken...
    assert stats["bytes_by_tier"].get("shared", 0) > 0   # ...and fell back
    assert stats["replica_fallbacks"] > 0
    m.close()


def test_peer_death_falls_back_to_second_peer_not_shared(tmp_path, rng):
    """With a second warm peer in the chain, a dying peer's ranges fall back
    to the OTHER peer — the shared tier still serves zero payload bytes."""
    tree = _tree(rng)
    _commit_shared(tmp_path / "ck", tree, n_shards=2)
    _warm_peer(tmp_path / "ck", tmp_path / "peerA", "peerA")
    _warm_peer(tmp_path / "ck", tmp_path / "peerB", "peerB")
    peer_a = tmp_path / "peerA"

    cold, m = _cold_manager(tmp_path / "ck", tmp_path / "cold",
                            peer_roots={"peerA": peer_a,
                                        "peerB": tmp_path / "peerB"})
    with faults.PreadFaults(
            cold, lambda p, off, n: peer_a in p.parents and n > 4096,
            error=OSError("peer A gone")) as inj:
        out, _ = m.restore(tree)
    assert inj.fired > 0
    _assert_trees_equal(out, tree)
    bt = m.last_restore_stats["bytes_by_tier"]
    assert bt.get("peer:peerB", 0) > 0, bt
    assert "shared" not in bt, bt
    m.close()


def test_stale_peer_inventory_is_never_served(tmp_path, rng):
    """Three staleness shapes: (a) a peer cache superseded by a newer commit
    is skipped via the registry step filter; (b) a LYING registry entry
    (claims the right step, peer's marker says otherwise) is skipped at the
    marker re-check; (c) a peer that invalidates withdraws its entry."""
    reg = CacheRegistry(tmp_path / "ck" / "peer_registry")
    tree1 = _tree(rng)
    _commit_shared(tmp_path / "ck", tree1, step=1)
    _warm_peer(tmp_path / "ck", tmp_path / "peerA", "peerA", registry=reg)

    # (a) a newer step commits on the shared tier: peerA's step-1 cache is
    # stale, the registry lookup filters it, the restore serves new bytes
    tree2 = {k: (np.asarray(v) + 1).astype(np.asarray(v).dtype)
             for k, v in tree1.items()}
    _commit_shared(tmp_path / "ck", tree2, step=2)
    cold, m = _cold_manager(tmp_path / "ck", tmp_path / "cold", registry=reg)
    out, man = m.restore(tree1)
    assert man["step"] == 2
    _assert_trees_equal(out, tree2)
    assert not (m.last_restore_stats or {}).get("peer")
    assert m.last_restore_stats["bytes_by_tier"].get("shared", 0) > 0
    m.wait_promotions()
    m.close()

    # (b) lying inventory: the entry claims step 2 but peerA still holds 1 —
    # the peer-side marker re-check rejects it before any payload read
    reg.publish("peerA", step=2, files=[],
                local_root=tmp_path / "peerA", tier="local")
    cold2, m2 = _cold_manager(tmp_path / "ck", tmp_path / "cold2",
                              registry=reg)
    out2, man2 = m2.restore(tree1)
    assert man2["step"] == 2
    _assert_trees_equal(out2, tree2)
    bt = m2.last_restore_stats["bytes_by_tier"]
    assert not any(t == "peer:peerA" for t in bt), bt
    m2.close()

    # (c) invalidation withdraws the cluster-visible claim
    store_a = TieredStore(tmp_path / "ck", seed=0,
                          tier_roots=node_local_tier_roots(tmp_path / "peerA"))
    ma = CheckpointManager(store_a, CheckpointPolicy(replicas=1, promote="eager"), node="peerA",
                           registry=reg)
    ma.invalidate_promoted()
    assert "peerA" not in reg.entries()
    ma.close()


def test_gone_peer_cache_with_live_marker_falls_back(tmp_path, rng):
    """The peer GC'd its shard files but its marker/manifest linger (crashed
    between delete and withdraw): header planning fails on the peer and every
    range falls back to shared — byte-identical, never an error."""
    tree = _tree(rng)
    _commit_shared(tmp_path / "ck", tree, n_shards=2)
    _warm_peer(tmp_path / "ck", tmp_path / "peerA", "peerA")
    for s in (tmp_path / "peerA").glob("local/node0/ckpt/step_*/shard_*.bin"):
        s.unlink()

    cold, m = _cold_manager(tmp_path / "ck", tmp_path / "cold",
                            peer_roots={"peerA": tmp_path / "peerA"})
    out, _ = m.restore(tree)
    _assert_trees_equal(out, tree)
    assert m.last_restore_stats["bytes_by_tier"].get("shared", 0) > 0
    m.close()


# ---------------------------------------------------------------------------
# registry + wire-format units
# ---------------------------------------------------------------------------

def test_registry_publish_withdraw_and_torn_entries(tmp_path):
    reg = CacheRegistry(tmp_path / "reg")
    assert reg.entries() == {}
    reg.publish("n0", step=3, files=["a", "b"], local_root="/roots/n0")
    reg.publish("n1", step=4, files=["a"], local_root="/roots/n1", tier="ram")
    (tmp_path / "reg" / "torn.json").write_text('{"node": "nX", "ste')
    ents = reg.entries()
    assert set(ents) == {"n0", "n1"}
    assert reg.warm_peers(4) == {"n1": ents["n1"]}
    assert reg.warm_peers(4, exclude=("n1",)) == {}
    assert reg.warm_peers(3, exclude=(None,)) == {"n0": ents["n0"]}
    reg.withdraw("n1")
    reg.withdraw("n1")                      # idempotent
    assert set(reg.entries()) == {"n0"}


def test_peer_roots_wire_format_roundtrip(tmp_path):
    peers = {"node1": tmp_path / "a", "node0": tmp_path / "b"}
    s = format_peer_roots(peers)
    assert s == f"node0={tmp_path / 'b'},node1={tmp_path / 'a'}"
    assert parse_peer_roots(s) == {k: Path(v) for k, v in peers.items()}
    assert parse_peer_roots(None) == {}
    assert parse_peer_roots("garbage,,=x,name=") == {}


# ---------------------------------------------------------------------------
# scheduler end to end: cold placement + peer hint -> zero shared bytes
# ---------------------------------------------------------------------------

def test_scheduler_peer_hint_cold_node_restores_via_peer(tmp_path):
    """node0 is warm but busy; the job's warm-wait budget is tiny, so it is
    placed COLD on node1 — with a peer hint naming node0.  The job's restore
    must come from node0's cache over the fabric, zero shared bytes."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    sim = SlurmSim(tmp_path / "sim", nodes=2)
    _warm_node0(sim, ckpt)
    sim.submit(_blocker_spec(2.5))                     # occupies node0
    jid = sim.submit(job_spec(ckpt, rdir, total=1, warm_wait_s=0.05))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    entry = rec.placement_log[0]
    assert entry["node"] == "node1"
    assert entry["peers"] == ["node0"]
    r0 = reports(rdir)[0]
    assert r0["node"] == "node1"
    assert r0["peer_roots"] == {"node0": str(sim.node("node0").local_root)}
    assert (r0["restore_stats"] or {}).get("peer") is True
    assert r0["restore_reads_by_tier"].get("shared", 0) == 0, r0
    assert r0["peer_read_bytes"] > 0
    from placement_jobs import make_tree, state_sum
    assert r0["state_sum"] == pytest.approx(state_sum(make_tree()))


# The multi-source == single-source property test (any interleaving of
# peer/shared/local range outcomes) lives in tests/test_peer_property.py so
# its optional hypothesis dependency cannot skip this module.
