"""Multi-process serving-fleet harness.

PR 7's bench iterated the fleet's replicas inside one process; this module
runs each follower as a REAL OS process — its own interpreter, its own
``TieredStore`` mount over a private node-local root, its own
``WeightSyncClient`` — so the replica-to-replica fabric, the follower-cache
advertisements, and the draining admission control are exercised with true
concurrency (the paper's cluster story: cooperating processes, not a loop).

The child (``python tests/fleet_harness.py <config.json>``) speaks exactly
the ``launch/serve.py --follow`` protocol — poll the push plane, fetch
deltas read-only with ``follower_cache=True``, gate admissions on staleness
— minus the jax engine: "generation" is a sleep, so dozens of replicas fit
in a test/bench run.  Results come back as one JSON file per replica.

Used by tests/test_fleet.py (3-process zero-shared-bytes e2e) and
benchmarks/bench_weight_push.py (``weight_push_fleet`` row).

Child config keys (all through ``replica_config``):

  root             fleet root directory (shared tier + registry + results)
  name             replica/node identity
  batches          generations to serve before exiting
  final_step       keep serving until this step is swapped in
  gen_s            simulated generation duration per batch
  poll_s           push-plane poll interval
  max_lag_steps    staleness bound (None: no gate)
  on_stale         "drain" | "raise"
  pipeline_uploads overlap to_native(N) with fetch(N+1)
  gate_on_peers    before fetching a step, wait (bounded) until some OTHER
                   replica advertises a follower cache for it — the fleet's
                   "seed one, then go replica-to-replica" policy; the seed
                   replica runs ungated
  gate_timeout_s   fall back to the shared tier after this long
  deadline_s       hard exit bound for the whole child
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time
import traceback
from pathlib import Path

import numpy as np

REPO_ROOT = Path(__file__).resolve().parents[1]
SRC = REPO_ROOT / "src"
CHUNK = 1 << 16


def tree_digest(tree: dict) -> str:
    """Order-independent content digest of a flat {name: ndarray} tree —
    what "the fleet converged byte-identically" means across processes."""
    h = hashlib.blake2b(digest_size=16)
    for k in sorted(tree):
        a = np.ascontiguousarray(tree[k])
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(str(tuple(a.shape)).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def template_from_manifest(manifest: dict) -> dict:
    """Rebuild a same-shape host tree from a manifest's leaf metadata, so a
    follower process needs NO out-of-band model config — the checkpoint
    itself says what to allocate."""
    return {e["path"]: np.zeros(tuple(e["shape"]), dtype=e["dtype"])
            for e in manifest["leaves"]}


# ---------------------------------------------------------------------------
# parent side: publisher + process management
# ---------------------------------------------------------------------------

class FleetPublisher:
    """The trainer side of the push plane, fleet-topology edition: commits
    delta checkpoints to the SHARED tier only (``promote="off"`` — no
    publisher-side warm cache, so every non-shared byte a replica reads is
    replica-to-replica by construction) and announces each push."""

    def __init__(self, root: Path, *, chunk_bytes: int = CHUNK,
                 sim_io_factor: float = 0.0):
        from repro.checkpoint.manager import (CheckpointManager,
                                              CheckpointPolicy)
        from repro.checkpoint.store import TieredStore
        from repro.sched.cache_registry import CacheRegistry
        self.root = Path(root)
        self.registry = CacheRegistry(self.root / "registry")
        self.manager = CheckpointManager(
            TieredStore(self.root / "ck", seed=0,
                        sim_io_factor=sim_io_factor),
            CheckpointPolicy(replicas=1, delta=True,
                             chunk_bytes=chunk_bytes, promote="off"),
            node="pub", registry=self.registry)

    def push(self, step: int, tree: dict) -> dict:
        save_stats = self.manager.save(step, tree)
        man = self.manager.commit(step)
        self.registry.announce_push(
            step=step, node="pub",
            manifest_version=man.get("manifest_version"))
        return {"manifest": man, "save_stats": save_stats,
                "announced_at": time.time()}

    def announce_uncommitted(self, step: int) -> None:
        """Announce a step that was never committed — the paused/crashed
        publisher scenario that drives followers into draining."""
        self.registry.announce_push(step=step, node="pub")

    def close(self) -> None:
        self.manager.close()


def replica_config(root: Path, name: str, **kw) -> dict:
    cfg = {
        "root": str(root),
        "name": name,
        "batches": 2,
        "final_step": None,
        "gen_s": 0.01,
        "poll_s": 0.02,
        "max_lag_steps": None,
        "on_stale": "drain",
        "pipeline_uploads": False,
        "gate_on_peers": False,
        "gate_timeout_s": 20.0,
        "deadline_s": 120.0,
        "chunk_bytes": CHUNK,
        "sim_io_factor": 0.0,
        "restore_workers": 0,
    }
    cfg.update(kw)
    return cfg


def spawn_replica(cfg: dict) -> subprocess.Popen:
    """Launch one follower child.  The config rides a JSON file (not the
    command line) and the result comes back the same way — no pickling, no
    multiprocessing spawn-method coupling."""
    root = Path(cfg["root"])
    cfg_dir = root / "fleet_cfg"
    cfg_dir.mkdir(parents=True, exist_ok=True)
    cfg_path = cfg_dir / f"{cfg['name']}.json"
    cfg_path.write_text(json.dumps(cfg))
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(SRC) + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else str(SRC))
    return subprocess.Popen(
        [sys.executable, str(Path(__file__).resolve()), str(cfg_path)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)


def result_path(cfg: dict) -> Path:
    return Path(cfg["root"]) / "fleet_results" / f"{cfg['name']}.json"


def wait_fleet(procs: list[tuple[dict, subprocess.Popen]],
               timeout_s: float = 180.0) -> dict[str, dict]:
    """Join every child and collect its result JSON; a child that died
    without writing one surfaces as an ``error`` result carrying its
    stderr, so test failures say WHY the replica fell over."""
    out: dict[str, dict] = {}
    deadline = time.monotonic() + timeout_s
    for cfg, p in procs:
        left = max(1.0, deadline - time.monotonic())
        try:
            stdout, stderr = p.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            p.kill()
            stdout, stderr = p.communicate()
            stderr = f"TIMEOUT after {timeout_s}s\n{stderr}"
        rp = result_path(cfg)
        if rp.exists():
            res = json.loads(rp.read_text())
        else:
            res = {"name": cfg["name"],
                   "error": f"no result file (rc={p.returncode})"}
        if p.returncode != 0 and "error" not in res:
            res["error"] = f"rc={p.returncode}"
        res["stdout"], res["stderr"] = stdout, stderr
        out[cfg["name"]] = res
    return out


def run_fleet(configs: list[dict], timeout_s: float = 180.0
              ) -> dict[str, dict]:
    return wait_fleet([(c, spawn_replica(c)) for c in configs], timeout_s)


# ---------------------------------------------------------------------------
# child side
# ---------------------------------------------------------------------------

def _wait_for_first_push(mgr, deadline: float) -> None:
    while time.monotonic() < deadline:
        if mgr.steps():
            return
        time.sleep(0.02)
    raise TimeoutError("no committed push appeared")


def _wait_for_peer_advert(registry, name: str, step: int,
                          timeout_s: float) -> bool:
    """The gate: block (bounded) until some OTHER replica advertises a
    follower cache at >= ``step``.  Returns False on timeout — the caller
    falls back to the shared tier rather than hanging the fleet."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        for n, e in registry.follower_entries().items():
            if n != name and e["step"] >= step:
                return True
        time.sleep(0.01)
    return False


def serve_replica(cfg: dict) -> dict:
    from repro.checkpoint.manager import (CheckpointManager,
                                          CheckpointPolicy)
    from repro.checkpoint.store import TieredStore, node_local_tier_roots
    from repro.sched.cache_registry import CacheRegistry
    from repro.serve.weight_sync import ParamHandle, WeightSyncClient

    root = Path(cfg["root"])
    name = cfg["name"]
    deadline = time.monotonic() + cfg["deadline_s"]
    registry = CacheRegistry(root / "registry")
    store = TieredStore(
        root / "ck", seed=0, sim_io_factor=cfg["sim_io_factor"],
        tier_roots=node_local_tier_roots(root / "nodes" / name))
    mgr = CheckpointManager(
        store,
        CheckpointPolicy(replicas=1, delta=True,
                         chunk_bytes=cfg["chunk_bytes"], promote="off",
                         restore_workers=cfg["restore_workers"]),
        node=name, registry=registry)
    _wait_for_first_push(mgr, deadline)
    template = template_from_manifest(mgr.read_manifest(mgr.steps()[0]))

    handle = ParamHandle(None, step=None)
    client = WeightSyncClient(
        mgr, handle, template, registry=registry, replica=name,
        max_lag_steps=cfg["max_lag_steps"], on_stale=cfg["on_stale"],
        pipeline_uploads=cfg["pipeline_uploads"])
    syncs: list[dict] = []
    served = 0
    final_step = cfg["final_step"]

    def sync():
        target = client.published_step()
        have = handle.newest_step
        if (cfg["gate_on_peers"] and target is not None
                and (have is None or target > have)
                and target in mgr.steps()):
            _wait_for_peer_advert(registry, name, target,
                                  cfg["gate_timeout_s"])
        rec = client.sync_once()
        if rec is not None:
            rec["completed_at"] = time.time()
            syncs.append(rec)

    while time.monotonic() < deadline:
        sync()
        if client.admit():
            # simulated generation: the admission gate, not the decode
            # loop, is what this harness exercises
            time.sleep(cfg["gen_s"])
            handle.commit_pending()
            served += 1
        else:
            time.sleep(cfg["poll_s"])
        done_step = (final_step is None
                     or (handle.step is not None
                         and handle.step >= final_step))
        if served >= cfg["batches"] and done_step and not client.draining:
            break
        if not client.draining:
            time.sleep(cfg["poll_s"] / 4)
    client.close()
    tree = handle.current
    res = {
        "name": name,
        "served": served,
        "final_step": handle.step,
        "digest": tree_digest(tree) if tree is not None else None,
        "drain_count": client.drain_count,
        "readmit_count": client.readmit_count,
        "syncs": syncs,
        "follower_advertised": any(r.get("follower_advertised")
                                   for r in syncs),
    }
    mgr.close()
    return res


def main(argv: list[str]) -> int:
    cfg = json.loads(Path(argv[0]).read_text())
    rp = result_path(cfg)
    rp.parent.mkdir(parents=True, exist_ok=True)
    try:
        res = serve_replica(cfg)
        rc = 0
    except Exception:                                   # noqa: BLE001
        res = {"name": cfg.get("name"),
               "error": traceback.format_exc()}
        rc = 1
    tmp = rp.with_suffix(f".{os.getpid()}.tmp")
    tmp.write_text(json.dumps(res))
    os.replace(tmp, rp)
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
