"""The paper's §VI sweep, framework analogue: C/R works identically across all
ten assigned architectures — train one step, checkpoint, restore into a fresh
state, and verify the next step matches the uninterrupted continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import TieredStore
from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.core.virtualization import fetch_tree, place_tree
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.parallel.mesh_rules import Rules
from repro.train import step as TS


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_checkpoint_restart_cycle(arch, rng, tmp_path):
    cfg = reduced(get_config(arch)).replace(num_layers=2)
    oc = adamw.OptConfig(warmup_steps=1, decay_steps=4)
    mesh = make_host_mesh()
    rules = Rules(mesh)
    step_fn, *_ = TS.make_train_step(cfg, mesh, oc, rules=rules, donate=False)

    def batch():
        shape = ((2, 16, cfg.num_codebooks) if cfg.num_codebooks else (2, 16))
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)}
        if cfg.num_image_tokens:
            b["image_embeds"] = jnp.asarray(
                rng.standard_normal((2, cfg.num_image_tokens, cfg.d_model), np.float32))
        return b

    b0, b1 = batch(), batch()
    state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    state, _ = step_fn(state, b0)

    mgr = CheckpointManager(TieredStore(tmp_path))
    mgr.save(0, fetch_tree(state))
    mgr.commit(0)

    # continuous path
    cont, m_cont = step_fn(state, b1)
    # restart path: fresh manager+placement, same next batch
    host, _ = CheckpointManager(TieredStore(tmp_path)).restore(
        TS.abstract_train_state(cfg, oc))
    restored = place_tree(host, TS.state_logical_axes(cfg), rules)
    rest, m_rest = step_fn(restored, b1)

    assert float(m_cont["loss"]) == float(m_rest["loss"]), arch
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        cont["params"], rest["params"])
    assert max(jax.tree_util.tree_leaves(d)) == 0.0, arch
