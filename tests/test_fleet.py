"""Multi-process serving-fleet e2e: real follower processes, replica-to-
replica delta propagation, draining admission control.

Each replica is a separate interpreter (tests/fleet_harness.py child)
mounting its own node-local tier over one shared checkpoint root — the
closest a test gets to the paper's cooperating-cluster restart without a
cluster.  The headline invariant: with one ungated "seed" replica, every
OTHER replica reads ZERO shared-tier payload bytes — the whole model and
every delta arrive through follower-cache peer tiers.
"""
import time

import numpy as np
import pytest

import fleet_harness as fh

pytestmark = pytest.mark.slow


def _wait_status(registry, names, pred, timeout_s=60.0, what=""):
    """Poll the fleet view until ``pred(entry)`` holds for every replica in
    ``names`` — how the parent paces pushes against live children."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        status = registry.replica_status()
        if all(n in status and pred(status[n]) for n in names):
            return status
        time.sleep(0.02)
    raise TimeoutError(
        f"fleet never reached {what}: {registry.replica_status()}")


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def _tree(rng, n_leaves=4, elems=60_000):
    return {f"l{i}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}


def _mutate(tree, names, delta=1.0, elems=128):
    out = dict(tree)
    for k in names:
        a = out[k].copy()
        a[:elems] += delta
        out[k] = a
    return out


def _shared_payload_bytes(res: dict) -> int:
    return sum(r["bytes_by_tier"].get("shared", 0) for r in res["syncs"])


def _peer_payload_bytes(res: dict) -> int:
    return sum(v for r in res["syncs"]
               for t, v in r["bytes_by_tier"].items()
               if t.startswith("peer:"))


def test_three_process_fleet_zero_shared_bytes_and_convergence(
        tmp_path, rng):
    pub = fh.FleetPublisher(tmp_path)
    tree = _tree(rng)
    pub.push(1, tree)

    # r1 is the ungated seed (it pays the 1x shared fetch); r2/r3 gate on a
    # peer advertisement before every fetch, so their bytes are
    # replica-to-replica by construction, not by race luck
    cfgs = [fh.replica_config(tmp_path, "r1", batches=2, final_step=3),
            fh.replica_config(tmp_path, "r2", batches=2, final_step=3,
                              gate_on_peers=True),
            fh.replica_config(tmp_path, "r3", batches=2, final_step=3,
                              gate_on_peers=True, pipeline_uploads=True)]
    procs = [(c, fh.spawn_replica(c)) for c in cfgs]
    names = [c["name"] for c in cfgs]

    # two delta pushes land while the fleet is LIVE — each one only after
    # every replica synced the previous step, so all three processes see
    # all three steps (skipping intermediates is legal, just not what this
    # test is about)
    for step, leaf in ((2, "l0"), (3, "l2")):
        _wait_status(pub.registry, names,
                     lambda e, s=step: (e.get("step") or 0) >= s - 1,
                     what=f"step {step - 1}")
        tree = _mutate(tree, [leaf])
        pub.push(step, tree)

    results = fh.wait_fleet(procs, timeout_s=120.0)
    for name, res in results.items():
        assert "error" not in res, (name, res.get("error"),
                                    res.get("stderr"))
        assert res["final_step"] == 3, (name, res)
        assert res["follower_advertised"], (name, res)
        assert [r["step"] for r in res["syncs"]] == [1, 2, 3], (name, res)

    # replica 2+ read ZERO shared-tier bytes: every byte (full tree at
    # step 1, both deltas) was served by another replica's follower cache
    for name in ("r2", "r3"):
        assert _shared_payload_bytes(results[name]) == 0, (
            name, [r["bytes_by_tier"] for r in results[name]["syncs"]])
        assert _peer_payload_bytes(results[name]) > 0, (name, results[name])
    # the seed paid the shared tier (there was nobody to peer from)
    assert _shared_payload_bytes(results["r1"]) > 0

    # ...and the fleet converged byte-identically to the publisher's tree
    want = fh.tree_digest(tree)
    assert [results[n]["digest"] for n in ("r1", "r2", "r3")] == [want] * 3
    pub.close()


def test_fleet_drains_and_readmits_under_paused_publisher(tmp_path, rng):
    pub = fh.FleetPublisher(tmp_path)
    tree = _tree(rng, n_leaves=2, elems=30_000)
    pub.push(1, tree)

    cfgs = [fh.replica_config(tmp_path, f"d{i}", batches=3, final_step=9,
                              max_lag_steps=2, gen_s=0.005)
            for i in range(2)]
    procs = [(c, fh.spawn_replica(c)) for c in cfgs]
    names = [c["name"] for c in cfgs]

    # wait until the fleet serves step 1, then stall the publisher
    # mid-push: announced, never committed — every replica must DRAIN
    # (no StaleReplicaError, no exit) ...
    _wait_status(pub.registry, names,
                 lambda e: (e.get("step") or 0) >= 1, what="step 1")
    pub.announce_uncommitted(9)
    status = _wait_status(pub.registry, names,
                          lambda e: e["phase"] == "draining",
                          what="draining")
    # ... then recover once the commit lands
    tree = _mutate(tree, ["l0", "l1"])
    pub.push(9, tree)

    results = fh.wait_fleet(procs, timeout_s=120.0)
    for name, res in results.items():
        assert "error" not in res, (name, res.get("error"),
                                    res.get("stderr"))
        assert res["drain_count"] >= 1, (name, res)
        assert res["readmit_count"] >= 1, (name, res)
        assert res["final_step"] == 9, (name, res)
        assert res["digest"] == fh.tree_digest(tree), name
    # the fleet view saw the draining phase while the publisher was stalled
    drained_seen = [e for e in status.values() if e["phase"] == "draining"]
    assert drained_seen, status
    pub.close()
