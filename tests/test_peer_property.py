"""Property: multi-source restore planning is byte-identical to single-source
under ANY interleaving of peer/shared/local range outcomes — healthy peers,
peers whose cache is gone, peers that die mid-fetch (OSError after N reads via
``faults.PreadFaults``), corrupted peer payloads, stale peer markers — the
restored tree always converges to the same bytes the shared tier alone yields.

The hypothesis-driven search runs when hypothesis is installed; a
deterministic sweep over the interesting interleavings (including every mode
paired with every other) runs unconditionally, so the property is exercised
even in environments without hypothesis.
"""
import itertools
import json
import tempfile
from pathlib import Path

import numpy as np
import pytest

import faults
from test_peer_fabric import (_assert_trees_equal, _cold_manager,
                              _commit_shared, _warm_peer)

PEER_MODES = ("ok", "gone", "late_oserror", "corrupt", "stale_marker")


def _tree():
    rng = np.random.default_rng(0)
    return {
        "w": rng.standard_normal((64, 16)).astype(np.float32),
        "b": rng.standard_normal((512,)).astype(np.float32),
        "k": rng.standard_normal((2048,)).astype(np.float32),
    }


def _check_interleaving(modes: tuple, afters: dict) -> None:
    """Build shared + len(modes) peers, damage each peer per its mode, and
    assert the multi-source restore equals the shared-only restore bit for
    bit.  ``afters[i]`` is how many peer reads succeed before peer i 'dies'
    (the mid-fetch death point)."""
    tree = _tree()
    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        _commit_shared(root / "ck", tree, n_shards=2)
        peer_roots, injector_specs = {}, []
        for i, mode in enumerate(modes):
            name = f"p{i}"
            peer_root = root / name
            _warm_peer(root / "ck", peer_root, name)
            peer_roots[name] = peer_root
            shards = sorted(peer_root.glob(
                "local/node0/ckpt/step_*/shard_*.bin"))
            assert shards
            if mode == "gone":
                for s in shards:
                    s.unlink()
            elif mode == "corrupt":
                for s in shards:
                    faults.flip_byte(s)
            elif mode == "stale_marker":
                (peer_root / "local" / "node0" / "ckpt"
                 / "PROMOTED.json").write_text(
                     json.dumps({"step": 999, "files": []}))
            elif mode == "late_oserror":
                injector_specs.append((peer_root, afters.get(i, 1)))

        cold, m = _cold_manager(root / "ck", root / "cold",
                                peer_roots=peer_roots, promote="off")
        installed = []
        try:
            for peer_root, after in injector_specs:
                inj = faults.PreadFaults(
                    cold,
                    lambda p, off, n, pr=peer_root: pr in p.parents and n > 1024,
                    after=after, error=OSError("peer died mid-fetch"))
                installed.append(inj.install())
            out_multi, _ = m.restore(tree)
        finally:
            for inj in reversed(installed):
                inj.uninstall()
        m.close()

        # single-source reference: a fresh cold node, shared tier only
        _, m_ref = _cold_manager(root / "ck", root / "cold_ref",
                                 peer_roots=None, promote="off")
        out_ref, _ = m_ref.restore(tree)
        m_ref.close()

        _assert_trees_equal(out_multi, out_ref)
        _assert_trees_equal(out_multi, tree)


# every single-peer mode, and every ordered pair of distinct modes — the
# deterministic core of the property, run whether or not hypothesis exists
_PAIRS = list(itertools.permutations(PEER_MODES, 2))


@pytest.mark.parametrize("modes", [(m,) for m in PEER_MODES] + _PAIRS,
                         ids=lambda m: "+".join(m))
def test_interleavings_deterministic(modes):
    _check_interleaving(tuple(modes), afters={i: 1 for i in range(len(modes))})


def test_all_peers_hostile_three_wide():
    _check_interleaving(("gone", "late_oserror", "corrupt"),
                        afters={1: 0})


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover - optional dep
    pass
else:
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_interleavings_hypothesis(data):
        n_peers = data.draw(st.integers(1, 3), label="n_peers")
        modes = tuple(
            data.draw(st.sampled_from(PEER_MODES), label=f"peer{i}_mode")
            for i in range(n_peers))
        afters = {i: data.draw(st.integers(0, 2), label=f"peer{i}_after")
                  for i in range(n_peers) if modes[i] == "late_oserror"}
        _check_interleaving(modes, afters)
