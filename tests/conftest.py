import sys
from pathlib import Path

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real (single) device; only launch/dryrun.py forces 512 host devices.
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
