"""Per-kernel validation: Pallas (interpret mode) and XLA-chunked vs pure-jnp
oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels._rwkv6_pallas import wkv6_pallas
from repro.kernels._ssd_pallas import ssd_pallas
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash
from repro.kernels.rwkv6_scan import wkv6_chunked_xla, wkv6_step
from repro.kernels.ssd_scan import ssd_chunked_xla, ssd_step
from repro.kernels.xla_attention import causal_blockwise

TOL = {np.float32: 2e-5, jnp.bfloat16: 5e-2}


def _mk_qkv(rng, B, Sq, Skv, H, Hkv, Dq, Dv, dtype):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, Dq), np.float32), dtype)
    k = jnp.asarray(rng.standard_normal((B, Skv, Hkv, Dq), np.float32), dtype)
    v = jnp.asarray(rng.standard_normal((B, Skv, Hkv, Dv), np.float32), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,Hkv,Dq,Dv", [
    (2, 256, 4, 2, 64, 64),     # GQA
    (1, 128, 8, 1, 128, 64),    # MQA-ish, d_qk != d_v (MLA shape)
    (2, 128, 4, 4, 32, 32),     # MHA
    (1, 512, 2, 2, 64, 64),     # longer seq
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(rng, B, S, H, Hkv, Dq, Dv, dtype):
    q, k, v = _mk_qkv(rng, B, S, S, H, Hkv, Dq, Dv, dtype)
    out = flash(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = ref.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=True)
    tol = TOL[np.float32 if dtype is np.float32 else jnp.bfloat16]
    assert float(jnp.abs(out.astype(jnp.float32) - want).max()) < tol


@pytest.mark.parametrize("Sq", [96, 256, 1000])
def test_blockwise_xla_vs_ref(rng, Sq):
    q, k, v = _mk_qkv(rng, 2, Sq, Sq, 4, 2, 32, 32, np.float32)
    out = causal_blockwise(q, k, v, block_q=64, block_k=64)
    want = ref.attention(q, k, v, causal=True)
    assert float(jnp.abs(out - want).max()) < 2e-5


@pytest.mark.parametrize("B,H,Hkv,Dq,Dv,S,kvl", [
    (2, 8, 2, 64, 64, 512, 300),
    (1, 16, 1, 128, 64, 256, 256),   # MLA-ish absorbed shape
    (4, 4, 4, 32, 32, 128, 77),
])
def test_flash_decode_vs_ref(rng, B, H, Hkv, Dq, Dv, S, kvl):
    q, k, v = _mk_qkv(rng, B, 1, S, H, Hkv, Dq, Dv, np.float32)
    out = flash_decode(q, k, v, kv_len=kvl, block_k=128, interpret=True)
    want = ref.attention(q, k, v, causal=False, kv_len=kvl)
    assert float(jnp.abs(out - want).max()) < 2e-5


def _mk_ssd(rng, B, S, H, P, N):
    x = jnp.asarray(rng.standard_normal((B, S, H, P), np.float32)) * 0.5
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, H))).astype(np.float32)) * 0.5
    Al = jnp.asarray(rng.standard_normal((H,)).astype(np.float32)) * 0.3
    Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32)) * 0.5
    Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32)) * 0.5
    D = jnp.ones((H,), jnp.float32)
    return x, dt, Al, Bm, Cm, D


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 3, 32, 16, 32), (1, 256, 2, 16, 64, 64), (2, 64, 4, 8, 8, 16),
])
def test_ssd_chunked_and_pallas_vs_ref(rng, B, S, H, P, N, chunk):
    x, dt, Al, Bm, Cm, D = _mk_ssd(rng, B, S, H, P, N)
    want, wst = ref.ssd(x, dt, Al, Bm, Cm, D, return_state=True)
    g1, s1 = ssd_chunked_xla(x, dt, Al, Bm, Cm, D, chunk=chunk, return_state=True)
    g2, s2 = ssd_pallas(x, dt, Al, Bm, Cm, D, chunk=chunk, return_state=True,
                        interpret=True)
    for g, s in ((g1, s1), (g2, s2)):
        assert float(jnp.abs(g - want).max()) < 5e-5
        assert float(jnp.abs(s - wst).max()) < 5e-5


def test_ssd_decode_step_matches_scan(rng):
    B, S, H, P, N = 2, 16, 2, 8, 8
    x, dt, Al, Bm, Cm, D = _mk_ssd(rng, B, S, H, P, N)
    _, st = ref.ssd(x, dt, Al, Bm, Cm, D, return_state=True)
    st2 = jnp.zeros_like(st)
    for t in range(S):
        y, st2 = ssd_step(x[:, t], dt[:, t], Al, Bm[:, t], Cm[:, t], D, st2)
    assert float(jnp.abs(st2 - st).max()) < 5e-5


def _mk_wkv(rng, B, S, H, Dh):
    r, k, v = (jnp.asarray(rng.standard_normal((B, S, H, Dh), np.float32)) * 0.5
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.7, 0.999, (B, S, H, Dh)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((H, Dh)).astype(np.float32)) * 0.3
    return r, k, v, w, u


@pytest.mark.parametrize("B,S,H,Dh,chunk", [
    (2, 128, 3, 32, 32), (1, 64, 2, 64, 16), (2, 96, 1, 16, 32),
])
def test_wkv6_chunked_and_pallas_vs_ref(rng, B, S, H, Dh, chunk):
    r, k, v, w, u = _mk_wkv(rng, B, S, H, Dh)
    want, wst = ref.wkv6(r, k, v, w, u, return_state=True)
    g1, s1 = wkv6_chunked_xla(r, k, v, w, u, chunk=chunk, return_state=True)
    g2, s2 = wkv6_pallas(r, k, v, w, u, chunk=chunk, return_state=True,
                         interpret=True)
    for g, s in ((g1, s1), (g2, s2)):
        assert float(jnp.abs(g - want).max()) < 1e-4
        assert float(jnp.abs(s - wst).max()) < 1e-4


def test_wkv6_decode_step_matches_scan(rng):
    B, S, H, Dh = 1, 12, 2, 16
    r, k, v, w, u = _mk_wkv(rng, B, S, H, Dh)
    ys, st = ref.wkv6(r, k, v, w, u, return_state=True)
    st2 = jnp.zeros_like(st)
    outs = []
    for t in range(S):
        y, st2 = wkv6_step(r[:, t], k[:, t], v[:, t], w[:, t], u, st2)
        outs.append(y)
    got = jnp.stack(outs, axis=1)
    assert float(jnp.abs(got - ys).max()) < 1e-4
    assert float(jnp.abs(st2 - st).max()) < 1e-4


@pytest.mark.parametrize("n", [64, 2048, 5000, 100_000])
def test_checksum_pallas_vs_ref(rng, n):
    words = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    a = ops.checksum(words, impl="auto")
    b = ops.checksum(words, impl="pallas_interpret")
    assert int(a) == int(b)


def test_checksum_detects_flip(rng):
    words = jnp.asarray(rng.integers(0, 2**32, size=4096, dtype=np.uint32))
    a = ops.checksum(words)
    flipped = words.at[1234].set(words[1234] ^ 1)
    assert int(a) != int(ops.checksum(flipped))


def test_checksum_empty_input_is_zero():
    empty = jnp.zeros((0,), jnp.uint32)
    assert int(ops.checksum(empty)) == 0
    assert int(ops.checksum(empty, impl="pallas_interpret")) == 0


def test_checksum_rejects_non_pow2_block(rng):
    from repro.kernels import checksum as ck

    words = jnp.asarray(rng.integers(0, 2**32, size=64, dtype=np.uint32))
    for bad in (0, -8, 1000):
        with pytest.raises(ValueError):
            ops.checksum(words, block=bad)
        with pytest.raises(ValueError):
            ck.checksum_pallas(words, block=bad)


@pytest.mark.parametrize("n,chunk_words", [(8192, 1024), (5000, 512), (1, 8)])
def test_chunk_fingerprints_pallas_vs_ref(rng, n, chunk_words):
    words = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.uint32))
    a = ops.chunk_fingerprints(words, chunk_words=chunk_words, impl="ref")
    b = ops.chunk_fingerprints(words, chunk_words=chunk_words,
                               impl="pallas_interpret")
    assert a.shape == (-(-n // chunk_words),)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_chunk_fingerprints_empty_and_pow2_guard(rng):
    assert ops.chunk_fingerprints(jnp.zeros((0,), jnp.uint32),
                                  chunk_words=64).shape == (0,)
    with pytest.raises(ValueError):
        ops.chunk_fingerprints(jnp.zeros((8,), jnp.uint32), chunk_words=48)
