"""Restore-aware scheduler placement on warm promoted caches, verified by a
fault-injection harness (tests/faults.py): a preempted job requeued onto its
warm node restores with ZERO shared-tier data bytes; a blind baseline does
not; and under injected faults (torn marker, truncated promoted shard,
mid-promotion kill, stale marker) the scheduler never restores stale bytes
and always converges to a correct restart."""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

import faults
from placement_jobs import REQUEUE_EXIT, expected_sum, make_tree, state_sum
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy, validate_promoted_cache
from repro.checkpoint.store import TieredStore
from repro.core.requeue import RequeueFile, WalltimeTracker
from repro.sched.placement import (SCORE_HINT, SCORE_WARM, CacheAffinity,
                                   rank_nodes)
from repro.sched.slurmsim import JobSpec, SlurmSim

JOB = Path(__file__).resolve().parent / "placement_jobs.py"
SRC = str(Path(__file__).resolve().parents[1] / "src")


def job_cmd(ckpt, rdir, total=3, **opts):
    cmd = [sys.executable, str(JOB), "--ckpt-dir", str(ckpt),
           "--report-dir", str(rdir), "--total-steps", str(total)]
    for k, v in opts.items():
        cmd += [f"--{k.replace('_', '-')}", str(v)]
    return cmd


def job_spec(ckpt, rdir, *, total=3, warm_wait_s=5.0, name="train", **opts):
    return JobSpec(
        name=name, cmd=job_cmd(ckpt, rdir, total=total, **opts),
        walltime_s=120, env={"PYTHONPATH": SRC},
        cache_affinity=CacheAffinity(ckpt_dir=str(ckpt),
                                     warm_wait_s=warm_wait_s))


def reports(rdir: Path) -> list[dict]:
    return [json.loads(p.read_text())
            for p in sorted(Path(rdir).glob("attempt_*.json"))]


def node_ckpt_root(sim: SlurmSim, name: str) -> Path:
    """A node's local-tier checkpoint prefix dir (local tier has one node
    dir, ``node0``, inside every cluster node's root)."""
    return sim.node(name).local_root / "local" / "node0" / "ckpt"


# ---------------------------------------------------------------------------
# headline: warm placement -> zero shared-tier restore bytes; blind does not
# ---------------------------------------------------------------------------

def test_warm_node_requeue_restores_zero_shared_bytes(tmp_path):
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    sim = SlurmSim(tmp_path / "sim", nodes=2)
    jid = sim.submit(job_spec(ckpt, rdir, total=3))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    assert rec.requeues == 2 and rec.exit_codes == [REQUEUE_EXIT] * 2 + [0]

    reps = reports(rdir)
    assert [r["attempt"] for r in reps] == [0, 1, 2]
    # every requeue went back to the warm node, and every warm restore was
    # served entirely from the node-local promoted cache
    assert rec.placements == ["node0"] * 3
    for r in reps[1:]:
        assert r["restore_stats"]["promoted"] is True
        assert r["restore_stats"]["tier"] == "local"
        assert r["restore_reads_by_tier"].get("shared", 0) == 0, r
        assert r["restore_reads_by_tier"].get("local", 0) > 0
    for entry in rec.placement_log[1:]:
        assert entry["scores"]["node0"] == SCORE_WARM
        assert entry["node"] == "node0"
    assert reps[-1]["state_sum"] == pytest.approx(expected_sum(3))


def test_blind_placement_baseline_reads_shared_bytes(tmp_path):
    """Round-robin (blind) placement requeues onto a cold node: correct, but
    every restore pays shared-filesystem bytes — the contrast that makes the
    placement policy measurable."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    sim = SlurmSim(tmp_path / "sim", nodes=2, placement="blind")
    jid = sim.submit(job_spec(ckpt, rdir, total=2))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    assert rec.placements == ["node0", "node1"]

    r1 = reports(rdir)[1]
    assert r1["node"] == "node1"
    assert not (r1["restore_stats"] or {}).get("promoted")
    assert r1["restore_reads_by_tier"].get("shared", 0) > 0
    assert r1["state_sum"] == pytest.approx(expected_sum(2))


# ---------------------------------------------------------------------------
# fault injection: every scenario must converge to a correct restart
# ---------------------------------------------------------------------------

def test_torn_marker_is_cold_not_fatal(tmp_path):
    """PROMOTED.json torn mid-write: the probe must read it as cold (not
    raise), placement falls back to the requeue hint, and the restore comes
    from the shared tier — never from the torn cache."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    torn = []

    def hook(rec):
        if rec.requeues == 1:
            marker = node_ckpt_root(sim, "node0") / "PROMOTED.json"
            faults.tear_json(marker)
            torn.append(str(marker))

    sim = SlurmSim(tmp_path / "sim", nodes=2, pre_launch=hook)
    jid = sim.submit(job_spec(ckpt, rdir, total=2, warm_wait_s=0.0))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert torn, "fault was never injected"
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)

    entry = rec.placement_log[1]
    assert entry["reasons"]["node0"] == "torn promoted marker"
    assert entry["scores"]["node0"] == SCORE_HINT      # hint, not warm
    r1 = reports(rdir)[1]
    assert not (r1["restore_stats"] or {}).get("promoted")
    assert r1["restore_reads_by_tier"].get("shared", 0) > 0
    assert reports(rdir)[-1]["state_sum"] == pytest.approx(expected_sum(2))


def test_truncated_promoted_shard_falls_back_to_shared(tmp_path):
    """Marker intact but a promoted shard is truncated, and the only node IS
    the damaged one (forced placement): the restore path must detect the
    damage, drop the cache, and restore correct bytes from shared."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    truncated = []

    def hook(rec):
        if rec.requeues == 1:
            shards = sorted(node_ckpt_root(sim, "node0").glob(
                "step_*/shard_*.bin"))
            assert shards, "no promoted shard to truncate"
            faults.truncate_file(shards[0])
            truncated.append(str(shards[0]))
            # the probe itself must notice the truncation too
            probe = validate_promoted_cache(TieredStore(
                Path(ckpt), tier_roots={"local": sim.node("node0").local_root}))
            assert not probe["valid"]
            assert probe["reason"].startswith("size mismatch")

    sim = SlurmSim(tmp_path / "sim", nodes=1, pre_launch=hook)
    jid = sim.submit(job_spec(ckpt, rdir, total=2, warm_wait_s=0.0))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert truncated, "fault was never injected"
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    r1 = reports(rdir)[1]
    assert not (r1["restore_stats"] or {}).get("promoted")
    assert r1["restore_reads_by_tier"].get("shared", 0) > 0
    assert reports(rdir)[-1]["state_sum"] == pytest.approx(expected_sum(2))


def test_mid_promotion_kill_leaves_no_marker_and_recovers(tmp_path):
    """The job dies (os._exit) while the promotion copier is mid-copy: the
    two-phase marker protocol must leave NO marker (only a torn .tmp), the
    next attempt probes cold, restores the committed step from shared, and
    the run converges bit-correct."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    observed = {}

    def hook(rec):
        if rec.requeues == 1:     # right after the mid-promotion death
            root = node_ckpt_root(sim, "node0")
            observed["marker_exists"] = (root / "PROMOTED.json").exists()
            observed["torn_tmps"] = [str(p) for p in root.rglob("*.tmp")]

    sim = SlurmSim(tmp_path / "sim", nodes=2, pre_launch=hook)
    jid = sim.submit(job_spec(ckpt, rdir, total=3, warm_wait_s=0.0,
                              mode="kill-mid-promotion", kill_on_attempt=0))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    assert rec.exit_codes[0] == REQUEUE_EXIT

    assert observed["marker_exists"] is False, "torn promotion published a marker"
    assert observed["torn_tmps"], "kill did not land mid-copy"
    assert rec.placement_log[1]["reasons"]["node0"] == "no promoted marker"
    reps = reports(rdir)
    # attempt 0 died before reporting; attempt 1 restored step 0 from shared
    assert reps[0]["attempt"] == 1 and reps[0]["start_step"] == 1
    assert reps[0]["restore_reads_by_tier"].get("shared", 0) > 0
    assert reps[-1]["state_sum"] == pytest.approx(expected_sum(3))


def test_stale_marker_is_never_served(tmp_path):
    """A newer step committed elsewhere supersedes node0's promoted cache:
    the probe must read it as stale and the restore must serve the NEW bytes
    — the restored checksum proves no stale bytes leaked."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    ext_tree = {k: np.full_like(v, 7.0) for k, v in make_tree().items()}
    injected = []

    def hook(rec):
        if rec.requeues == 1:
            ext = CheckpointManager(TieredStore(Path(ckpt)), CheckpointPolicy(replicas=1))
            ext.save(5, ext_tree)
            ext.commit(5)
            injected.append(5)

    sim = SlurmSim(tmp_path / "sim", nodes=2, pre_launch=hook)
    jid = sim.submit(job_spec(ckpt, rdir, total=2, warm_wait_s=0.0))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert injected and rec.state == "COMPLETED", (rec.state, rec.exit_codes)

    entry = rec.placement_log[1]
    assert entry["reasons"]["node0"].startswith("stale")
    assert entry["scores"]["node0"] == SCORE_HINT
    r1 = reports(rdir)[1]
    assert not (r1["restore_stats"] or {}).get("promoted")
    assert r1["state_sum"] == pytest.approx(state_sum(ext_tree))


# ---------------------------------------------------------------------------
# bounded wait-for-warm-node policy
# ---------------------------------------------------------------------------

def _warm_node0(sim: SlurmSim, ckpt: Path) -> None:
    """Promote a committed step into node0's local tier, in-process."""
    store = TieredStore(Path(ckpt),
                        tier_roots={"local": sim.node("node0").local_root})
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="eager"))
    m.save(0, make_tree())
    m.commit(0)
    m.wait_promotions()
    m.close()
    assert validate_promoted_cache(store)["valid"]


def _blocker_spec(seconds: float) -> JobSpec:
    return JobSpec(name="blocker",
                   cmd=[sys.executable, "-c",
                        f"import time; time.sleep({seconds})"],
                   walltime_s=60, requeue=False)


def test_bounded_wait_waits_for_busy_warm_node(tmp_path):
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    sim = SlurmSim(tmp_path / "sim", nodes=2)
    _warm_node0(sim, ckpt)
    sim.submit(_blocker_spec(1.2))                     # occupies node0
    jid = sim.submit(job_spec(ckpt, rdir, total=1, warm_wait_s=30.0))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    entry = rec.placement_log[0]
    assert entry["node"] == "node0" and entry["waited_s"] >= 0.5
    r0 = reports(rdir)[0]
    assert r0["restore_stats"]["promoted"] is True
    assert r0["restore_reads_by_tier"].get("shared", 0) == 0


def test_bounded_wait_expires_and_falls_back_to_peer_fetch(tmp_path):
    """The wait budget runs out with the warm node still busy: the job is
    placed COLD — but since PR 4 it is handed the warm node as a peer hint,
    so the 'cold' restore comes over the peer fabric (zero shared bytes)
    rather than from the shared filesystem.  The fully-cold shared read only
    remains when the fabric is off (asserted below)."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    sim = SlurmSim(tmp_path / "sim", nodes=2)
    _warm_node0(sim, ckpt)
    sim.submit(_blocker_spec(2.5))
    jid = sim.submit(job_spec(ckpt, rdir, total=1, warm_wait_s=0.15))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    entry = rec.placement_log[0]
    assert entry["node"] == "node1" and 0.15 <= entry["waited_s"] < 2.0
    assert entry["peers"] == ["node0"]
    r0 = reports(rdir)[0]
    assert not (r0["restore_stats"] or {}).get("promoted")
    assert (r0["restore_stats"] or {}).get("peer") is True
    assert r0["restore_reads_by_tier"].get("shared", 0) == 0, r0
    assert r0["peer_read_bytes"] > 0
    assert r0["state_sum"] == pytest.approx(state_sum(make_tree()))


def test_bounded_wait_expires_fabric_off_reads_shared(tmp_path):
    """Same expired-wait scenario with peer discovery disabled: the pre-
    fabric baseline — a cold placement pays shared-filesystem bytes."""
    ckpt, rdir = tmp_path / "ck", tmp_path / "reports"
    sim = SlurmSim(tmp_path / "sim", nodes=2)
    _warm_node0(sim, ckpt)
    sim.submit(_blocker_spec(2.5))
    jid = sim.submit(job_spec(ckpt, rdir, total=1, warm_wait_s=0.15,
                              peer_discovery="off"))
    sim.run(timeout_s=120)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    r0 = reports(rdir)[0]
    assert not (r0["restore_stats"] or {}).get("promoted")
    assert r0["restore_reads_by_tier"].get("shared", 0) > 0
    assert r0["state_sum"] == pytest.approx(state_sum(make_tree()))


# ---------------------------------------------------------------------------
# cache-inventory API + placement-hint round trip (in-process, no scheduler)
# ---------------------------------------------------------------------------

def test_cache_inventory_validation_states(tmp_path, rng):
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="eager", keep_last=10))
    tree = {"w": rng.standard_normal((256,)).astype(np.float32),
            "b": rng.standard_normal((64,)).astype(np.float32)}
    m.save(1, tree)
    m.commit(1)
    m.wait_promotions()
    inv = m.cache_inventory()
    assert inv["valid"] and inv["step"] == inv["latest"] == 1
    assert inv["reason"] == "warm" and inv["files"] >= 1

    # newer commit without promotion -> stale
    m_off = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="off", keep_last=10))
    m_off.save(2, tree)
    m_off.commit(2)
    inv = validate_promoted_cache(store)
    assert not inv["valid"] and inv["reason"].startswith("stale")
    assert inv["step"] == 1 and inv["latest"] == 2

    # re-promote the latest, then damage it in increasingly subtle ways
    m.prefetch_latest()
    m.wait_promotions()
    assert validate_promoted_cache(store)["valid"]
    shard = sorted((store.root / "local" / "node0" / "ckpt").glob(
        "step_*/shard_*.bin"))[-1]
    faults.truncate_file(shard)
    inv = validate_promoted_cache(store)
    assert not inv["valid"] and inv["reason"].startswith("size mismatch")
    shard.unlink()
    inv = validate_promoted_cache(store)
    assert not inv["valid"] and inv["reason"].startswith("missing promoted")
    faults.tear_json(store.root / "local" / "node0" / "ckpt" / "PROMOTED.json")
    inv = validate_promoted_cache(store)
    assert not inv["valid"] and inv["reason"] == "torn promoted marker"
    m.close()
    m_off.close()


def test_requeue_record_hint_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("SLURMSIM_NODE", "nodeX")
    rf = RequeueFile(tmp_path / "requeue.json")
    rec = rf.save(WalltimeTracker(limit_s=10), last_step=3, reason="test")
    assert rec["node"] == "nodeX" and rec["placements"] == ["nodeX"]

    aff = CacheAffinity(ckpt_dir=str(tmp_path))
    assert aff.requeue_record()["node"] == "nodeX"
    ranked = rank_nodes([("nodeX", tmp_path / "a"), ("nodeY", tmp_path / "b")],
                        aff)
    assert ranked["nodeX"]["score"] == SCORE_HINT
    assert ranked["nodeY"]["score"] == 0
