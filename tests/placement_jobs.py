"""Minimal checkpoint-restart job driven by tests/test_placement.py.

A numpy-only stand-in for launch/train.py (no jax import, so a full SlurmSim
requeue cycle costs milliseconds, not a jit compile): each "life" restores
the latest checkpoint (or cold-starts), advances a deterministic state a few
steps, commits, records the requeue file with its node identity, and exits 85
until the step budget is done.  Every life writes a JSON report — which node
it ran on, where its restore bytes came from (per tier), the restore-engine
stats, and a state checksum — that the test asserts placement behaviour
against.

Run as:  python tests/placement_jobs.py --ckpt-dir D --report-dir R \
             --total-steps 3 [--steps-per-life 1] [--promote eager] \
             [--mode kill-mid-promotion] [--kill-on-attempt 0]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

import faults
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import (TieredStore, is_peer_tier,
                                    node_local_tier_roots)
from repro.core.requeue import RequeueFile, WalltimeTracker, detect_node
from repro.sched.cache_registry import (ENV_PEER_ROOTS, REGISTRY_DIRNAME,
                                        CacheRegistry, parse_peer_roots)

REQUEUE_EXIT = 85


class CountingStore(faults.ByteCountingStoreMixin, TieredStore):
    """Counts every byte actually fetched, keyed by tier — the job-side
    evidence for the zero-shared-bytes placement assertions."""


def make_tree() -> dict:
    rng = np.random.default_rng(0)
    return {
        "w": rng.standard_normal((128, 64)).astype(np.float32),
        "b": rng.standard_normal((4096,)).astype(np.float32),
        "k": rng.standard_normal((16384,)).astype(np.float32),
    }


def advance(tree: dict) -> dict:
    """One deterministic 'training step'."""
    return {k: (v + 1.0).astype(v.dtype) for k, v in tree.items()}


def state_sum(tree: dict) -> float:
    return float(sum(np.asarray(v, np.float64).sum() for v in tree.values()))


def expected_sum(total_steps: int) -> float:
    """What ``state_sum`` must be after ``total_steps`` committed steps —
    the test-side oracle for 'no stale bytes were ever restored'."""
    tree = make_tree()
    base = state_sum(tree)
    n_elems = sum(np.asarray(v).size for v in tree.values())
    return base + total_steps * float(n_elems)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--report-dir", required=True)
    ap.add_argument("--total-steps", type=int, default=3)
    ap.add_argument("--steps-per-life", type=int, default=1)
    ap.add_argument("--promote", default="eager",
                    choices=["off", "on_restore", "eager"])
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--mode", default="normal",
                    choices=["normal", "kill-mid-promotion"])
    ap.add_argument("--kill-on-attempt", type=int, default=0)
    ap.add_argument("--peer-discovery", default="env",
                    choices=["env", "registry", "off"],
                    help="where warm-peer roots come from: the scheduler's "
                         "REPRO_PEER_ROOTS hint (env, default), the shared "
                         "CacheRegistry (registry), or nowhere (off) — the "
                         "blind-baseline tests need the fabric fully off")
    args = ap.parse_args(argv)

    node = detect_node() or "?"
    attempt = int(os.environ.get("SLURM_RESTART_COUNT", "0"))
    local_root = os.environ.get("REPRO_LOCAL_ROOT")
    tier_roots = node_local_tier_roots(local_root) if local_root else None
    store = CountingStore(Path(args.ckpt_dir), tier_roots=tier_roots, seed=0)
    peers = {}
    registry = None
    if args.peer_discovery == "env":
        peers = parse_peer_roots(os.environ.get(ENV_PEER_ROOTS))
    elif args.peer_discovery == "registry":
        registry = CacheRegistry(Path(args.ckpt_dir) / REGISTRY_DIRNAME)
    m = CheckpointManager(store, CheckpointPolicy(replicas=args.replicas, promote=args.promote),
                          peer_roots=peers, node=node, registry=registry)

    if args.mode == "kill-mid-promotion" and attempt == args.kill_on_attempt:
        # the promotion copier dies mid-copy: a torn .tmp file and NO marker
        # must be all it leaves behind (two-phase promotion)
        def torn_copy(src_tier, rel, dst_tier, **kw):
            src = store.replica_paths(src_tier, rel)[0]
            dst = store._node_dirs(dst_tier)[0] / rel
            dst.parent.mkdir(parents=True, exist_ok=True)
            raw = src.read_bytes()
            (dst.with_suffix(dst.suffix + ".tmp")).write_bytes(
                raw[: len(raw) // 2])
            os._exit(REQUEUE_EXIT)     # SIGKILL-equivalent node loss

        store.copy_file = torn_copy

    template = make_tree()
    restore_stats = None
    try:
        tree, man = m.restore(template)
        start = man["step"] + 1
        restore_stats = m.last_restore_stats
    except FileNotFoundError:
        tree = make_tree()
        start = 0
    restore_reads = dict(store.read_by_tier)

    last = start - 1
    for step in range(start, min(start + args.steps_per_life,
                                 args.total_steps)):
        tree = advance(tree)
        last = step
    if last >= start:
        m.save(last, tree)
        m.commit(last)
        m.wait_promotions()            # under kill mode this never returns
        rf = RequeueFile(Path(args.ckpt_dir) / "requeue.json")
        rf.save(WalltimeTracker(limit_s=1e9), last, reason="life-end",
                node=node)

    report = {
        "attempt": attempt,
        "node": node,
        "start_step": start,
        "last_step": last,
        "peer_roots": {n: str(p) for n, p in peers.items()},
        "restore_stats": restore_stats,
        "restore_reads_by_tier": restore_reads,
        "peer_read_bytes": sum(v for t, v in restore_reads.items()
                               if is_peer_tier(t)),
        "state_sum": state_sum(tree),
        "cache_inventory": m.cache_inventory(),
    }
    rdir = Path(args.report_dir)
    rdir.mkdir(parents=True, exist_ok=True)
    (rdir / f"attempt_{attempt:02d}.json").write_text(json.dumps(report))
    m.close()
    return 0 if last >= args.total_steps - 1 else REQUEUE_EXIT


if __name__ == "__main__":
    sys.exit(main())
