"""Signal trapping, walltime accounting, requeue records, slurmsim basics."""
import json
import os
import signal
import sys
import time


from repro.core.requeue import RequeueFile, WalltimeTracker
from repro.core.signals import SignalTrap
from repro.sched.slurmsim import REQUEUE_EXIT, JobSpec, SlurmSim


def test_signal_trap_sets_flag_only():
    with SignalTrap((signal.SIGUSR1,)) as trap:
        assert not trap.triggered
        os.kill(os.getpid(), signal.SIGUSR1)
        assert trap.wait(2.0)
        assert trap.received == signal.SIGUSR1
        trap.reset()
        assert not trap.triggered
    # handler restored after exit
    assert signal.getsignal(signal.SIGUSR1) != trap._handler


def test_walltime_tracker_margin_and_budget():
    t = WalltimeTracker(limit_s=0.2, margin_s=0.15, total_budget_s=0.5,
                        consumed_s=0.4)
    assert not t.budget_exhausted()
    time.sleep(0.11)
    assert t.near_limit()
    time.sleep(0.05)
    assert t.budget_exhausted()
    assert ":" in t.human()


def test_requeue_file_accumulates(tmp_path):
    rf = RequeueFile(tmp_path / "rq.json")
    t = WalltimeTracker(limit_s=100)
    time.sleep(0.02)
    rec1 = rf.save(t, last_step=5, reason="walltime")
    assert rec1["requeues"] == 1 and rec1["last_step"] == 5
    t2 = WalltimeTracker(limit_s=100, consumed_s=rec1["consumed_s"])
    rec2 = rf.save(t2, last_step=9)
    assert rec2["requeues"] == 2
    assert rec2["consumed_s"] >= rec1["consumed_s"]


def test_slurmsim_completion_and_failure(tmp_path):
    sim = SlurmSim(tmp_path)
    ok = sim.submit(JobSpec("ok", [sys.executable, "-c", "print('hi')"],
                            walltime_s=30, requeue=False))
    bad = sim.submit(JobSpec("bad", [sys.executable, "-c", "raise SystemExit(3)"],
                             walltime_s=30, requeue=False))
    sim.run(timeout_s=60)
    assert sim.job(ok).state == "COMPLETED"
    assert sim.job(bad).state == "FAILED"
    # append-mode output survives
    assert "hi" in (tmp_path / "ok.out").read_text()


def test_slurmsim_comment_walltime_survives_max_requeues(tmp_path):
    """The paper's --comment accounting: consumed walltime accumulates across
    every requeue cycle until max_requeues exhausts, is persisted in the
    comment file, and seeds a RESUBMITTED job even under a fresh SlurmSim."""
    prog = "import time, sys; time.sleep(0.2); sys.exit(85)"
    sim = SlurmSim(tmp_path)
    jid = sim.submit(JobSpec("acct", [sys.executable, "-c", prog],
                             walltime_s=30, max_requeues=2))
    sim.run(timeout_s=60)
    rec = sim.job(jid)
    # 3 attempts (initial + 2 requeues), then the budget is spent -> FAILED
    assert rec.state == "FAILED"
    assert rec.requeues == 2 and rec.exit_codes == [85, 85, 85]
    assert rec.consumed_s >= 3 * 0.2

    comment = json.loads((tmp_path / "acct.comment").read_text())
    assert comment["requeues"] == 2
    assert comment["consumed_s"] == rec.consumed_s
    assert len(comment["placements"]) == 3

    # a fresh scheduler resubmitting the same job resumes the accounting
    sim2 = SlurmSim(tmp_path)
    jid2 = sim2.submit(JobSpec("acct", [sys.executable, "-c", "pass"],
                               walltime_s=30))
    assert sim2.job(jid2).consumed_s == rec.consumed_s
    sim2.run(timeout_s=60)
    comment2 = json.loads((tmp_path / "acct.comment").read_text())
    assert comment2["consumed_s"] > rec.consumed_s


def test_slurmsim_comment_walltime_survives_manual_preempt(tmp_path):
    """scancel-style preemption must land in the same accounting: the
    preempted attempt's runtime is consumed walltime, not lost."""
    flag = tmp_path / "flag"
    prog = (
        "import sys, os, time; p=%r;\n"
        "sys.exit(0) if os.path.exists(p) "
        "else (open(p,'w').write('x'), time.sleep(30))"
    ) % str(flag)
    sim = SlurmSim(tmp_path)
    jid = sim.submit(JobSpec("pre", [sys.executable, "-c", prog],
                             walltime_s=60, max_requeues=3))
    import threading
    import time as _t

    def preempt_when_running():
        deadline = _t.monotonic() + 20
        while _t.monotonic() < deadline:
            if flag.exists() and sim.job(jid).state == "RUNNING":
                _t.sleep(0.3)          # accrue some measurable walltime
                sim.preempt(jid)
                return
            _t.sleep(0.02)

    th = threading.Thread(target=preempt_when_running, daemon=True)
    th.start()
    sim.run(timeout_s=120)
    th.join(timeout=5)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    assert rec.requeues == 1
    comment = json.loads((tmp_path / "pre.comment").read_text())
    assert comment["consumed_s"] >= 0.25       # preempted attempt counted
    assert comment["consumed_s"] == rec.consumed_s
    assert len(comment["placements"]) == 2


def test_slurmsim_requeue_on_85(tmp_path):
    # first attempt exits 85 (checkpointed), second completes — via a flag file
    prog = (
        "import sys, os; p='%s';\n"
        "sys.exit(0) if os.path.exists(p) else (open(p,'w').write('x'), sys.exit(85))"
    ) % (tmp_path / "flag")
    jid = sim_jid = None
    sim = SlurmSim(tmp_path)
    jid = sim.submit(JobSpec("rq", [sys.executable, "-c", prog], walltime_s=30))
    sim.run(timeout_s=60)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED" and rec.requeues == 1
    assert rec.exit_codes == [REQUEUE_EXIT, 0]
