"""Signal trapping, walltime accounting, requeue records, slurmsim basics."""
import os
import signal
import sys
import time


from repro.core.requeue import RequeueFile, WalltimeTracker
from repro.core.signals import SignalTrap
from repro.sched.slurmsim import REQUEUE_EXIT, JobSpec, SlurmSim


def test_signal_trap_sets_flag_only():
    with SignalTrap((signal.SIGUSR1,)) as trap:
        assert not trap.triggered
        os.kill(os.getpid(), signal.SIGUSR1)
        assert trap.wait(2.0)
        assert trap.received == signal.SIGUSR1
        trap.reset()
        assert not trap.triggered
    # handler restored after exit
    assert signal.getsignal(signal.SIGUSR1) != trap._handler


def test_walltime_tracker_margin_and_budget():
    t = WalltimeTracker(limit_s=0.2, margin_s=0.15, total_budget_s=0.5,
                        consumed_s=0.4)
    assert not t.budget_exhausted()
    time.sleep(0.11)
    assert t.near_limit()
    time.sleep(0.05)
    assert t.budget_exhausted()
    assert ":" in t.human()


def test_requeue_file_accumulates(tmp_path):
    rf = RequeueFile(tmp_path / "rq.json")
    t = WalltimeTracker(limit_s=100)
    time.sleep(0.02)
    rec1 = rf.save(t, last_step=5, reason="walltime")
    assert rec1["requeues"] == 1 and rec1["last_step"] == 5
    t2 = WalltimeTracker(limit_s=100, consumed_s=rec1["consumed_s"])
    rec2 = rf.save(t2, last_step=9)
    assert rec2["requeues"] == 2
    assert rec2["consumed_s"] >= rec1["consumed_s"]


def test_slurmsim_completion_and_failure(tmp_path):
    sim = SlurmSim(tmp_path)
    ok = sim.submit(JobSpec("ok", [sys.executable, "-c", "print('hi')"],
                            walltime_s=30, requeue=False))
    bad = sim.submit(JobSpec("bad", [sys.executable, "-c", "raise SystemExit(3)"],
                             walltime_s=30, requeue=False))
    sim.run(timeout_s=60)
    assert sim.job(ok).state == "COMPLETED"
    assert sim.job(bad).state == "FAILED"
    # append-mode output survives
    assert "hi" in (tmp_path / "ok.out").read_text()


def test_slurmsim_requeue_on_85(tmp_path):
    # first attempt exits 85 (checkpointed), second completes — via a flag file
    prog = (
        "import sys, os; p='%s';\n"
        "sys.exit(0) if os.path.exists(p) else (open(p,'w').write('x'), sys.exit(85))"
    ) % (tmp_path / "flag")
    jid = sim_jid = None
    sim = SlurmSim(tmp_path)
    jid = sim.submit(JobSpec("rq", [sys.executable, "-c", prog], walltime_s=30))
    sim.run(timeout_s=60)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED" and rec.requeues == 1
    assert rec.exit_codes == [REQUEUE_EXIT, 0]
