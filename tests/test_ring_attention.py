"""Ring attention == ref oracle, on a real multi-device mesh (subprocess)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.kernels import ref
from repro.kernels.ring_attention import ring_attention

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
for (B, S, H, Hkv, D) in [(2, 64, 4, 2, 32), (4, 128, 14, 2, 16), (2, 64, 4, 4, 64)]:
    q = jnp.asarray(rng.standard_normal((B, S, H, D), np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D), np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D), np.float32))
    with mesh:
        out = jax.jit(lambda q, k, v: ring_attention(q, k, v, mesh=mesh))(q, k, v)
    want = ref.attention(q, k, v, causal=True)
    err = float(jnp.abs(out - want).max())
    print(f"B{B} S{S} H{H}/{Hkv}: err={err:.2e}")
    assert err < 2e-5, err

# gradient flows through the ring (fori_loop -> scan, ppermute transpose)
B, S, H, D = 2, 64, 4, 32
q = jnp.asarray(rng.standard_normal((B, S, H, D), np.float32))
k = jnp.asarray(rng.standard_normal((B, S, H, D), np.float32))
v = jnp.asarray(rng.standard_normal((B, S, H, D), np.float32))
def loss_ring(q, k, v):
    return jnp.sum(ring_attention(q, k, v, mesh=mesh) ** 2)
def loss_ref(q, k, v):
    return jnp.sum(ref.attention(q, k, v, causal=True).astype(jnp.float32) ** 2)
with mesh:
    g1 = jax.jit(jax.grad(loss_ring, argnums=(0, 1, 2)))(q, k, v)
g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
for a, b in zip(g1, g2):
    err = float(jnp.abs(a - b).max())
    print("grad err", err)
    assert err < 5e-4, err
print("RING OK")
"""


@pytest.mark.slow
def test_ring_attention_subprocess():
    env = {**os.environ, "PYTHONPATH": SRC}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PROG], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "RING OK" in r.stdout
