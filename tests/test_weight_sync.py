"""Serving-fleet weight distribution: ParamHandle double-buffering,
WeightSyncClient delta fetches over the chunk fabric, the registry push
plane, and the --max-lag-steps staleness gate.

Everything except the engine boundary test drives numpy trees — the sync
protocol is deliberately jax-free.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import (TieredStore, is_peer_tier,
                                    node_local_tier_roots)
from repro.sched.cache_registry import CacheRegistry
from repro.serve.weight_sync import (ParamHandle, StaleReplicaError,
                                     WeightSyncClient)

CHUNK = 1 << 16


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _tree(rng, n_leaves=4, elems=70_000):
    return {f"l{i}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}


def _mutate(tree, names, delta=1.0, elems=100):
    out = dict(tree)
    for k in names:
        a = out[k].copy()
        a[:elems] += delta
        out[k] = a
    return out


def _assert_trees_equal(got, want):
    flat_g = dict(SER.flatten_with_names(got))
    flat_w = dict(SER.flatten_with_names(want))
    assert set(flat_g) == set(flat_w)
    for k in flat_w:
        np.testing.assert_array_equal(flat_g[k], flat_w[k])


def _pol(**kw):
    base = dict(replicas=1, delta=True, chunk_bytes=CHUNK)
    base.update(kw)
    return CheckpointPolicy(**base)


class Fabric:
    """One shared checkpoint root + registry; publisher eager-promotes so
    the fleet can fetch deltas peer-to-peer (the bench topology)."""

    def __init__(self, root):
        self.root = root
        self.registry = CacheRegistry(root / "registry")

    def store_for(self, node):
        return TieredStore(
            self.root / "ck", seed=0,
            tier_roots=node_local_tier_roots(self.root / "nodes" / node))

    def publisher(self):
        return CheckpointManager(self.store_for("pub"),
                                 _pol(promote="eager"),
                                 node="pub", registry=self.registry)

    def replica_manager(self, name):
        return CheckpointManager(self.store_for(name),
                                 _pol(promote="on_restore"),
                                 node=name, registry=self.registry)

    def push(self, pub, step, tree):
        pub.save(step, tree)
        man = pub.commit(step)
        pub.wait_promotions()
        self.registry.announce_push(
            step=step, node="pub",
            manifest_version=man.get("manifest_version"))
        return man


# ---------------------------------------------------------------------------
# ParamHandle: the double buffer itself
# ---------------------------------------------------------------------------

def test_param_handle_stage_supersede_and_flip(rng):
    t1, t2, t3 = ({"w": rng.standard_normal(8)} for _ in range(3))
    h = ParamHandle(t1, step=1)
    cur = h.current
    assert h.step == 1 and h.pending_step is None and h.newest_step == 1
    assert not h.commit_pending()                  # nothing staged: no-op

    h.stage(t2, 2)
    assert h.current is cur, "staging must not touch the served tree"
    assert h.step == 1 and h.pending_step == 2 and h.newest_step == 2

    h.stage(t3, 3)                                 # newer push supersedes
    assert h.pending_step == 3
    assert h.commit_pending()
    assert h.current is t3 and h.step == 3 and h.pending_step is None
    assert h.swap_count == 1
    assert not h.commit_pending()                  # drained


# ---------------------------------------------------------------------------
# the headline: a warm-but-stale follower fetches EXACTLY the delta, with
# zero shared-tier bytes, and never promotes/invalidates anything
# ---------------------------------------------------------------------------

def test_stale_follower_fetches_delta_with_zero_shared_bytes(tmp_path, rng):
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)

    mgr = fab.replica_manager("r0")
    host, man = mgr.restore(tree1)                 # warm-up: promotes step 1
    mgr.wait_promotions()
    assert man["step"] == 1
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1,
                              registry=fab.registry, replica="r0")
    assert client.lag() == 0 and client.sync_once() is None

    # the push: one leaf changes -> one delta chunk set
    tree2 = _mutate(tree1, ["l0"])
    save_stats = pub.save(2, tree2)
    man2 = pub.commit(2)
    pub.wait_promotions()
    fab.registry.announce_push(step=2, node="pub")
    delta_bytes = save_stats["delta"]["bytes_written"]

    rec = client.sync_once()
    assert rec is not None and rec["step"] == 2 and rec["from_step"] == 1
    by_tier = rec["bytes_by_tier"]
    assert by_tier.get("shared", 0) == 0, by_tier  # fabric, not the pfs
    peer_bytes = sum(v for t, v in by_tier.items() if is_peer_tier(t))
    assert 0 < peer_bytes <= 2 * delta_bytes, (peer_bytes, delta_bytes)
    assert by_tier.get("local", 0) > 0             # unchanged chunks: own cache
    assert rec["delta"] and rec["manifest_version"] == 2

    # decode-visible state is untouched until the boundary swap
    assert handle.step == 1 and handle.pending_step == 2
    _assert_trees_equal(handle.current, tree1)
    assert handle.commit_pending()
    _assert_trees_equal(handle.current, tree2)
    assert handle.step == man2["step"] == 2

    # READ-ONLY follower: the fetch must not have promoted step 2 into (or
    # invalidated) the node cache another process may be serving from
    marker = json.loads(
        (fab.root / "nodes" / "r0" / "local" / "node0" / "ckpt"
         / "PROMOTED.json").read_text())
    assert marker["step"] == 1
    mgr.close()
    pub.close()


def test_second_sync_is_idempotent_and_history_records(tmp_path, rng):
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1,
                              registry=fab.registry, replica="r0")

    tree2 = _mutate(tree1, ["l1"])
    fab.push(pub, 2, tree2)
    assert client.sync_once() is not None
    # staged counts as "have": a second poll before the swap must not refetch
    assert client.lag() == 0 and client.sync_once() is None
    assert len(client.history) == 1
    handle.commit_pending()
    _assert_trees_equal(handle.current, tree2)
    mgr.close()
    pub.close()


# ---------------------------------------------------------------------------
# the registry push plane
# ---------------------------------------------------------------------------

def test_push_plane_announce_latest_and_replica_status(tmp_path):
    reg = CacheRegistry(tmp_path / "registry")
    assert reg.latest_push() is None
    reg.announce_push(step=3, node="pub", manifest_version=2)
    reg.announce_push(step=5, node="pub")
    ann = reg.latest_push()
    assert ann["step"] == 5 and ann["node"] == "pub"

    reg.publish_replica("r0", step=5, phase="serving")
    reg.publish_replica("r1", step=3, target_step=5, phase="fetching")
    status = reg.replica_status()
    assert status["r0"]["lag"] == 0
    assert status["r1"]["lag"] == 2 and status["r1"]["phase"] == "fetching"


def test_torn_push_announcement_reads_as_absent(tmp_path):
    reg = CacheRegistry(tmp_path / "registry")
    reg.announce_push(step=1, node="pub")
    (tmp_path / "registry" / "PUSH.json").write_text("{torn")
    assert reg.latest_push() is None               # advisory plane: no crash


# ---------------------------------------------------------------------------
# staleness gate (--max-lag-steps)
# ---------------------------------------------------------------------------

def test_max_lag_gate_forces_swap_when_exceeded(tmp_path, rng):
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1, registry=fab.registry,
                              replica="r0", max_lag_steps=1)

    # within the bound: the gate is a no-op (no fetch, no swap)
    tree2 = _mutate(tree1, ["l0"])
    fab.push(pub, 2, tree2)
    assert client.lag() == 1
    assert client.ensure_fresh() == 1 and handle.step == 1

    # past the bound: the gate fetches AND swaps at this boundary
    tree3 = _mutate(tree2, ["l1"])
    fab.push(pub, 3, tree3)
    assert client.lag() == 2
    assert client.ensure_fresh() == 0
    assert handle.step == 3
    _assert_trees_equal(handle.current, tree3)
    mgr.close()
    pub.close()


def test_max_lag_gate_fails_replica_under_paused_publisher(tmp_path, rng):
    # the publisher ANNOUNCED a step it never committed (crashed mid-push):
    # the replica keeps serving within the bound; with on_stale="raise" it
    # fails out of rotation — rather than serving unboundedly stale
    # weights — once past it (the drain path is tested below)
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1, registry=fab.registry,
                              replica="r0", max_lag_steps=2,
                              on_stale="raise")

    fab.registry.announce_push(step=9, node="pub")  # never committed
    assert client.sync_once() is None               # keeps serving step 1
    assert handle.step == 1
    with pytest.raises(StaleReplicaError, match="behind"):
        client.ensure_fresh()
    assert fab.registry.replica_status()["r0"]["phase"] == "stalled"

    # no bound configured -> the same situation never raises
    client.max_lag_steps = None
    assert client.ensure_fresh() == 8
    mgr.close()
    pub.close()


def test_follow_loop_applies_pushes(tmp_path, rng):
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1,
                              registry=fab.registry, replica="r0")
    tree2 = _mutate(tree1, ["l2"])
    fab.push(pub, 2, tree2)
    seen = []
    n = client.follow(poll_s=0.01, max_polls=3, on_sync=seen.append)
    assert n == 1 and [r["step"] for r in seen] == [2]
    assert handle.pending_step == 2                # swap stays engine-owned
    mgr.close()
    pub.close()


# ---------------------------------------------------------------------------
# draining admission control (on_stale="drain", the default)
# ---------------------------------------------------------------------------

def test_drain_and_readmit_under_paused_publisher(tmp_path, rng):
    # same paused-publisher situation as above, default policy: the replica
    # DRAINS (refuses new admissions, keeps serving what it started, shows
    # "draining" fleet-wide) instead of raising mid-batch, and re-admits on
    # the first boundary after it catches up
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1, registry=fab.registry,
                              replica="r0", max_lag_steps=2)

    assert client.admit() and not client.draining   # healthy: admitted

    fab.registry.announce_push(step=9, node="pub")  # never committed
    assert client.ensure_fresh() == 8               # returns lag, no raise
    assert client.draining and client.drain_count == 1
    assert not client.admit()                       # new work refused
    assert client.admit() is False                  # stays draining...
    assert client.drain_count == 1                  # ...but counted ONCE
    assert fab.registry.replica_status()["r0"]["phase"] == "draining"
    assert handle.step == 1                         # still serving step 1

    # the publisher recovers and actually commits step 9
    tree9 = _mutate(tree1, ["l0", "l3"])
    fab.push(pub, 9, tree9)
    assert client.admit()                           # caught up: re-admitted
    assert not client.draining and client.readmit_count == 1
    assert handle.step == 9                         # gate forced the swap
    _assert_trees_equal(handle.current, tree9)
    assert fab.registry.replica_status()["r0"]["phase"] == "serving"
    mgr.close()
    pub.close()


def test_engine_admit_gates_on_sync_client(tmp_path, rng):
    # Engine.admit() without a sync client is always True; with one it
    # mirrors the client's drain state (numpy-only: build Engine lazily
    # via object.__new__ to skip jit compilation)
    from repro.serve.engine import Engine

    eng = object.__new__(Engine)
    eng.sync_client = None
    assert eng.admit()

    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1, registry=fab.registry,
                              replica="r0", max_lag_steps=1)
    eng.sync_client = client
    assert eng.admit()
    fab.registry.announce_push(step=7, node="pub")  # uncommitted: can't close
    assert not eng.admit()
    mgr.close()
    pub.close()


# ---------------------------------------------------------------------------
# thread safety: follow() thread + boundary ensure_fresh() must never
# double-fetch one step or tear history
# ---------------------------------------------------------------------------

def test_sync_once_thread_safe_no_double_fetch(tmp_path, rng):
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)
    client = WeightSyncClient(mgr, handle, tree1, registry=fab.registry,
                              replica="r0", max_lag_steps=0)

    # a slow, concurrency-counting restore: without the sync lock both
    # threads pass the "target <= have" check before either stages, and
    # the step is fetched twice
    orig = mgr.restore
    calls = {"n": 0, "live": 0, "max_live": 0}
    mu = threading.Lock()

    def slow_restore(*a, **kw):
        with mu:
            calls["n"] += 1
            calls["live"] += 1
            calls["max_live"] = max(calls["max_live"], calls["live"])
        try:
            time.sleep(0.05)
            return orig(*a, **kw)
        finally:
            with mu:
                calls["live"] -= 1
    mgr.restore = slow_restore

    tree2 = _mutate(tree1, ["l0"])
    fab.push(pub, 2, tree2)
    start = threading.Barrier(2)
    errs = []

    def worker(fn):
        try:
            start.wait()
            fn()
        except Exception as e:                      # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(client.sync_once,)),
          threading.Thread(target=worker, args=(client.ensure_fresh,))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    assert calls["n"] == 1, "the same step was fetched twice"
    assert calls["max_live"] == 1, "restores overlapped"
    assert [r["step"] for r in client.history] == [2]
    handle.commit_pending()
    _assert_trees_equal(handle.current, tree2)
    mgr.close()
    pub.close()


# ---------------------------------------------------------------------------
# registry fixes: negative-lag clamp + unique-tmp atomic writes
# ---------------------------------------------------------------------------

def test_replica_status_clamps_replica_ahead_of_announcement(tmp_path):
    reg = CacheRegistry(tmp_path / "registry")
    reg.announce_push(step=3, node="pub")           # stale announcement
    reg.publish_replica("r0", step=5, phase="serving")  # replica is AHEAD
    status = reg.replica_status()
    # must agree with WeightSyncClient.lag()'s max(0, ...) clamp, not -2
    assert status["r0"]["lag"] == 0


def test_registry_atomic_writes_survive_concurrent_writers(tmp_path):
    # the old fixed `<name>.json.tmp` path let two writers interleave
    # write/rename: one renames the other's half-written tmp (or crashes on
    # a vanished tmp), publishing torn-in-content JSON.  With mkstemp each
    # writer renames only bytes it wrote in full.
    reg = CacheRegistry(tmp_path / "registry")
    stop = threading.Event()
    errs: list = []
    torn: list = []

    def writer(tid):
        try:
            for i in range(200):
                reg.announce_push(step=i, node=f"w{tid}")
        except Exception as e:                      # noqa: BLE001
            errs.append(e)
        finally:
            stop.set()

    def reader():
        while not stop.is_set():
            ann = reg.latest_push()
            if ann is not None and ann["node"] not in ("w0", "w1"):
                torn.append(ann)

    ts = [threading.Thread(target=writer, args=(i,)) for i in range(2)]
    rt = threading.Thread(target=reader)
    rt.start()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    stop.set()
    rt.join()
    assert not errs, errs
    assert not torn, torn
    assert reg.latest_push() is not None            # final entry parseable
    assert not list((tmp_path / "registry").glob("*.tmp"))  # none leaked


# ---------------------------------------------------------------------------
# pipelined device upload: to_native of push N overlaps the next fetch
# ---------------------------------------------------------------------------

def test_pipelined_upload_counts_inflight_and_stages_in_order(tmp_path, rng):
    fab = Fabric(tmp_path)
    pub = fab.publisher()
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)
    mgr = fab.replica_manager("r0")
    host, _ = mgr.restore(tree1)
    mgr.wait_promotions()
    handle = ParamHandle(host, step=1)

    uploaded = []
    gate = threading.Event()

    def slow_to_native(tree):                       # a fake device upload
        gate.wait(5.0)
        uploaded.append(threading.current_thread().name)
        return tree

    client = WeightSyncClient(mgr, handle, tree1, registry=fab.registry,
                              replica="r0", to_native=slow_to_native,
                              pipeline_uploads=True)

    tree2 = _mutate(tree1, ["l0"])
    fab.push(pub, 2, tree2)
    rec = client.sync_once()                        # returns BEFORE upload
    assert rec is not None and rec["pipelined"]
    assert not uploaded                             # upload still in flight
    assert client.lag() == 0, "in-flight upload must count as have"
    assert client.sync_once() is None               # and dedup the poll

    tree3 = _mutate(tree2, ["l1"])
    fab.push(pub, 3, tree3)
    assert client.sync_once()["step"] == 3          # fetch overlaps upload 2
    gate.set()
    client.wait_uploads()
    assert len(uploaded) == 2
    assert all("weight-upload" in n for n in uploaded)
    handle.commit_pending()
    _assert_trees_equal(handle.current, tree3)      # ordered: 3 supersedes 2
    client.close()
    mgr.close()
    pub.close()


# ---------------------------------------------------------------------------
# the tentpole, single-process: a follower that synced step N advertises its
# chunk inventory, and the NEXT replica pulls the delta from it — zero
# shared-tier bytes
# ---------------------------------------------------------------------------

def test_follower_advertises_and_serves_next_replica(tmp_path, rng):
    fab = Fabric(tmp_path)
    # publisher never promotes: the ONLY non-shared source any replica can
    # use is another replica's follower cache
    pub = CheckpointManager(fab.store_for("pub"), _pol(promote="off"),
                            node="pub", registry=fab.registry)
    tree1 = _tree(rng)
    fab.push(pub, 1, tree1)

    mgr1 = fab.replica_manager("r1")
    host1, _ = mgr1.restore(tree1, 1, promote=False, follower_cache=True)
    st1 = mgr1.last_restore_stats
    assert st1["follower_advertised"] and st1["chunks_teed"] > 0
    ent = fab.registry.follower_entries()
    assert ent["r1"]["step"] == 1 and ent["r1"]["kind"] == "follower"
    # chunk-only entries never reach the shard fabric's source list
    assert "r1" not in fab.registry.warm_peers(1)
    assert "r1" in fab.registry.near_peers(1)

    handle1 = ParamHandle(host1, step=1)
    client1 = WeightSyncClient(mgr1, handle1, tree1, registry=fab.registry,
                               replica="r1")
    tree2 = _mutate(tree1, ["l0"])
    save_stats = pub.save(2, tree2)
    fab.push(pub, 2, tree2)
    delta_bytes = save_stats["delta"]["bytes_written"]
    rec1 = client1.sync_once()
    assert rec1["follower_advertised"]
    assert fab.registry.follower_entries()["r1"]["step"] == 2

    # replica 2, cold on this node family: the whole step-2 fetch must be
    # served by r1's follower cache — zero shared-tier payload bytes
    mgr2 = fab.replica_manager("r2")
    host2, _ = mgr2.restore(tree1, 2, promote=False, follower_cache=True)
    st2 = mgr2.last_restore_stats
    by_tier = st2["bytes_by_tier"]
    assert by_tier.get("shared", 0) == 0, by_tier
    peer_bytes = sum(v for t, v in by_tier.items() if is_peer_tier(t))
    assert peer_bytes > delta_bytes                 # full tree, from r1
    _assert_trees_equal(host2, tree2)
    assert fab.registry.follower_entries()["r2"]["step"] == 2

    # invalidation withdraws the follower entry with the cache
    mgr2.invalidate_promoted()
    assert "r2" not in fab.registry.follower_entries()
    mgr1.close()
    mgr2.close()
    pub.close()


# ---------------------------------------------------------------------------
# engine boundary: a push staged MID-DECODE never tears the loop — all n
# tokens come from one tree, and the swap lands at the next boundary
# ---------------------------------------------------------------------------

def test_engine_swap_never_tears_mid_decode(rng):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.serve.engine import Engine

    cfg = reduced(get_config("llama3.2-1b")).replace(num_layers=2)
    mesh = make_host_mesh()
    batch, prompt, max_seq = 2, 8, 32
    p1 = M.init_params(cfg, jax.random.PRNGKey(0))
    p2 = M.init_params(cfg, jax.random.PRNGKey(1))
    shape = ((batch, prompt, cfg.num_codebooks) if cfg.num_codebooks
             else (batch, prompt))
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, shape), jnp.int32)}

    # reference: uninterrupted generation on p1
    ref = Engine(cfg, mesh, p1, batch=batch, max_seq=max_seq)
    ref.prefill(prompts)
    ref_tokens = ref.generate(8)

    # follower engine: p2 lands mid-loop via on_token (the sync thread's
    # stage), and MUST NOT affect the remaining tokens of this call
    handle = ParamHandle(p1, step=1)
    eng = Engine(cfg, mesh, handle, batch=batch, max_seq=max_seq)
    eng.prefill(prompts)

    def stage_midway(tok, _calls=[]):
        _calls.append(tok)
        if len(_calls) == 2:
            handle.stage(p2, 2)

    first = eng.generate(4, on_token=stage_midway)
    np.testing.assert_array_equal(first, ref_tokens[:, :4])
    assert handle.step == 1 and handle.pending_step == 2

    # host-roundtrip the cache so the donated device buffers are not shared
    # between the two continuations
    snap_host = jax.tree_util.tree_map(np.asarray, eng.snapshot())

    # continuation AFTER the boundary: byte-identical to an engine that was
    # born on p2 and restored at the same point
    rest = eng.generate(4)             # maybe_swap() flips to p2 here
    assert handle.step == 2 and handle.swap_count == 1

    eng2 = Engine(cfg, mesh, p2, batch=batch, max_seq=max_seq)
    eng2.restore(jax.tree_util.tree_map(jnp.asarray, snap_host))
    rest_ref = eng2.generate(4)
    np.testing.assert_array_equal(rest, rest_ref)
