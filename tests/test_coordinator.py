"""Coordinator protocol: two-phase commit, straggler timeout, worker-death
abort, EXIT_REQ propagation — workers are real threads over real TCP sockets."""
import threading
import time

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import TieredStore
from repro.core.coordinator import CheckpointCoordinator
from repro.core.worker import CkptClient


class WorkerThread(threading.Thread):
    """Tiny 'training loop': counts steps, services checkpoint rounds."""

    def __init__(self, host, port, wid, store, num_workers, *, save_delay=0.0,
                 die_during_save=False, steps=400):
        super().__init__(daemon=True)
        self.client = CkptClient(host, port, wid)
        self.mgr = CheckpointManager(store, worker_id=wid, num_workers=num_workers)
        self.save_delay = save_delay
        self.die_during_save = die_during_save
        self.steps = steps
        self.state = {"w": np.arange(10, dtype=np.float32) * (wid + 1)}
        self.serviced = []
        self.error = None

    def run(self):
        try:
            for step in range(self.steps):
                time.sleep(0.003)  # "train"
                if self.client.exit_requested:
                    return

                def save(label):
                    if self.die_during_save:
                        self.client.close()          # simulated node death
                        raise RuntimeError("node died")
                    time.sleep(self.save_delay)
                    return self.mgr.save(label, self.state)

                out = self.client.service(step, save)
                if out is not None:
                    self.serviced.append(out)
        except Exception as e:  # noqa: BLE001
            self.error = e


@pytest.fixture
def store(tmp_path):
    return TieredStore(tmp_path)


def _mk(store, n, straggler_timeout=15.0):
    mgr0 = CheckpointManager(store, worker_id=0, num_workers=n)
    coord = CheckpointCoordinator(
        expected_workers=n, straggler_timeout=straggler_timeout,
        commit_fn=mgr0.commit)
    return coord


def test_two_phase_commit_happy_path(store):
    n = 3
    coord = _mk(store, n)
    workers = [WorkerThread(coord.host, coord.port, w, store, n) for w in range(n)]
    for w in workers:
        w.start()
    coord.wait_for_workers(n)
    rec = coord.trigger_checkpoint(step=7, reason="test")
    assert rec["ok"], rec
    # every worker observed COMMIT
    time.sleep(0.2)
    mgr = CheckpointManager(store, num_workers=n)
    out, man = mgr.restore({"w": np.zeros(10, np.float32)})
    assert man["step"] == 7 and man["num_workers"] == n
    coord.request_exit("done")
    for w in workers:
        w.join(timeout=10)
        assert w.error is None
    coord.close()


def test_straggler_timeout_aborts(store):
    n = 2
    coord = _mk(store, n, straggler_timeout=0.5)
    w0 = WorkerThread(coord.host, coord.port, 0, store, n)
    w1 = WorkerThread(coord.host, coord.port, 1, store, n, save_delay=5.0)
    w0.start(); w1.start()
    coord.wait_for_workers(n)
    rec = coord.trigger_checkpoint(step=3)
    assert not rec["ok"] and "barrier failed" in rec["error"]
    # no manifest must exist (abort => previous checkpoint stays authoritative)
    mgr = CheckpointManager(store, num_workers=n)
    assert mgr.steps() == []
    coord.request_exit("done")
    w0.join(timeout=10); w1.join(timeout=10)
    coord.close()


def test_worker_death_aborts_round(store):
    n = 2
    coord = _mk(store, n)
    w0 = WorkerThread(coord.host, coord.port, 0, store, n)
    w1 = WorkerThread(coord.host, coord.port, 1, store, n, die_during_save=True)
    w0.start(); w1.start()
    coord.wait_for_workers(n)
    rec = coord.trigger_checkpoint(step=4)
    assert not rec["ok"]
    mgr = CheckpointManager(store, num_workers=n)
    assert mgr.steps() == []
    coord.request_exit("done")
    w0.join(timeout=10)
    coord.close()


def test_exit_request_propagates(store):
    n = 2
    coord = _mk(store, n)
    workers = [WorkerThread(coord.host, coord.port, w, store, n, steps=10_000)
               for w in range(n)]
    for w in workers:
        w.start()
    coord.wait_for_workers(n)
    coord.request_exit("preemption")
    for w in workers:
        w.join(timeout=10)
        assert not w.is_alive()
        assert w.client.exit_reason == "preemption"
    coord.close()


def test_interval_trigger(store):
    n = 1
    mgr0 = CheckpointManager(store, worker_id=0, num_workers=n)
    coord = CheckpointCoordinator(expected_workers=n, interval_s=0.4,
                                  commit_fn=mgr0.commit, straggler_timeout=10)
    w = WorkerThread(coord.host, coord.port, 0, store, n, steps=10_000)
    w.start()
    coord.wait_for_workers(1)
    time.sleep(1.5)
    coord.request_exit("done")
    w.join(timeout=10)
    ok_rounds = [h for h in coord.history if h.get("ok")]
    assert len(ok_rounds) >= 2, coord.history
    coord.close()
