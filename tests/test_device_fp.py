"""Device-resident dirty detection (``CheckpointPolicy.device_fp``).

The invariant everything here guards: the device path is a pure
OPTIMIZATION of the host delta path — same chunk hashes, same manifests,
same restored bytes — whose only observable difference is the
device->host accounting (``d2h_bytes`` tracks the churn, not the model
size).  The word-stream and fingerprint layers are checked against the
host serialization oracle bit-for-bit (including the Pallas kernel in
interpret mode), then whole save chains are compared end to end.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import serialization as SER  # noqa: E402
from repro.checkpoint.manager import (CheckpointManager,  # noqa: E402
                                      CheckpointPolicy)
from repro.checkpoint.store import TieredStore  # noqa: E402
from repro.kernels import ops  # noqa: E402

CHUNK = 256                       # 64 words: power of two for the kernel


def _words_oracle(a) -> np.ndarray:
    """The host-side convention: little-endian payload bytes, zero-padded
    to a word boundary, viewed <u4."""
    b = np.asarray(a).tobytes()
    pad = (-len(b)) % 4
    return np.frombuffer(b + b"\0" * pad, dtype="<u4")


# ---------------------------------------------------------------------------
# layer 1: leaf_words == the host byte view, for every dtype width
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,n", [
    (jnp.float32, 33), (jnp.int32, 7), (jnp.uint32, 8),
    (jnp.float16, 9), (jnp.bfloat16, 10), (jnp.uint16, 11),
    (jnp.int8, 7), (jnp.uint8, 13), (jnp.bool_, 11),
    (jnp.float32, 0),
])
def test_leaf_words_matches_host_view(dtype, n):
    rng = np.random.default_rng(3)
    raw = rng.integers(0, 200, size=n)
    if dtype == jnp.bool_:
        x = jnp.asarray(raw % 2 == 0)
    else:
        x = jnp.asarray(raw).astype(dtype)
    got = np.asarray(ops.leaf_words(x))
    np.testing.assert_array_equal(got, _words_oracle(x))


def test_leaf_words_scalar_and_numpy_paths():
    # 0-d jax leaf
    np.testing.assert_array_equal(
        np.asarray(ops.leaf_words(jnp.float32(1.5))),
        _words_oracle(jnp.float32(1.5)))
    # numpy fast path keeps float64 bit-exact (jnp would downcast with
    # x64 disabled) and handles 0-d / odd-length tails
    rng = np.random.default_rng(4)
    for a in (rng.standard_normal(5),                 # f64
              np.float64(2.75),                       # 0-d
              rng.integers(0, 9, 7).astype(np.int8),  # 7 bytes -> pad
              np.zeros(0, np.float32)):
        np.testing.assert_array_equal(np.asarray(ops.leaf_words(a)),
                                      _words_oracle(a))


# ---------------------------------------------------------------------------
# layer 2: tree_chunk_fingerprints == serialization.fingerprint_chunks
# ---------------------------------------------------------------------------

def _fp_tree():
    rng = np.random.default_rng(5)
    return [
        ("aligned", jnp.asarray(                      # exact chunk multiple
            rng.standard_normal(CHUNK // 4 * 3).astype(np.float32))),
        ("ragged", jnp.asarray(                       # ragged word tail
            rng.standard_normal(CHUNK // 4 + 5).astype(np.float32))),
        ("bytes", jnp.asarray(                        # tail not %4 bytes
            rng.integers(0, 100, CHUNK + 7).astype(np.int8))),
        ("tiny", jnp.asarray(rng.standard_normal(3).astype(np.float32))),
        ("empty", jnp.zeros((0,), jnp.float32)),      # zero-byte leaf
        ("host64", rng.standard_normal(CHUNK // 8 + 1)),   # numpy f64
    ]


@pytest.mark.parametrize("impl", ["auto", "pallas_interpret"])
def test_tree_chunk_fingerprints_matches_serialization(impl):
    leaves = _fp_tree()
    got = ops.tree_chunk_fingerprints(leaves, CHUNK, impl=impl)
    assert set(got) == {name for name, _ in leaves}
    for name, leaf in leaves:
        want = SER.fingerprint_chunks(np.asarray(leaf).tobytes(), CHUNK)
        np.testing.assert_array_equal(
            got[name], want, err_msg=f"leaf {name} ({impl})")
        assert got[name].dtype == np.uint32


def test_policy_device_fp_validation():
    with pytest.raises(ValueError, match="requires delta"):
        CheckpointPolicy(device_fp=True)
    with pytest.raises(ValueError, match="power of two"):
        CheckpointPolicy(delta=True, device_fp=True, chunk_bytes=12)
    CheckpointPolicy(delta=True, device_fp=True, chunk_bytes=CHUNK)


# ---------------------------------------------------------------------------
# layer 3: whole save chains — device path byte-identical to host path
# ---------------------------------------------------------------------------

def _base_tree():
    rng = np.random.default_rng(6)
    return {
        # 4 exact chunks: the D2H accounting below is byte-exact on it
        "a": rng.standard_normal(CHUNK).astype(np.float32),
        "b": rng.standard_normal(CHUNK // 4 + 9).astype(np.float32),
        "c": rng.integers(0, 100, CHUNK + 7).astype(np.int8),  # ragged tail
        "d": rng.standard_normal(5),                           # float64
        "e": np.zeros(0, np.float32),                          # zero-byte
        "f": np.float32(3.25),                                 # 0-d scalar
    }


def _mutate(tree, elems):
    out = dict(tree)
    a = out["a"].copy()
    a[:elems] += 1.0
    out["a"] = a
    return out


def _manifest_payload(m):
    """The content-bearing part of a manifest: leaves (chunks incl. fp) and
    the step — everything timing/meta is excluded."""
    man = dict(m)
    return {"step": man["step"], "leaves": man["leaves"]}


def _save_chain(tmp, name, device_fp):
    store = TieredStore(tmp / name, seed=0)
    mgr = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK,
        fingerprint=True, device_fp=device_fp))
    tree = _base_tree()
    parts, manifests = [], []
    cur = tree
    for s, elems in ((1, 0), (2, 96), (3, 40)):
        if elems:
            cur = _mutate(cur, elems)
        parts.append(mgr.save(s, cur))
        mgr.commit(s)
        manifests.append(_manifest_payload(mgr.read_manifest(s)))
    restored = []
    for s in (1, 2, 3):
        out, _ = mgr.restore(tree, s)
        restored.append(out)
    digests = store.chunk_digests("shared", "ckpt")
    mgr.close()
    return parts, manifests, restored, digests, cur


def test_device_save_chain_bit_identical_to_host(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_FP_IMPL", "pallas_interpret")
    h_parts, h_man, h_res, h_dig, h_final = _save_chain(
        tmp_path, "host", False)
    d_parts, d_man, d_res, d_dig, d_final = _save_chain(
        tmp_path, "dev", True)

    # identical chunk stores, identical manifests, identical restores
    assert d_dig == h_dig
    assert d_man == h_man
    for got, want in zip(d_res, h_res):
        flat_g, flat_w = dict(SER.flatten_with_names(got)), dict(
            SER.flatten_with_names(want))
        assert set(flat_g) == set(flat_w)
        for k in flat_w:
            np.testing.assert_array_equal(flat_g[k], flat_w[k])
            assert np.asarray(flat_g[k]).dtype == np.asarray(flat_w[k]).dtype

    # D2H accounting: the host path snapshots the world every step...
    payload = sum(np.asarray(a).nbytes for a in _base_tree().values())
    assert h_parts[1]["delta"]["d2h_bytes"] == payload
    assert h_parts[1]["delta"]["chunks_clean_device"] == 0
    # ...the device path pays only for the dirty chunks: step 2 dirties
    # exactly elements [0,96) of the 4-chunk f32 leaf "a" -> chunks 0-1
    d2 = d_parts[1]["delta"]
    assert d2["d2h_bytes"] == 2 * CHUNK
    assert d2["chunks_clean_device"] > 0
    assert d2["fp_device_s"] > 0.0
    # step 3 dirties elements [0,40) -> chunk 0 only
    assert d_parts[2]["delta"]["d2h_bytes"] == CHUNK


def test_device_save_jnp_leaves_match_numpy_leaves(tmp_path, monkeypatch):
    """The bitcast word streams feed the same manifests as host memory:
    a device tree (jnp leaves, incl. sub-word dtypes) and its numpy twin
    produce identical chunk plans."""
    monkeypatch.setenv("REPRO_DEVICE_FP_IMPL", "pallas_interpret")
    rng = np.random.default_rng(7)
    base = {
        "w32": rng.standard_normal(CHUNK // 2).astype(np.float32),
        "w16": rng.standard_normal(CHUNK // 4 + 3).astype(np.float16),
        "w8": rng.integers(0, 90, CHUNK - 5).astype(np.int8),
        "flags": rng.integers(0, 2, 37).astype(bool),
    }

    def chain(name, to_leaf):
        store = TieredStore(tmp_path / name, seed=0)
        mgr = CheckpointManager(store, CheckpointPolicy(
            replicas=1, delta=True, chunk_bytes=CHUNK,
            fingerprint=True, device_fp=True))
        tree = {k: to_leaf(v) for k, v in base.items()}
        mgr.save(1, tree)
        mgr.commit(1)
        man = _manifest_payload(mgr.read_manifest(1))
        out, _ = mgr.restore(base, 1)
        mgr.close()
        return man, out

    man_np, out_np = chain("np", lambda v: v)
    man_j, out_j = chain("jnp", jnp.asarray)
    assert man_np == man_j
    for k, v in base.items():
        np.testing.assert_array_equal(np.asarray(out_j[k]), v)
        np.testing.assert_array_equal(np.asarray(out_np[k]), v)


# ---------------------------------------------------------------------------
# iterative pre-copy on the device path
# ---------------------------------------------------------------------------

def test_device_iterative_predump_hashes_only_new_churn(tmp_path,
                                                        monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_FP_IMPL", "pallas_interpret")
    store = TieredStore(tmp_path, seed=0)
    mgr = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK,
        fingerprint=True, device_fp=True))
    tree = _base_tree()
    mgr.save(1, tree)
    mgr.commit(1)

    # lead N-2: 2 chunks of "a" dirtied since the parent manifest
    cur = _mutate(tree, 96)
    mgr.precommit(2, cur)
    s1 = mgr.wait_predump()
    assert s1["chunks_hashed"] == 2 and s1["d2h_bytes"] == 2 * CHUNK

    # lead N-1: only chunk 0 re-dirtied since lead N-2
    cur = _mutate(cur, 40)
    mgr.precommit(3, cur)
    s2 = mgr.wait_predump()
    assert s2["chunks_hashed"] == 1 and s2["d2h_bytes"] == CHUNK
    assert s2["chunks_hashed"] < s1["chunks_hashed"]

    # the save consumes lead N-1: nothing dirtied since -> zero D2H,
    # zero hashing, and the manifest still restores bit-exactly
    p = mgr.save(4, cur)
    mgr.commit(4)
    d = p["delta"]
    assert d["chunks_hashed"] == 0 and d["d2h_bytes"] == 0
    assert d["predump_step"] == 3
    out, _ = mgr.restore(tree, 4)
    flat_g, flat_w = dict(SER.flatten_with_names(out)), dict(
        SER.flatten_with_names(cur))
    for k in flat_w:
        np.testing.assert_array_equal(flat_g[k], flat_w[k])
    mgr.close()


def test_host_iterative_predump_uses_previous_lead(tmp_path):
    """The host pre-dump path reuses the previous lead's fp-clean entries
    too (same iterative schedule, no device involved)."""
    store = TieredStore(tmp_path, seed=0)
    mgr = CheckpointManager(store, CheckpointPolicy(
        replicas=1, delta=True, chunk_bytes=CHUNK, fingerprint=True))
    tree = _base_tree()
    mgr.save(1, tree)
    mgr.commit(1)

    cur = _mutate(tree, 96)
    mgr.precommit(2, cur)
    s1 = mgr.wait_predump()
    cur = _mutate(cur, 40)
    mgr.precommit(3, cur)
    s2 = mgr.wait_predump()
    assert s2["chunks_hashed"] < s1["chunks_hashed"] == 2
    assert s2["chunks_hashed"] == 1

    p = mgr.save(4, cur)
    mgr.commit(4)
    assert p["delta"]["chunks_hashed"] == 0
    out, _ = mgr.restore(tree, 4)
    flat_g, flat_w = dict(SER.flatten_with_names(out)), dict(
        SER.flatten_with_names(cur))
    for k in flat_w:
        np.testing.assert_array_equal(flat_g[k], flat_w[k])
    mgr.close()
