"""Parallel multi-shard restore engine + shared->local tier promotion:
parallel == serial byte-for-byte, per-range replica fallback, promotion
serving the second restart with zero shared-tier bytes, manifest-driven
invalidation, deterministic (seedable) replica placement."""
import os
import random
import threading
import time
from pathlib import Path

import numpy as np
import pytest

import faults
from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.restore_engine import ParallelRestorer, auto_workers
from repro.checkpoint.store import DEFAULT_TIERS, TieredStore


def _tree(rng, big_kb: int = 64):
    return {
        "w": rng.standard_normal((64, 32)).astype(np.float32),
        "b": rng.standard_normal((256,)).astype(np.float32),
        "big": rng.standard_normal((big_kb * 256,)).astype(np.float32),
        "step": np.int32(7),
        "scalar": np.float64(2.5),
    }


def _save_multi_worker(store, tree, step, num_workers, **kw):
    pol = CheckpointPolicy(**kw)
    for w in range(num_workers):
        mw = CheckpointManager(store, pol, worker_id=w,
                               num_workers=num_workers)
        mw.save(step, tree)
    m0 = CheckpointManager(store, pol, worker_id=0, num_workers=num_workers)
    m0.commit(step, num_workers=num_workers)
    return m0


def _assert_trees_equal(got, want):
    flat_g = dict(SER.flatten_with_names(got))
    flat_w = dict(SER.flatten_with_names(want))
    assert set(flat_g) == set(flat_w)
    for name in flat_w:
        a, b = np.asarray(flat_g[name]), np.asarray(flat_w[name])
        assert a.dtype == b.dtype, name
        assert a.tobytes() == b.tobytes(), name


class TierCountingStore(faults.ByteCountingStoreMixin, TieredStore):
    """Counts every byte actually fetched, keyed by tier — see faults.py."""


# ---------------------------------------------------------------------------
# parallel == serial, byte for byte
# ---------------------------------------------------------------------------

def test_parallel_restore_equals_serial(tmp_path, rng):
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    _save_multi_worker(store, tree, 5, num_workers=3, replicas=2)

    serial = CheckpointManager(store, CheckpointPolicy(restore_workers=1))
    out_s, man_s = serial.restore(tree)
    parallel = CheckpointManager(store, CheckpointPolicy(restore_workers=4))
    out_p, man_p = parallel.restore(tree)

    assert man_s["step"] == man_p["step"] == 5
    _assert_trees_equal(out_p, out_s)
    assert parallel.last_restore_stats["mode"] == "parallel"
    assert parallel.last_restore_stats["workers"] == 4
    assert serial.last_restore_stats["mode"] == "serial"


def test_parallel_restore_splits_large_shards(tmp_path, rng):
    """A shard bigger than split_bytes becomes several range tasks (split at
    leaf boundaries), and the reassembled tree is still exact."""
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng, big_kb=256)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1))
    m.save(1, tree)
    man = m.commit(1)

    engine = ParallelRestorer(store, workers=4, split_bytes=64 * 1024)
    by_file: dict = {}
    for e in man["leaves"]:
        by_file.setdefault(e["file"], []).append(e)
    named, stats = engine.restore("shared", by_file)
    assert stats.tasks > len(by_file), (stats.tasks, len(by_file))
    for name, arr in SER.flatten_with_names(tree):
        assert np.asarray(arr).tobytes() == named[name].tobytes(), name


def test_parallel_restore_incremental_manifest(tmp_path, rng):
    """An incremental manifest spanning a base and a delta shard restores
    correctly through the parallel engine."""
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store,
                          CheckpointPolicy(incremental=True, keep_last=10, replicas=1,
                                           restore_workers=4))
    tree = _tree(rng)
    m.save(1, tree)
    m.commit(1)
    tree2 = dict(tree)
    tree2["big"] = tree["big"] + 1
    m.save(2, tree2)
    man2 = m.commit(2)
    assert any(e.get("reused") for e in man2["leaves"])

    m2 = CheckpointManager(store, CheckpointPolicy(restore_workers=4))
    out, man = m2.restore(tree, step=2)
    _assert_trees_equal(out, tree2)


# ---------------------------------------------------------------------------
# per-range replica fallback under injected OSError
# ---------------------------------------------------------------------------

def test_parallel_range_read_falls_back_on_oserror(tmp_path, rng):
    """Headers plan clean against replica A, then A's payload reads fail with
    OSError mid-restore: every affected range must fall back to replica B."""
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    _save_multi_worker(store, tree, 3, num_workers=2, replicas=2)

    man = CheckpointManager(store).read_manifest(3)
    a_shard = man["leaves"][0]["file"]
    bad_root = store.root / "shared"
    bad_node = faults.replica_file(store, "shared", a_shard).parts[-4]

    # payload reads (big) on the primary replica's node fail; header reads
    # (small) succeed so the plan is built against this replica
    injector = faults.PreadFaults(
        store,
        lambda p, off, n: (bad_root in p.parents and bad_node in p.parts
                           and n > 4096),
        error=OSError("simulated torn replica page"))
    with injector:
        m = CheckpointManager(store, CheckpointPolicy(restore_workers=4))
        out, _ = m.restore(tree)
    _assert_trees_equal(out, tree)
    assert injector.fired > 0
    assert m.last_restore_stats["replica_fallbacks"] > 0


def test_parallel_restore_raises_when_no_replica_intact(tmp_path, rng):
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=2))
    m.save(1, tree)
    m.commit(1)
    with faults.PreadFaults(store, lambda p, off, n: n > 4096,
                            error=OSError("all replicas torn")):
        with pytest.raises(SER.ChecksumError, match="no intact replica"):
            CheckpointManager(store, CheckpointPolicy(restore_workers=4)).restore(tree)


def test_chaos_mid_range_corruption_replica_fallback(tmp_path, rng):
    """Chaos: one replica's payload bytes are flipped mid-file AFTER commit
    (headers/footers stay parseable, so the plan is built against the BAD
    replica).  Every range read crossing the corruption must CRC-fail and
    fall back per-range to the intact replica, and the reassembled state
    must be byte-identical."""
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng, big_kb=256)
    m = CheckpointManager(store, CheckpointPolicy(replicas=2))
    m.save(1, tree)
    man = m.commit(1)

    shard_rel = next(e["file"] for e in man["leaves"])
    bad = faults.replica_file(store, "shared", shard_rel, idx=0)
    faults.flip_byte(bad)          # mid-file: payload territory for v2 shards

    eng = CheckpointManager(store, CheckpointPolicy(restore_workers=4))
    out, _ = eng.restore(tree)
    _assert_trees_equal(out, tree)
    assert eng.last_restore_stats["replica_fallbacks"] > 0


# ---------------------------------------------------------------------------
# shared -> local tier promotion
# ---------------------------------------------------------------------------

def test_on_restore_promotion_second_restore_zero_shared_bytes(tmp_path, rng):
    store = TierCountingStore(tmp_path, seed=0)
    tree = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="on_restore"))
    m.save(4, tree)
    m.commit(4)

    store.reset()
    out1, _ = m.restore(tree)
    assert store.read_by_tier.get("shared", 0) > 0     # cold: shared bytes
    m.wait_promotions()
    assert not m.promote_failures

    store.reset()
    m2 = CheckpointManager(store, CheckpointPolicy(promote="on_restore"))
    out2, man = m2.restore(tree)
    assert man["step"] == 4
    assert store.read_by_tier.get("shared", 0) == 0, store.read_by_tier
    assert store.read_by_tier.get("local", 0) > 0
    assert m2.last_restore_stats.get("promoted") is True
    _assert_trees_equal(out2, out1)
    m.close()
    m2.close()


def test_promotion_is_crc_verified_and_failure_is_soft(tmp_path, rng):
    """A promotion that cannot copy intact bytes records a failure, publishes
    no marker, and never raises into the training thread."""
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="on_restore"))
    m.save(1, tree)
    man = m.commit(1)
    # corrupt the only shared replica's payload AFTER commit: the copy lands
    # but its CRC check against the manifest must reject it
    shard_rel = next(e["file"] for e in man["leaves"])
    faults.flip_byte(faults.replica_file(store, "shared", shard_rel), offset=10)

    m._promote_now(man)
    assert m.promote_failures, "corrupt promotion must be recorded"
    assert m._read_marker() is None
    assert not store.exists("local", shard_rel)
    m.close()


def test_promoted_cache_invalidated_when_newer_step_commits(tmp_path, rng):
    store = TierCountingStore(tmp_path, seed=0)
    tree1 = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="on_restore", keep_last=5))
    m.save(1, tree1)
    m.commit(1)
    m.restore(tree1)
    m.wait_promotions()
    assert m._read_marker()["step"] == 1

    tree2 = dict(tree1)
    tree2["w"] = tree1["w"] + 1
    m.save(2, tree2)
    m.commit(2)                       # newer step commits -> cache is stale

    store.reset()
    out, man = m.restore(tree1)       # latest == step 2
    assert man["step"] == 2
    _assert_trees_equal(out, tree2)
    # stale cache was NOT served (shared bytes were read), and was dropped
    assert store.read_by_tier.get("shared", 0) > 0
    m.wait_promotions()
    assert m._read_marker()["step"] == 2   # re-promoted at the new step
    store.reset()
    out2, _ = m.restore(tree1)
    assert store.read_by_tier.get("shared", 0) == 0, store.read_by_tier
    _assert_trees_equal(out2, tree2)
    m.close()


def test_eager_promotion_on_commit(tmp_path, rng):
    store = TierCountingStore(tmp_path, seed=0)
    tree = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="eager"))
    m.save(2, tree)
    m.commit(2)
    m.wait_promotions()
    assert not m.promote_failures
    assert m._read_marker()["step"] == 2

    store.reset()
    m2 = CheckpointManager(store, CheckpointPolicy(promote="eager"))
    out, man = m2.restore(tree)
    assert man["step"] == 2
    assert store.read_by_tier.get("shared", 0) == 0, store.read_by_tier
    _assert_trees_equal(out, tree)
    m.close()
    m2.close()


def test_damaged_promoted_cache_falls_back_to_shared(tmp_path, rng):
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="on_restore"))
    m.save(1, tree)
    m.commit(1)
    m.restore(tree)
    m.wait_promotions()
    # evict the promoted shard bytes but leave the marker: the restore must
    # detect the damage, drop the cache, and still serve from shared
    man = m.read_manifest(1)
    shard_rel = next(e["file"] for e in man["leaves"])
    store.delete_file("local", shard_rel)
    out, _ = m.restore(tree)
    _assert_trees_equal(out, tree)
    assert m.last_restore_stats.get("promoted") is None
    m.close()


def test_incremental_promotion_does_not_recopy_base_shard(tmp_path, rng):
    """eager + incremental: the second promotion copies only the delta shard;
    the already-promoted base shard is kept in place, not re-transferred."""
    store = TieredStore(tmp_path, seed=0)
    copies = []
    real_copy = TieredStore.copy_file

    def counting_copy(self, src_tier, rel, dst_tier, **kw):
        copies.append(rel)
        return real_copy(self, src_tier, rel, dst_tier, **kw)

    store.copy_file = counting_copy.__get__(store)
    m = CheckpointManager(store,
                          CheckpointPolicy(replicas=1, incremental=True, promote="eager",
                                           keep_last=10))
    tree = _tree(rng)
    m.save(1, tree)
    m.commit(1)
    m.wait_promotions()
    first_copies = list(copies)

    tree2 = dict(tree)
    tree2["w"] = tree["w"] + 1                # only one leaf changes
    m.save(2, tree2)
    man2 = m.commit(2)
    m.wait_promotions()
    assert not m.promote_failures
    assert any(e.get("reused") for e in man2["leaves"])
    second_copies = copies[len(first_copies):]
    base_rel = next(e["file"] for e in man2["leaves"] if e.get("reused"))
    delta_rel = next(e["file"] for e in man2["leaves"] if not e.get("reused"))
    assert delta_rel in second_copies
    assert base_rel not in second_copies, second_copies
    # and the promoted cache still restores the new step intact, node-locally
    store2 = TierCountingStore(tmp_path, seed=0)
    m2 = CheckpointManager(store2, CheckpointPolicy(promote="on_restore"))
    out, man = m2.restore(tree)
    assert man["step"] == 2
    assert store2.read_by_tier.get("shared", 0) == 0, store2.read_by_tier
    _assert_trees_equal(out, tree2)
    m.close()
    m2.close()


def test_restoring_older_step_keeps_newer_promoted_cache(tmp_path, rng):
    """An explicit rollback restore of an older step must not evict the
    promoted cache of the newer (still committed) step."""
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="on_restore", keep_last=10))
    tree1 = _tree(rng)
    m.save(1, tree1)
    m.commit(1)
    tree2 = dict(tree1)
    tree2["w"] = tree1["w"] + 1
    m.save(2, tree2)
    m.commit(2)
    m.restore(tree1)                          # latest (2) -> promoted
    m.wait_promotions()
    assert m._read_marker()["step"] == 2

    out, man = m.restore(tree1, step=1)       # rollback/inspection
    assert man["step"] == 1
    _assert_trees_equal(out, tree1)
    m.wait_promotions()
    # the warmer step-2 cache survives the older-step restore
    assert m._read_marker()["step"] == 2
    m.close()


def test_workpool_close_after_failure_stops_threads():
    from repro.checkpoint.async_writer import WorkPool

    pool = WorkPool(max_inflight=2, workers=2, name="t-pool")
    pool.submit(lambda: (_ for _ in ()).throw(OSError("disk gone")))
    with pytest.raises(RuntimeError, match="background checkpoint task"):
        pool.close()
    # the failure must not leak pool threads or leave the pool half-open
    assert all(not t.is_alive() for t in pool._threads)
    pool.close()                              # second close is a no-op


def test_workpool_try_submit_drops_instead_of_blocking():
    """Promotion scheduling must never backpressure the training thread: a
    full pool rejects (False) instead of blocking."""
    import threading as th

    from repro.checkpoint.async_writer import WorkPool

    pool = WorkPool(max_inflight=2, workers=1, name="t-pool")
    gate = th.Event()
    pool.submit(gate.wait)
    pool.submit(gate.wait)
    assert pool.try_submit(lambda: None) is False   # full: dropped, no block
    gate.set()
    pool.wait()
    assert pool.try_submit(lambda: None) is True    # drained: accepted
    pool.close()


def test_gc_cancels_inflight_promotion_for_deleted_step(tmp_path, rng):
    """GC/promotion race: gc() starts deleting a step whose write-behind
    promotion is mid-copy.  The copier must abort BEFORE publishing a marker
    (cancelled, not failed), and the follow-up promotion of the surviving
    step must land a complete, valid cache."""
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    gate, started = threading.Event(), threading.Event()
    real_copy = TieredStore.copy_file

    def slow_copy(self, src_tier, rel, dst_tier, **kw):
        out = real_copy(self, src_tier, rel, dst_tier, **kw)
        started.set()
        assert gate.wait(10)           # gc runs while the copier is "here"
        return out

    store.copy_file = slow_copy.__get__(store)
    for w in range(2):                 # two shard files: copy 1 lands, then
        CheckpointManager(store, CheckpointPolicy(replicas=1), worker_id=w,
                          num_workers=2).save(1, tree)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="eager", keep_last=1),
                          num_workers=2)
    m.commit(1, num_workers=2)         # schedules promotion; copier blocks
    assert started.wait(10)
    for w in range(2):
        CheckpointManager(store, CheckpointPolicy(replicas=1), worker_id=w,
                          num_workers=2).save(2, tree)
    m.commit(2, num_workers=2)         # gc deletes step 1 mid-promotion
    gate.set()
    m.wait_promotions()
    assert m.promote_cancelled >= 1
    assert m._read_marker() is not None and m._read_marker()["step"] == 2
    assert m.cache_inventory()["valid"]
    # the cancelled run's partial copies were retired, not leaked (no marker
    # would ever reference them)
    assert not store.list_prefix("local", "ckpt/step_0000000001")
    m.close()


def test_gc_cancels_queued_promotion_too(tmp_path, rng):
    """A promotion still QUEUED behind a busy copier when gc() deletes its
    step must cancel on dequeue — not run, fail on the retired source, and
    wipe the whole promote tier via the failure path."""
    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    gate, started = threading.Event(), threading.Event()
    real_copy = TieredStore.copy_file

    def slow_copy(self, *a, **kw):
        out = real_copy(self, *a, **kw)
        started.set()
        assert gate.wait(10)
        return out

    store.copy_file = slow_copy.__get__(store)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1, promote="eager", keep_last=1))
    m.save(1, tree)
    m.commit(1)                        # promo(1) executing (blocked in copy)
    assert started.wait(10)
    m.save(2, tree)
    m.commit(2)                        # gc dooms step 1; promo(2) QUEUED
    m.save(3, tree)
    m.commit(3)                        # gc dooms queued promo(2); promo(3)
    gate.set()                         # dropped (pool full) -> skipped
    m.wait_promotions()
    assert m.promote_cancelled >= 2    # the executing AND the queued one
    assert not m.promote_failures, m.promote_failures
    assert m.promote_skipped >= 1
    assert m._read_marker() is None    # no torn/stale marker published
    store.copy_file = real_copy.__get__(store)
    m.prefetch_latest()                # cache recovers at the latest step
    m.wait_promotions()
    assert m._read_marker()["step"] == 3
    assert m.cache_inventory()["valid"]
    m.close()


# ---------------------------------------------------------------------------
# restore pool sizing: env override + tier-concurrency cap (no magic 8)
# ---------------------------------------------------------------------------

def test_auto_workers_env_override_and_tier_cap(tmp_path, rng, monkeypatch):
    monkeypatch.setenv("REPRO_RESTORE_WORKERS", "3")
    assert auto_workers() == 3
    assert auto_workers(cap=1) == 3           # explicit override wins
    monkeypatch.setenv("REPRO_RESTORE_WORKERS", "garbage")
    assert auto_workers(cap=2) == 2           # mangled override degrades
    monkeypatch.delenv("REPRO_RESTORE_WORKERS")
    assert auto_workers(cap=2) == 2           # tier budget caps the pool
    assert auto_workers() == max(2, os.cpu_count() or 2)   # no magic 8

    store = TieredStore(tmp_path, seed=0)
    tree = _tree(rng)
    m = CheckpointManager(store, CheckpointPolicy(replicas=1))
    m.save(1, tree)
    m.commit(1)
    eng = CheckpointManager(store)             # shared tier: concurrency 8
    eng.restore(tree)
    assert 1 <= eng.last_restore_stats["workers"] <= DEFAULT_TIERS["shared"].concurrency
    monkeypatch.setenv("REPRO_RESTORE_WORKERS", "5")
    eng2 = CheckpointManager(store)
    eng2.restore(tree)
    assert eng2.last_restore_stats["workers"] == 5


# ---------------------------------------------------------------------------
# fd cache: ranged reads reuse one descriptor, mutations invalidate it
# ---------------------------------------------------------------------------

def test_pread_fd_cache_reuses_and_invalidates(tmp_path):
    store = TieredStore(tmp_path, seed=0)
    store.put("local", "f/data.bin", b"A" * 1024)
    p = store.replica_paths("local", "f/data.bin")[0]
    assert store.get_range("local", "f/data.bin", 0, 4) == b"AAAA"
    assert p in store._fds                     # descriptor cached...
    fd1 = store._fds[p].fd
    assert store.get_range("local", "f/data.bin", 512, 4) == b"AAAA"
    assert store._fds[p].fd == fd1             # ...and reused, not re-opened
    # rename-over via put must invalidate: the next read sees NEW bytes
    store.put("local", "f/data.bin", b"B" * 1024)
    assert store.get_range("local", "f/data.bin", 0, 4) == b"BBBB"
    # delete + re-copy (the damaged-cache repromotion path): no stale fd
    store.get_range("local", "f/data.bin", 0, 1)       # cache it again
    store.delete_file("local", "f/data.bin")
    store.put("shared", "f/data.bin", b"C" * 1024)
    store.copy_file("shared", "f/data.bin", "local")
    assert store.get_range("local", "f/data.bin", 0, 4) == b"CCCC"
    # delete_prefix invalidates everything under it
    store.get_range("local", "f/data.bin", 0, 1)
    store.delete_prefix("local", "f")
    assert not store.exists("local", "f/data.bin")
    store.close()
    assert not store._fds


# ---------------------------------------------------------------------------
# deterministic replica placement (seedable RNG)
# ---------------------------------------------------------------------------

def test_choose_nodes_seedable_and_injectable(tmp_path):
    s1 = TieredStore(tmp_path / "a", seed=7)
    s2 = TieredStore(tmp_path / "b", seed=7)
    picks1 = [[p.name for p in s1._choose_nodes("shared", 2)]
              for _ in range(20)]
    picks2 = [[p.name for p in s2._choose_nodes("shared", 2)]
              for _ in range(20)]
    assert picks1 == picks2
    # module-level random must not influence placement
    random.seed(123)
    s3 = TieredStore(tmp_path / "c", seed=7)
    random.seed(999)
    picks3 = [[p.name for p in s3._choose_nodes("shared", 2)]
              for _ in range(20)]
    assert picks3 == picks1
    # injectable RNG wins over seed
    s4 = TieredStore(tmp_path / "d", rng=random.Random(7))
    picks4 = [[p.name for p in s4._choose_nodes("shared", 2)]
              for _ in range(20)]
    assert picks4 == picks1


# ---------------------------------------------------------------------------
# scheduler: parallel beats serial under simulated shared-FS latency
# ---------------------------------------------------------------------------

def test_parallel_restore_faster_than_serial_under_latency(tmp_path, rng):
    """With the shared tier's simulated per-op latency on, fanning 8 shards
    across 8 readers must beat the one-at-a-time loop by a wide margin (the
    paper's Fig. 2 restart-latency effect, inverted)."""
    tiers = dict(DEFAULT_TIERS)
    store = TieredStore(tmp_path, tiers=tiers, sim_io_factor=0.5, seed=0)
    tree = {f"l{i:02d}": rng.standard_normal((64,)).astype(np.float32)
            for i in range(16)}
    _save_multi_worker(store, tree, 1, num_workers=8, replicas=1)

    t0 = time.perf_counter()
    out_s, _ = CheckpointManager(store, CheckpointPolicy(restore_workers=1)).restore(tree)
    serial_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_p, _ = CheckpointManager(store, CheckpointPolicy(restore_workers=8)).restore(tree)
    parallel_s = time.perf_counter() - t0

    _assert_trees_equal(out_p, out_s)
    assert parallel_s < 0.6 * serial_s, (parallel_s, serial_s)
