"""End-to-end Fig.-3 workflow: a real training subprocess under the slurm
simulator is preempted (walltime USR1), checkpoints, exits 85, is requeued, and
finishes with params BIT-IDENTICAL to an uninterrupted reference run."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.sched.slurmsim import REQUEUE_EXIT, JobSpec, SlurmSim

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _base_cmd(ckpt_dir, metrics, steps=40):
    return [sys.executable, "-m", "repro.launch.train",
            "--arch", "qwen2-0.5b", "--reduced",
            "--steps", str(steps), "--batch", "4", "--seq", "64",
            "--interval-steps", "100", "--step-sleep", "0.2",
            "--walltime", "600", "--margin", "2",
            "--ckpt-dir", str(ckpt_dir), "--metrics-out", str(metrics)]


@pytest.mark.slow
def test_preempt_requeue_bit_identical(tmp_path):
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}

    ref_dir, pre_dir = tmp_path / "ref", tmp_path / "pre"
    ref_metrics, pre_metrics = tmp_path / "ref.json", tmp_path / "pre.json"

    r = subprocess.run(_base_cmd(ref_dir, ref_metrics), env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr

    sim = SlurmSim(tmp_path / "sim")
    jid = sim.submit(JobSpec(
        name="train", walltime_s=20.0, signal_margin_s=3.0,
        cmd=_base_cmd(pre_dir, pre_metrics), env={"PYTHONPATH": SRC,
                                                  "JAX_PLATFORMS": "cpu"},
        max_requeues=10))
    sim.run(timeout_s=400)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    assert rec.requeues >= 1, "walltime preemption never happened"
    assert REQUEUE_EXIT in rec.exit_codes

    ref = {m["step"]: m["loss"] for m in json.loads(ref_metrics.read_text())}
    pre = {m["step"]: m["loss"] for m in json.loads(pre_metrics.read_text())}
    last = max(ref)
    assert last in pre, "requeued job never reached the final step"
    assert ref[last] == pre[last], "preempted run diverged from reference"


@pytest.mark.slow
def test_manual_preemption_scancel(tmp_path):
    """Manual C/R strategy: operator preempts (SIGTERM) mid-run; job requeues."""
    env_d = {"PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    sim = SlurmSim(tmp_path / "sim")
    jid = sim.submit(JobSpec(
        name="train", walltime_s=600.0, signal_margin_s=5.0,
        cmd=_base_cmd(tmp_path / "ck", tmp_path / "m.json", steps=25),
        env=env_d, max_requeues=3))
    import threading, time

    def preempt_later():
        time.sleep(12)
        if sim.job(jid).state == "RUNNING":
            sim.preempt(jid)

    t = threading.Thread(target=preempt_later, daemon=True)
    t.start()
    sim.run(timeout_s=300)
    rec = sim.job(jid)
    assert rec.state == "COMPLETED", (rec.state, rec.exit_codes)
    # requeue count may be 0 if the job outran the preemptor; exit codes tell
    if rec.requeues:
        assert rec.exit_codes[0] == REQUEUE_EXIT
