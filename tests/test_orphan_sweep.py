"""Multi-worker pre-dump orphan reclamation (``sweep_orphan_chunks``).

A pre-dump writes chunks BEFORE any manifest names them; when the consuming
save no longer references some of them (the data moved on), the per-save
sweep only reclaims them in single-writer runs — with other workers alive it
cannot tell "my orphan" from "your in-flight chunk".  The coordinator sweep
closes that gap: digests minus every kept-manifest/uncommitted-wpart
reference, barriered on the in-flight intent markers every delta save and
pre-dump publishes.
"""
import json
import time

import numpy as np
import pytest

from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import (TieredStore, chunk_rel,
                                    manifest_chunk_hashes)

CHUNK = 1 << 16


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _tree(rng, n_leaves=4, elems=70_000):
    return {f"l{i}": rng.standard_normal(elems).astype(np.float32)
            for i in range(n_leaves)}


def _mutate(tree, delta):
    out = {}
    for k, a in tree.items():
        a = a.copy()
        a[:200] += delta
        out[k] = a
    return out


def _assert_trees_equal(got, want):
    flat_g = dict(SER.flatten_with_names(got))
    flat_w = dict(SER.flatten_with_names(want))
    assert set(flat_g) == set(flat_w)
    for k in flat_w:
        np.testing.assert_array_equal(flat_g[k], flat_w[k])


def _pol(**kw):
    base = dict(replicas=1, delta=True, chunk_bytes=CHUNK, keep_last=3)
    base.update(kw)
    return CheckpointPolicy(**base)


def _workers(store, n):
    return [CheckpointManager(store, _pol(), worker_id=w, num_workers=n)
            for w in range(n)]


# ---------------------------------------------------------------------------
# the gap itself: a 2-worker pre-dump whose data moved on leaks chunks that
# no manifest will ever name, and the commit-time coordinator sweep reaps
# them without touching anything restorable
# ---------------------------------------------------------------------------

def test_multi_worker_predump_orphans_reclaimed_at_commit(tmp_path, rng):
    store = TieredStore(tmp_path, seed=0)
    w0, w1 = _workers(store, 2)
    tree1 = _tree(rng)
    for w in (w0, w1):
        w.save(1, tree1)
    w0.commit(1, num_workers=2)

    # pre-dump against a snapshot that the final save then DIVERGES from:
    # every pre-written chunk for the mutated regions becomes an orphan
    tree_pre = _mutate(tree1, 0.5)
    tree2 = _mutate(tree1, 1.0)
    w0.precommit(2, tree_pre)
    w0.wait_predump()
    for w in (w0, w1):
        w.save(2, tree2)
    w0.commit(2, num_workers=2)        # gc() runs the coordinator sweep

    sweep = w0.last_orphan_sweep
    assert sweep is not None and sweep["skipped"] is None
    assert sweep["reaped"], "pre-dump orphans were not reclaimed"

    # post-condition: on-disk chunks == exactly the kept manifests' refs
    keep = (manifest_chunk_hashes(w0.read_manifest(1))
            | manifest_chunk_hashes(w0.read_manifest(2)))
    assert store.chunk_digests("shared", "ckpt") == keep
    # nothing restorable was torn
    out2, _ = w0.restore(tree2, 2)
    _assert_trees_equal(out2, tree2)
    out1, _ = w0.restore(tree1, 1)
    _assert_trees_equal(out1, tree1)
    for w in (w0, w1):
        w.close()


def test_single_writer_needs_no_coordinator_sweep(tmp_path, rng):
    # with one writer the consuming save already reclaims its own pre-dump
    # fallout; the coordinator sweep then finds a clean floor
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, _pol())
    tree1 = _tree(rng)
    m.save(1, tree1)
    m.commit(1)
    m.precommit(2, _mutate(tree1, 0.5))
    m.wait_predump()
    m.save(2, _mutate(tree1, 1.0))
    m.commit(2)
    sweep = m.sweep_orphan_chunks()
    assert sweep["skipped"] is None and sweep["reaped"] == []
    m.close()


# ---------------------------------------------------------------------------
# barriers: fresh in-flight markers defer the sweep; stale ones age out;
# uncommitted wparts (an in-flight commit's payload) are never candidates
# ---------------------------------------------------------------------------

def _orphan(store, prefix="ckpt"):
    """Plant a chunk file no manifest references."""
    h = "ab" * 16
    store.put("shared", chunk_rel(prefix, h), b"orphaned payload")
    return h


def _marker(store, t, prefix="ckpt", step=5, worker=1):
    rel = f"{prefix}/inflight/delta_{step:010d}_w{worker:05d}.json"
    store.put("shared", rel, json.dumps(
        {"kind": "delta", "step": step, "worker": worker, "t": t}).encode())
    return rel


def test_fresh_marker_defers_sweep_stale_marker_ages_out(tmp_path, rng):
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, _pol(), num_workers=2)
    h = _orphan(store)
    rel = _marker(store, time.time())

    sweep = m.sweep_orphan_chunks()
    assert sweep["skipped"] == "in-flight saves"
    assert h in store.chunk_digests("shared", "ckpt")

    # same marker, but its writer died 2 sweeps ago: aged out and reaped
    store.put("shared", rel, json.dumps(
        {"kind": "delta", "step": 5, "worker": 1,
         "t": time.time() - 10_000}).encode())
    sweep = m.sweep_orphan_chunks(stale_marker_s=900.0)
    assert sweep["skipped"] is None
    assert h in sweep["reaped"]
    assert h not in store.chunk_digests("shared", "ckpt")
    assert rel not in store.list_prefix("shared", "ckpt/inflight")
    m.close()


def test_torn_marker_defers_until_its_mtime_ages(tmp_path, rng):
    # a marker torn mid-write has no parseable timestamp; its file mtime
    # (fresh here) still counts as "a writer may be alive" and defers
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, _pol(), num_workers=2)
    h = _orphan(store)
    store.put("shared", "ckpt/inflight/delta_0000000005_w00001.json",
              b"{torn")
    sweep = m.sweep_orphan_chunks()
    assert sweep["skipped"] == "in-flight saves"
    assert h in store.chunk_digests("shared", "ckpt")
    m.close()


def test_uncommitted_wpart_chunks_are_protected(tmp_path, rng):
    # worker 1 saved step 2 (wpart on disk) but the coordinator has not
    # committed yet: those chunks belong to an in-flight commit, not to any
    # manifest — the sweep must treat them like kept refs
    store = TieredStore(tmp_path, seed=0)
    w0, w1 = _workers(store, 2)
    tree1 = _tree(rng)
    for w in (w0, w1):
        w.save(1, tree1)
    w0.commit(1, num_workers=2)

    tree2 = _mutate(tree1, 1.0)
    w1.save(2, tree2)                  # no commit: manifest-less wpart
    sweep = w0.sweep_orphan_chunks()
    assert sweep["skipped"] is None and sweep["reaped"] == []

    w0.save(2, tree2)
    w0.commit(2, num_workers=2)        # the in-flight commit completes
    out, _ = w0.restore(tree2, 2)
    _assert_trees_equal(out, tree2)
    for w in (w0, w1):
        w.close()


def test_elastic_worker_change_mid_predump(tmp_path, rng):
    """Elastic resize mid-pre-dump: worker 1 of 2 pre-dumps, is preempted,
    and the fleet comes back as ONE worker that saves and commits the next
    step.  The departed worker's intent marker must keep its manifest-less
    chunks alive (the resized commit's sweep backs off), and only after the
    marker ages out may the sweep reclaim them — with both committed steps
    still restorable."""
    store = TieredStore(tmp_path, seed=0)
    w0, w1 = _workers(store, 2)
    tree1 = _tree(rng)
    for w in (w0, w1):
        w.save(1, tree1)
    w0.commit(1, num_workers=2)

    # worker 1 pre-dumps a snapshot no save will ever consume...
    w1.precommit(2, _mutate(tree1, 0.5))
    w1.wait_predump()
    before = store.chunk_digests("shared", "ckpt")

    # ...and the fleet resizes: a single fresh worker owns every leaf now
    solo = CheckpointManager(store, _pol(), worker_id=0, num_workers=1)
    tree2 = _mutate(tree1, 1.0)
    solo.save(2, tree2)
    solo.commit(2, num_workers=1)

    keep = (manifest_chunk_hashes(solo.read_manifest(1))
            | manifest_chunk_hashes(solo.read_manifest(2)))
    orphans = before - keep
    assert orphans, "scenario needs manifest-less pre-dump chunks"
    # the departed worker's marker is fresh: the sweep defers (the resized
    # single-worker commit no longer sweeps automatically, so the elastic
    # coordinator must invoke it — and the marker barrier must still hold)
    sweep = solo.sweep_orphan_chunks()
    assert sweep["skipped"] == "in-flight saves"
    assert orphans <= store.chunk_digests("shared", "ckpt")

    # the worker never comes back; once its marker ages out the next sweep
    # reclaims exactly the manifest-less pre-dump chunks
    for rel in store.list_prefix("shared", "ckpt/inflight"):
        store.put("shared", rel, json.dumps(
            {"kind": "predump", "step": 2, "worker": 1,
             "t": time.time() - 10_000}).encode())
    sweep = solo.sweep_orphan_chunks(stale_marker_s=900.0)
    assert sweep["skipped"] is None
    assert orphans <= set(sweep["reaped"])
    assert store.chunk_digests("shared", "ckpt") == keep

    out2, _ = solo.restore(tree2, 2)
    _assert_trees_equal(out2, tree2)
    out1, _ = solo.restore(tree1, 1)
    _assert_trees_equal(out1, tree1)
    for w in (w0, w1, solo):
        w.close()


def test_unreadable_wpart_leaks_rather_than_tears(tmp_path, rng):
    store = TieredStore(tmp_path, seed=0)
    m = CheckpointManager(store, _pol(), num_workers=2)
    h = _orphan(store)
    store.put("shared", "ckpt/step_0000000007/wpart_w00001.json", b"{torn")
    sweep = m.sweep_orphan_chunks()
    assert sweep["skipped"] == "unreadable manifest or wpart"
    assert h in store.chunk_digests("shared", "ckpt")
    m.close()
