"""Checkpoint substrate: serialization, manager commit protocol, incremental,
corruption fallback, GC."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import serialization as SER
from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import TieredStore


def _tree(rng):
    return {
        "a": {"w": rng.standard_normal((4, 8)).astype(np.float32),
              "b16": rng.standard_normal((3,)).astype(np.float32).astype(jnp.bfloat16)},
        "step": np.int32(7),
        "nested": [rng.integers(0, 10, (2, 2), dtype=np.int32),
                   np.float64(3.5)],
    }


def test_shard_roundtrip(rng):
    tree = _tree(rng)
    recs = SER.tree_to_records(tree)
    data = SER.write_shard_bytes(recs, meta={"k": 1})
    named, meta = SER.read_shard_bytes(data)
    assert meta == {"k": 1}
    out = SER.restore_tree(tree, named)
    for (p1, a), (p2, b) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        assert np.asarray(a).dtype == np.asarray(b).dtype, p1
        assert np.array_equal(np.asarray(a), np.asarray(b)), p1


def test_shard_crc_detects_corruption(rng):
    data = bytearray(SER.write_shard_bytes(SER.tree_to_records(_tree(rng))))
    data[-3] ^= 0xFF
    with pytest.raises(SER.ChecksumError):
        SER.read_shard_bytes(bytes(data))


def test_manager_commit_is_atomic(tmp_path, rng):
    store = TieredStore(tmp_path)
    m = CheckpointManager(store, CheckpointPolicy(keep_last=10))
    tree = _tree(rng)
    m.save(5, tree)
    # no manifest yet -> restore fails (two-phase: WRITTEN but not committed)
    with pytest.raises(FileNotFoundError):
        m.restore(tree)
    m.commit(5)
    out, man = m.restore(tree)
    assert man["step"] == 5
    assert np.array_equal(out["a"]["w"], tree["a"]["w"])


def test_manager_multiworker_parts(tmp_path, rng):
    store = TieredStore(tmp_path)
    tree = _tree(rng)
    for w in range(3):
        mw = CheckpointManager(store, worker_id=w, num_workers=3)
        mw.save(2, tree)
    m0 = CheckpointManager(store, worker_id=0, num_workers=3)
    m0.commit(2, num_workers=3)
    # elastic: restore with a DIFFERENT worker count (MxN)
    m5 = CheckpointManager(store, worker_id=0, num_workers=5)
    out, _ = m5.restore(tree)
    assert np.array_equal(out["a"]["w"], tree["a"]["w"])
    assert int(out["step"]) == 7


def test_incremental_reuses_unchanged(tmp_path, rng):
    store = TieredStore(tmp_path)
    m = CheckpointManager(store, CheckpointPolicy(incremental=True, keep_last=10))
    tree = _tree(rng)
    m.save(1, tree)
    m.commit(1)
    tree2 = dict(tree)
    tree2["step"] = np.int32(8)          # only one leaf changes
    m.save(2, tree2)
    man = m.commit(2)
    reused = [e for e in man["leaves"] if e.get("reused")]
    fresh = [e for e in man["leaves"] if not e.get("reused")]
    assert len(fresh) == 1 and fresh[0]["path"] == "step"
    assert all("step_0000000001" in e["file"] for e in reused)
    out, _ = m.restore(tree)
    assert int(out["step"]) == 8
    assert np.array_equal(out["a"]["w"], tree["a"]["w"])


def test_replica_fallback_on_corruption(tmp_path, rng):
    store = TieredStore(tmp_path)
    # shared tier has 8 node dirs; write 2 replicas
    m = CheckpointManager(store, CheckpointPolicy(replicas=2))
    tree = _tree(rng)
    m.save(3, tree)
    m.commit(3)
    # corrupt ONE replica of the shard
    shards = [p for p in tmp_path.rglob("shard_*.bin")]
    assert len(shards) >= 2
    raw = bytearray(shards[0].read_bytes())
    raw[-5] ^= 0xFF
    shards[0].write_bytes(bytes(raw))
    out, _ = m.restore(tree)             # falls back to the intact replica
    assert np.array_equal(out["a"]["w"], tree["a"]["w"])


def test_gc_keeps_incremental_bases(tmp_path, rng):
    store = TieredStore(tmp_path)
    m = CheckpointManager(store, CheckpointPolicy(incremental=True, keep_last=2))
    tree = _tree(rng)
    for s in range(1, 6):
        t = dict(tree)
        t["step"] = np.int32(s)
        m.save(s, t)
        m.commit(s)
    steps = m.steps()
    assert steps == [4, 5]
    # base files referenced by steps 4/5 must still resolve
    out, _ = m.restore(tree, step=5)
    assert int(out["step"]) == 5
