"""End-to-end behaviour tests for the paper's system: the train driver's C/R
surface (cold start, interval checkpoints, restore, async mode, incremental),
exercised through the public CLI in-process."""
import json

import numpy as np

from repro.launch import train as T
from repro.sched.slurmsim import REQUEUE_EXIT


def _run(tmp_path, extra, steps=8, tag="m"):
    out = tmp_path / f"{tag}.json"
    code = T.main([
        "--arch", "qwen2-0.5b", "--reduced", "--steps", str(steps),
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path / "ckpt"),
        "--metrics-out", str(out), *extra])
    metrics = json.loads(out.read_text()) if out.exists() else []
    return code, metrics


def test_cold_start_and_resume(tmp_path):
    code, m1 = _run(tmp_path, ["--interval-steps", "3"], steps=6, tag="a")
    assert code == 0 and len(m1) == 6
    # resume continues from the final checkpoint, not step 0
    code, m2 = _run(tmp_path, ["--interval-steps", "3"], steps=9, tag="b")
    assert code == 0
    assert m2[0]["step"] == 6, m2[:2]


def test_async_and_incremental_modes(tmp_path):
    # lr=0 keeps params frozen -> param leaves dedup across checkpoints, while
    # optimizer moments still change and are rewritten (AdamW touches every
    # moment every step; incremental pays off for frozen/stable subsets).
    code, m = _run(tmp_path, ["--interval-steps", "2", "--ckpt-mode", "async",
                              "--ckpt-incremental", "--lr", "0.0"], steps=6)
    assert code == 0 and len(m) == 6
    manifests = [json.loads(p.read_text())
                 for p in (tmp_path / "ckpt").rglob("MANIFEST.json")]
    assert manifests
    man = max(manifests, key=lambda m: m["step"])   # latest step, not path order
    reused = [e for e in man["leaves"] if e.get("reused")]
    rewritten = [e for e in man["leaves"] if not e.get("reused")]
    assert reused, "incremental never reused frozen params"
    assert any(e["path"].startswith("opt/") for e in rewritten)


def test_walltime_exit_requeues(tmp_path):
    code, m = _run(tmp_path, ["--walltime", "0.5", "--margin", "100",
                              "--step-sleep", "0.01"], steps=50)
    # margin > walltime => near_limit immediately after first step
    assert code == REQUEUE_EXIT
    assert len(m) >= 1
    req = json.loads((tmp_path / "ckpt" / "requeue.json").read_text())
    assert req["requeues"] == 1 and req["last_step"] >= 0


def test_loss_goes_down_on_learnable_data():
    """Uniform-random tokens start at the optimal CE (ln V) — overfit one
    fixed batch instead to verify the optimizer actually learns."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.parallel.mesh_rules import Rules
    from repro.train import step as TS

    cfg = reduced(get_config("qwen2-0.5b"))
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=2, decay_steps=50)
    mesh = make_host_mesh()
    jitted, *_ = TS.make_train_step(cfg, mesh, oc, rules=Rules(mesh), donate=False)
    state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)}
    losses = []
    for _ in range(25):
        state, metrics = jitted(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
