"""Quickstart: train a small LM with transparent checkpoint-restart.

    PYTHONPATH=src python examples/quickstart.py

Runs ~60 steps of a reduced qwen2 on CPU with interval checkpoints; then
*simulates a crash* by rebuilding everything from scratch and restoring the
latest committed checkpoint — training continues exactly where it left off.
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import TieredStore
from repro.configs.base import get_config, reduced
from repro.core.cr_manager import CRManager
from repro.data.pipeline import PipelineState, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.parallel.mesh_rules import Rules
from repro.train import step as TS


def make_session(ckpt_dir):
    cfg = reduced(get_config("qwen2-0.5b"))
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=5, decay_steps=60)
    mesh = make_host_mesh()
    rules = Rules(mesh)
    step_fn, *_ = TS.make_train_step(cfg, mesh, oc, rules=rules, donate=False)
    ckpt = CheckpointManager(TieredStore(Path(ckpt_dir)))
    crm = CRManager(ckpt, interval_steps=10, cfg=cfg, rules=rules)
    pipe = SyntheticTokens(cfg, batch_size=4, seq_len=64, seed=0)
    templates = {"state": TS.abstract_train_state(cfg, oc)}
    axes = {"state": TS.state_logical_axes(cfg)}
    def init():
        return TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))

    return cfg, step_fn, crm, pipe, templates, axes, init


def train(ckpt_dir, until_step):
    cfg, step_fn, crm, pipe, templates, axes, init = make_session(ckpt_dir)
    state, meta, start = crm.restore_or_init(init, templates, axes)
    if meta and "data_state" in meta:
        pipe.restore(PipelineState.from_dict(meta["data_state"]))
    for step in range(start, until_step):
        state, metrics = step_fn(state, next(pipe))
        if step % 10 == 0:
            print(f"  step {step:3d}  loss {float(metrics['loss']):.4f}")
        crm.step_boundary(step, lambda: state,
                          extra_meta={"data_state": pipe.state().to_dict()})
    crm.checkpoint_now(until_step - 1, lambda: state,
                       extra_meta={"data_state": pipe.state().to_dict()})
    crm.close()
    return float(metrics["loss"])


if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as d:
        print("phase 1: train to step 30, checkpointing every 10 steps")
        train(d, 30)
        print("phase 2: 'crash' — fresh process state; restore and continue to 60")
        loss = train(d, 60)
        print(f"done. final loss {loss:.4f}")
