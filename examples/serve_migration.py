"""Serving with pause/migrate/resume — C/R applied to inference state.

    PYTHONPATH=src python examples/serve_migration.py

The paper highlights DMTCP's ability to "pause, migrate, or resume computations
across different machines".  For an LM server the live state is the KV cache +
generation cursor.  This example serves a batch of requests, snapshots the
engine mid-generation through the checkpoint substrate, tears the engine down,
"migrates" to a fresh engine (new object, could be a new host), restores, and
verifies the continuation is token-identical to an unmigrated run.
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import TieredStore
from repro.configs.base import get_config, reduced
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve.engine import Engine

ARCH = "llama3.2-1b"
BATCH, PROMPT, MAX_SEQ = 4, 12, 64


def main():
    cfg = reduced(get_config(ARCH))
    mesh = make_host_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (BATCH, PROMPT)), jnp.int32)}

    # ---- reference: uninterrupted generation --------------------------------
    ref = Engine(cfg, mesh, params, batch=BATCH, max_seq=MAX_SEQ)
    ref.prefill(prompts)
    ref_tokens = np.concatenate([ref.generate(10), ref.generate(10)], axis=1)

    # ---- serve 10 tokens, snapshot, migrate, resume -------------------------
    eng = Engine(cfg, mesh, params, batch=BATCH, max_seq=MAX_SEQ)
    eng.prefill(prompts)
    first = eng.generate(10)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(TieredStore(Path(d)))
        snap = eng.snapshot()
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), snap)
        mgr.save(0, host)
        mgr.commit(0)
        del eng                                     # old server gone
        print("engine checkpointed; migrating to a fresh engine...")

        eng2 = Engine(cfg, mesh, params, batch=BATCH, max_seq=MAX_SEQ)
        restored, _ = mgr.restore(host)
        eng2.restore(jax.tree_util.tree_map(jnp.asarray, restored))
        second = eng2.generate(10)

    got = np.concatenate([first, second], axis=1)
    assert np.array_equal(got, ref_tokens), "migrated continuation diverged!"
    print(f"OK — {BATCH} requests x 20 tokens; migrated continuation is "
          f"token-identical to the unmigrated run")
    print("sample continuation (request 0):", got[0].ravel()[:10], "...")


if __name__ == "__main__":
    main()
