"""Preemptible training under a batch scheduler — the paper's Fig. 3 end-to-end.

    PYTHONPATH=src python examples/preemptible_training.py [--preset demo|100m]

Submits a training job to the Slurm simulator with a walltime far shorter than
the job needs.  The scheduler delivers SIGUSR1 before each limit; the job
checkpoints, exits 85, is requeued (output appended), restores, and repeats
until the run completes.  The final summary shows every attempt, the steps it
covered, and that total progress equals a single uninterrupted run.

Presets:
  demo  ~6M-param model, 120 steps  (finishes in a few minutes on 1 CPU core)
  100m  ~100M-param model, 300 steps (the full-scale deliverable; needs real
        compute — identical code path, just bigger numbers)
"""
import argparse
import json
import re
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.sched.slurmsim import JobSpec, SlurmSim  # noqa: E402

PRESETS = {
    # (extra train args, per-attempt walltime seconds)
    "demo": (["--reduced", "--steps", "120", "--batch", "4", "--seq", "64",
              "--step-sleep", "0.1"], 25.0),
    "100m": (["--steps", "300", "--batch", "8", "--seq", "512",
              "--microbatches", "2"], 1800.0),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    extra, walltime = PRESETS[args.preset]

    with tempfile.TemporaryDirectory() as d:
        ckpt = Path(d) / "ckpt"
        metrics = Path(d) / "metrics.json"
        cmd = [sys.executable, "-m", "repro.launch.train", "--arch", args.arch,
               "--ckpt-dir", str(ckpt), "--metrics-out", str(metrics),
               "--walltime", "86400", "--margin", "2", *extra]
        sim = SlurmSim(Path(d) / "slurm")
        jid = sim.submit(JobSpec(
            name="pretrain", cmd=cmd, walltime_s=walltime, signal_margin_s=4.0,
            env={"PYTHONPATH": str(ROOT / "src"), "JAX_PLATFORMS": "cpu"},
            max_requeues=50))
        print(f"submitted job {jid} (walltime {walltime}s/attempt) — running...")
        sim.run(timeout_s=86400)
        rec = sim.job(jid)
        print(f"\njob state: {rec.state}   attempts: {rec.requeues + 1}   "
              f"exit codes: {rec.exit_codes}")
        out = (Path(d) / "slurm" / "pretrain.out").read_text()
        attempts = re.findall(r"=== launch attempt (\d+) on \S+ ===", out)
        resumes = re.findall(r"restored checkpoint step=(\d+)", out)
        print(f"scheduler launches: {attempts}")
        print(f"restore points:      {resumes}")
        if metrics.exists():
            m = json.loads(metrics.read_text())
            print(f"final step {m[-1]['step']}  final loss {m[-1]['loss']:.4f}")
        assert rec.state == "COMPLETED"
        print("OK — preempted training completed via checkpoint-requeue cycles")


if __name__ == "__main__":
    main()
