"""Elastic (MxN) restart: checkpoint under one mesh, resume under another.

    PYTHONPATH=src python examples/elastic_restart.py

DMTCP's process virtualization lets a checkpoint restart on different nodes;
the framework's topology virtualization lets one restart on a different *chip
topology*.  This example trains on a simulated (4 data x 2 model) mesh,
checkpoints, then resumes on (2 data x 4 model) and on (8 data x 1 model) —
same bits, new sharding, training continues.  Each phase runs in a subprocess
because the host-device count must be set before jax initializes.
"""
import os
import subprocess
import sys
from pathlib import Path
import tempfile

ROOT = Path(__file__).resolve().parents[1]

PHASE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax
from pathlib import Path
from repro.configs.base import get_config, reduced
from repro.optim import adamw
from repro.train import step as TS
from repro.parallel.mesh_rules import Rules
from repro.checkpoint.store import TieredStore
from repro.checkpoint.manager import CheckpointManager
from repro.core.virtualization import fetch_tree, place_tree
from repro.data.pipeline import SyntheticTokens

shape, out, mode = eval(sys.argv[1]), sys.argv[2], sys.argv[3]
axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
cfg = reduced(get_config("llama3.2-1b"))
oc = adamw.OptConfig(warmup_steps=2, decay_steps=20)
mesh = jax.make_mesh(shape, axes)
rules = Rules(mesh)
step_fn, *_ = TS.make_train_step(cfg, mesh, oc, rules=rules, donate=False)
mgr = CheckpointManager(TieredStore(Path(out)))
pipe = SyntheticTokens(cfg, 8, 32, seed=1)
with mesh:
    if mode == "save":
        state = TS.init_train_state(cfg, oc, jax.random.PRNGKey(0))
        for step in range(4):
            state, m = step_fn(state, next(pipe))
        mgr.save(3, fetch_tree(state)); mgr.commit(3)
        print(f"saved at step 3 on mesh {shape}, loss {float(m['loss']):.5f}")
    else:
        host, man = mgr.restore(TS.abstract_train_state(cfg, oc))
        state = place_tree(host, TS.state_logical_axes(cfg), rules)
        sh = jax.tree_util.tree_leaves(state)[1].sharding
        state, m = step_fn(state, pipe.batch_at(4))
        print(f"resumed on mesh {shape}: step 4 loss {float(m['loss']):.5f} "
              f"(example param sharding: {sh.spec})")
"""


def run(shape, out, mode):
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", PHASE, repr(shape), out, mode],
                       env=env, text=True, capture_output=True, timeout=600)
    if r.returncode != 0:
        print(r.stdout, r.stderr)
        raise SystemExit(1)
    print("  " + r.stdout.strip().splitlines()[-1])


if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as d:
        print("checkpoint on (4,2):")
        run((4, 2), d, "save")
        print("elastic restores:")
        for shape in [(4, 2), (2, 4), (8, 1), (2, 2, 2)]:
            run(shape, d, "restore")
        print("OK — one checkpoint, four topologies")
