"""Mamba2 block (used by zamba2 and available standalone).

Layout: in_proj -> [z | x | B | C | dt] ; causal depthwise conv over [x|B|C] ;
SSD scan ; gated RMSNorm ; out_proj.  Decode carries (conv window, ssm state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.ssd_scan import ssd_step
from repro.models import layers as L
from repro.models.layers import ParamSpec, shard_hint


def _dims(cfg: ModelConfig):
    E = cfg.d_inner
    N = cfg.ssm_state_dim
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    return E, N, H, P, W


def mamba2_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    E, N, H, P, W = _dims(cfg)
    conv_ch = E + 2 * N
    return {
        "in_proj": L.linear_spec(D, 2 * E + 2 * N + H, "embed", "ssm_inner"),
        "conv_w": ParamSpec((W, conv_ch), (None, "ssm_inner"), "normal", 1.0),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), "zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), "ssm_a"),
        "D": ParamSpec((H,), ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), "zeros"),
        "norm": L.rms_norm_spec(E),
        "out_proj": L.linear_spec(E, D, "ssm_inner", "embed"),
    }


def _split(cfg, proj):
    E, N, H, P, W = _dims(cfg)
    z = proj[..., :E]
    xBC = proj[..., E : 2 * E + 2 * N]
    dt_raw = proj[..., 2 * E + 2 * N :]
    return z, xBC, dt_raw


def _causal_conv(xBC, w, b):
    """Depthwise causal conv via W shifted adds. xBC: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    out = xBC * w[-1][None, None]
    for i in range(1, W):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, : xBC.shape[1]]
        out = out + shifted * w[W - 1 - i][None, None]
    return jax.nn.silu(out + b[None, None])


def mamba2_full(p, cfg: ModelConfig, x, *, want_state: bool = False, impl=None):
    """x: (B,S,D) -> (y, (conv_state, ssm_state) | None)."""
    dt_c = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    E, N, H, P, W = _dims(cfg)
    proj = L.linear(p["in_proj"], x, dt_c)
    z, xBC, dt_raw = _split(cfg, proj)
    xBC_conv = _causal_conv(xBC, p["conv_w"].astype(dt_c), p["conv_b"].astype(dt_c))
    xs = xBC_conv[..., :E].reshape(B, S, H, P)
    xs = shard_hint(xs, ("batch", "seq", "ssm_heads_dim", None))
    Bm = xBC_conv[..., E : E + N]
    Cm = xBC_conv[..., E + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    y = ops.ssd(
        xs, dt.astype(dt_c), p["A_log"], Bm, Cm, p["D"],
        chunk=cfg.ssm_chunk, impl=impl or "auto", return_state=want_state,
    )
    state = None
    if want_state:
        y, ssm_state = y
        # last W-1 *pre-conv* inputs, zero-padded on the left when S < W-1
        conv_state = jnp.pad(xBC, ((0, 0), (max(W - 1 - S, 0), 0), (0, 0)))[:, -(W - 1):]
        state = (conv_state.astype(dt_c), ssm_state)
    y = y.reshape(B, S, E)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.linear(p["out_proj"], y, dt_c)
    return out, state


def mamba2_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """x: (B,1,D); conv_state: (B,W-1,E+2N); ssm_state: (B,H,P,N) fp32."""
    dt_c = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    E, N, H, P, W = _dims(cfg)
    proj = L.linear(p["in_proj"], x, dt_c)
    z, xBC, dt_raw = _split(cfg, proj)                       # (B,1,*)
    window = jnp.concatenate([conv_state, xBC.astype(conv_state.dtype)], axis=1)  # (B,W,C)
    conv_w = p["conv_w"].astype(dt_c)
    conv = jnp.einsum("bwc,wc->bc", window.astype(dt_c), conv_w) + p["conv_b"].astype(dt_c)
    conv = jax.nn.silu(conv)
    xs = conv[:, :E].reshape(B, H, P)
    Bm = conv[:, E : E + N]
    Cm = conv[:, E + N :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    y, ssm_state = ssd_step(xs, dt, p["A_log"], Bm, Cm, p["D"], ssm_state)
    y = y.reshape(B, 1, E)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.linear(p["out_proj"], y, dt_c)
    return out, (window[:, 1:], ssm_state)
