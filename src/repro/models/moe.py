"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

GShard-style grouped routing: tokens are split into ``moe_groups`` routing groups
(sharded along the data axis), each group computes top-k assignments and packs
tokens into per-expert capacity slots *locally* (no cross-shard routing state).
Dispatch/combine are gathers/scatters — real data movement, not the dense one-hot
einsum of the original GShard formulation (which would fabricate O(E*C*D) fake
FLOPs per token and wreck both the roofline and actual TPU throughput).

Sharding: groups -> data axis before dispatch; expert dim -> data axis after
dispatch (XLA SPMD inserts the all-to-all); expert FFN weights are TP-sharded on
the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import ParamSpec, shard_hint


def moe_spec(cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    s = {
        "router": ParamSpec((D, E), ("embed", None), "normal"),
        "wi_gate": ParamSpec((E, D, F), ("expert", "embed", "mlp"), "normal"),
        "wi_up": ParamSpec((E, D, F), ("expert", "embed", "mlp"), "normal"),
        "wo": ParamSpec((E, F, D), ("expert", "mlp", "embed"), "normal"),
    }
    if cfg.num_shared_experts:
        s["shared"] = L.swiglu_spec(D, cfg.moe_d_ff * cfg.num_shared_experts)
    return s


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _quant_transport(x, hint, dt_name):
    """int8-quantized resharding: the dispatch all-to-all moves 1-byte lanes +
    per-slot scales instead of bf16 (DeepSeek-V3's FP8 dispatch, TPU-native).
    Gradients take the straight-through path (bf16 combine-side transport,
    matching DSv3's bf16 combine)."""
    return _quant_transport_impl(x, hint, dt_name)


def _quant_transport_impl(x, hint, dt_name):
    # NOTE: pinning the pre-quant tensor to the source sharding (to force the
    # int8 wire) was tried and REFUTED — it added a bf16 gather-side reshard
    # that outweighed the int8 saving (EXPERIMENTS §Perf i5).  Unpinned, XLA
    # places the reshard wherever it is cheapest and the quant still shrinks
    # whatever crosses it.
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = (jnp.maximum(amax, 1e-6) / 127.0).astype(jnp.dtype(dt_name))
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    q = shard_hint(q, hint)                       # <- int8 all-to-all
    scale = shard_hint(scale, hint[:-1] + (None,))
    return q.astype(jnp.dtype(dt_name)) * scale


def _quant_fwd(x, hint, dt_name):
    return _quant_transport_impl(x, hint, dt_name), None


def _quant_bwd(hint, dt_name, _res, g):
    return (g,)                                    # straight-through; XLA
                                                   # reshards the cotangent


_quant_transport.defvjp(_quant_fwd, _quant_bwd)


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(np.ceil(tokens_per_group * cfg.num_experts_per_tok
                    / cfg.num_experts * cfg.capacity_factor))
    return max(8, int(np.ceil(c / 8) * 8))


def moe_ffn(p, cfg: ModelConfig, x: jax.Array, moe_groups: int):
    """x: (B,S,D) -> (out, aux_loss). Token order is preserved."""
    dt = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    G = min(moe_groups, T)
    while T % G:
        G -= 1
    Tg = T // G
    C = _capacity(Tg, cfg)

    xg = x.reshape(G, Tg, D)
    xg = shard_hint(xg, ("exp_group", None, "embed"))

    # ---- routing (fp32) -------------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,Tg,E)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (G,Tg,K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch style)
    density = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * mean_prob) * E * cfg.router_aux_weight

    # ---- slot assignment: position of each (token, k) in its expert's queue ----
    flat_e = top_e.reshape(G, Tg * K)                            # routing order: token-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (G,Tg*K,E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                    # (G,Tg*K,E)
    slot = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=-1)[..., 0]
    valid = slot < C                                             # dropped beyond capacity
    slot = jnp.where(valid, slot, 0)

    # ---- inverse map: which token fills (e, c)? -------------------------------
    tok_idx = jnp.broadcast_to(jnp.arange(Tg)[:, None], (Tg, K)).reshape(Tg * K)

    def invert(fe, sl, vd):
        # fe/sl/vd: (Tg*K,) -> slot_tok (E*C,), slot_filled (E*C,)
        target = fe * C + sl
        slot_tok = jnp.zeros((E * C,), jnp.int32).at[target].set(
            jnp.where(vd, tok_idx, 0), mode="drop")
        slot_filled = jnp.zeros((E * C,), jnp.bool_).at[target].set(
            vd, mode="drop")
        return slot_tok, slot_filled

    slot_tok, slot_filled = jax.vmap(invert)(flat_e, slot, valid)  # (G,E*C)

    # ---- dispatch: gather tokens into (G,E,C,D), reshard expert->data ----------
    # NOTE: sharding the capacity dim over data when E doesn't divide (granite-
    # moe's 40e) was tried and REFUTED — it distributed expert FLOPs (1.35x) but
    # moved more bytes overall (EXPERIMENTS §Perf i3); the "moe_cap" rule entry
    # remains documented-but-unbound.
    xe = jnp.take_along_axis(xg, slot_tok[..., None], axis=1)     # (G,E*C,D)
    xe = xe.reshape(G, E, C, D)
    xe = xe * slot_filled.reshape(G, E, C, 1).astype(xe.dtype)
    hint = (None, "expert", None, "embed")
    if cfg.moe_dispatch_bits == 8:
        xe = _quant_transport(xe, hint, str(dt))                  # int8 a2a
    else:
        xe = shard_hint(xe, hint)                                 # bf16 a2a

    # ---- expert FFN (TP on model axis) -----------------------------------------
    g = jnp.einsum("gecd,edf->gecf", xe.astype(dt), p["wi_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xe.astype(dt), p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = shard_hint(h, (None, "expert", None, "mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    ye = shard_hint(ye, ("exp_group", None, None, "embed"))       # <- all-to-all back

    # ---- combine: weighted scatter-add back to token order ---------------------
    ye = ye.reshape(G, E * C, D)
    gathered = jnp.take_along_axis(
        ye, (flat_e * C + slot)[..., None], axis=1)               # (G,Tg*K,D)
    w = (top_p.reshape(G, Tg * K) * valid.astype(jnp.float32)).astype(dt)
    contrib = gathered * w[..., None]
    out = jnp.sum(contrib.reshape(G, Tg, K, D), axis=2)           # (G,Tg,D)

    if cfg.num_shared_experts:
        out = out + L.swiglu(p["shared"], xg, dt)

    return out.reshape(B, S, D), aux
