"""RWKV6 ("Finch") layer: data-dependent-decay time-mix + channel-mix.

Faithful structure: token-shift ddlerp with a rank-`rwkv_lora_mix` LoRA producing
per-channel mix offsets for (r,k,v,w,g); decay ``w = exp(-exp(w0 + lora(x_w)))``;
WKV6 recurrence; per-head GroupNorm; gated output.  Decode state per layer:
(x_prev for time-mix, x_prev for channel-mix, wkv state (H,D,D)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.rwkv6_scan import wkv6_step
from repro.models import layers as L
from repro.models.layers import ParamSpec, shard_hint

_MIX_NAMES = ("r", "k", "v", "w", "g")  # 5 ddlerp channels


def _dims(cfg: ModelConfig):
    D = cfg.d_model
    Dh = cfg.head_dim
    H = D // Dh
    return D, H, Dh


def time_mix_spec(cfg: ModelConfig) -> dict:
    D, H, Dh = _dims(cfg)
    R = cfg.rwkv_lora_mix
    R2 = cfg.rwkv_lora_decay
    return {
        "mu_x": ParamSpec((D,), (None,), "small"),
        "mu": ParamSpec((5, D), (None, None), "small"),
        "lora_w1": ParamSpec((D, 5 * R), ("embed", None), "small"),
        "lora_w2": ParamSpec((5, R, D), (None, None, "embed"), "small"),
        "wr": L.linear_spec(D, D, "embed", "heads"),
        "wk": L.linear_spec(D, D, "embed", "heads"),
        "wv": L.linear_spec(D, D, "embed", "heads"),
        "wg": L.linear_spec(D, D, "embed", "heads"),
        "w0": ParamSpec((D,), (None,), "decay"),
        "decay_w1": ParamSpec((D, R2), ("embed", None), "small"),
        "decay_w2": ParamSpec((R2, D), (None, "embed"), "small"),
        "u": ParamSpec((H, Dh), ("ssm_heads", None), "small"),
        "ln_scale": ParamSpec((D,), (None,), "ones"),
        "ln_bias": ParamSpec((D,), (None,), "zeros"),
        "wo": L.linear_spec(D, D, "heads", "embed"),
    }


def channel_mix_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    F = cfg.d_ff
    return {
        "mu_k": ParamSpec((D,), (None,), "small"),
        "mu_r": ParamSpec((D,), (None,), "small"),
        "wk": L.linear_spec(D, F, "embed", "mlp"),
        "wv": L.linear_spec(F, D, "mlp", "embed"),
        "wr": L.linear_spec(D, D, "embed", "embed"),
    }


def _ddlerp(p, x, x_prev, dt):
    """Returns the 5 mixed inputs (r,k,v,w,g). x/x_prev: (B,S,D)."""
    xx = x_prev - x
    xxx = x + xx * p["mu_x"].astype(dt)
    R = p["lora_w1"].shape[1] // 5
    lo = jnp.tanh(xxx @ p["lora_w1"].astype(dt))             # (B,S,5R)
    B_, S_, _ = lo.shape
    lo = lo.reshape(B_, S_, 5, R)
    offs = jnp.einsum("bsfr,frd->bsfd", lo, p["lora_w2"].astype(dt))
    mixed = []
    for i in range(5):
        mix = p["mu"][i].astype(dt) + offs[:, :, i]
        mixed.append(x + xx * mix)
    return mixed


def time_mix_full(p, cfg: ModelConfig, x, *, x_prev0=None, want_state=False,
                  impl=None):
    """x: (B,S,D). x_prev0: (B,D) carried shift state (decode handoff)."""
    dt = jnp.dtype(cfg.compute_dtype)
    D, H, Dh = _dims(cfg)
    B, S, _ = x.shape
    if x_prev0 is None:
        x_prev0 = jnp.zeros((B, D), dt)
    x_prev = jnp.concatenate([x_prev0[:, None], x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev, dt)
    r = L.linear(p["wr"], xr, dt).reshape(B, S, H, Dh)
    k = L.linear(p["wk"], xk, dt).reshape(B, S, H, Dh)
    v = L.linear(p["wv"], xv, dt).reshape(B, S, H, Dh)
    g = L.linear(p["wg"], xg, dt)
    w_raw = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_w1"].astype(dt)) @ p["decay_w2"].astype(dt)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw)).reshape(B, S, H, Dh)
    r = shard_hint(r, ("batch", "seq", "ssm_heads_dim", None))
    out = ops.wkv6(r, k, v, w.astype(dt), p["u"], impl=impl or "auto",
                   return_state=want_state)
    state = None
    if want_state:
        out, wkv_state = out
        state = (x[:, -1].astype(dt), wkv_state)
    y = out.reshape(B, S, D)
    y = L.group_norm(y, H, cfg.norm_eps) * p["ln_scale"].astype(dt) + p["ln_bias"].astype(dt)
    y = y * jax.nn.silu(g)
    return L.linear(p["wo"], y, dt), state


def time_mix_decode(p, cfg: ModelConfig, x, x_prev, wkv_state):
    """x: (B,1,D); x_prev: (B,D); wkv_state: (B,H,Dh,Dh) fp32."""
    dt = jnp.dtype(cfg.compute_dtype)
    D, H, Dh = _dims(cfg)
    B = x.shape[0]
    xp = x_prev[:, None]
    xr, xk, xv, xw, xg = _ddlerp(p, x, xp, dt)
    r = L.linear(p["wr"], xr, dt).reshape(B, H, Dh)
    k = L.linear(p["wk"], xk, dt).reshape(B, H, Dh)
    v = L.linear(p["wv"], xv, dt).reshape(B, H, Dh)
    g = L.linear(p["wg"], xg, dt)
    w_raw = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_w1"].astype(dt)) @ p["decay_w2"].astype(dt)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw)).reshape(B, H, Dh)
    y, wkv_state = wkv6_step(r, k, v, w.astype(dt), p["u"], wkv_state)
    y = y.reshape(B, 1, D)
    y = L.group_norm(y, H, cfg.norm_eps) * p["ln_scale"].astype(dt) + p["ln_bias"].astype(dt)
    y = y * jax.nn.silu(g)
    return L.linear(p["wo"], y, dt), (x[:, 0].astype(dt), wkv_state)


def channel_mix(p, cfg: ModelConfig, x, x_prev0=None, want_state=False):
    """Works for full sequences and single steps alike."""
    dt = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    if x_prev0 is None:
        x_prev0 = jnp.zeros((B, D), dt)
    x_prev = jnp.concatenate([x_prev0[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["mu_k"].astype(dt)
    xr = x + xx * p["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(L.linear(p["wk"], xk, dt)))
    kk = shard_hint(kk, ("batch", "seq", "mlp"))
    out = jax.nn.sigmoid(L.linear(p["wr"], xr, dt)) * L.linear(p["wv"], kk, dt)
    if want_state:
        return out, x[:, -1].astype(dt)
    return out
