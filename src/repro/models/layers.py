"""Parameter-spec system + basic layers (pure JAX; no flax).

Every parameter is declared once as a :class:`ParamSpec` carrying its shape, its
*logical axes* (used by ``repro.parallel`` to derive NamedShardings), and its
initializer.  ``materialize`` turns a spec tree into a param tree; ``logical_axes``
extracts the matching axis tree.  Model code is plain functions over param dicts.
"""
from __future__ import annotations

import dataclasses
from contextvars import ContextVar
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import path_str

# ----------------------------------------------------------------------------------
# Param specs
# ----------------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple              # logical axis name (or None) per dim; len == len(shape)
    init: str = "normal"     # normal | zeros | ones | embed | small | ssm_a | decay
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, spec: ParamSpec, dtype) -> jax.Array:
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape) * std).astype(dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, shape) * 0.02 * spec.scale).astype(dtype)
    if spec.init == "small":
        return (jax.random.normal(key, shape) * 0.02 * spec.scale).astype(dtype)
    if spec.init == "ssm_a":  # mamba2 A_log: log of Uniform[1, 16]
        u = jax.random.uniform(key, shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "decay":  # rwkv decay base, negative-ish
        return (jax.random.normal(key, shape) * 0.5 - 1.0).astype(dtype)
    raise ValueError(spec.init)


def materialize(specs, key: jax.Array, dtype=jnp.float32):
    """Spec tree -> param tree.  Each leaf gets a key folded from its path hash.

    crc32, NOT python hash(): hash() is salted per process and would make init
    non-reproducible across restarts (bit-identity under C/R requires process-
    independent initialization)."""
    import zlib

    def make(path, spec):
        leaf_key = jax.random.fold_in(key, zlib.crc32(path_str(path).encode()) % (2**31))
        return _init_leaf(leaf_key, spec, dtype)

    return jax.tree_util.tree_map_with_path(
        make, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def abstract_params(specs, dtype=jnp.float32):
    """Spec tree -> ShapeDtypeStruct tree (for dry-run lowering, no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def logical_axes(specs):
    return jax.tree_util.tree_map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


# ----------------------------------------------------------------------------------
# Activation sharding hints.  The train/serve step factory installs a resolver
# (logical axes tuple -> jax.sharding.Sharding | None); the model calls shard_hint.
# ----------------------------------------------------------------------------------
_SHARD_RESOLVER: ContextVar[Optional[Callable]] = ContextVar("shard_resolver", default=None)


class use_shard_resolver:
    def __init__(self, resolver):
        self.resolver = resolver
        self._tok = None

    def __enter__(self):
        self._tok = _SHARD_RESOLVER.set(self.resolver)
        return self

    def __exit__(self, *exc):
        _SHARD_RESOLVER.reset(self._tok)


def shard_hint(x: jax.Array, axes: tuple) -> jax.Array:
    resolver = _SHARD_RESOLVER.get()
    if resolver is None:
        return x
    sharding = resolver(axes, x.shape)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


# ----------------------------------------------------------------------------------
# Layers
# ----------------------------------------------------------------------------------


def rms_norm_spec(dim: int) -> dict:
    return {"scale": ParamSpec((dim,), (None,), "ones")}


def rms_norm(p, x, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def group_norm(x, num_groups: int, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over the last dim (used by RWKV6 wkv output)."""
    dt = x.dtype
    *lead, d = x.shape
    g = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.var(g, axis=-1, keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + eps)
    return g.reshape(*lead, d).astype(dt)


def linear_spec(d_in: int, d_out: int, in_ax, out_ax, bias: bool = False,
                init: str = "normal", scale: float = 1.0) -> dict:
    s = {"w": ParamSpec((d_in, d_out), (in_ax, out_ax), init, scale)}
    if bias:
        s["b"] = ParamSpec((d_out,), (out_ax,), "zeros")
    return s


def linear(p, x, compute_dtype=None) -> jax.Array:
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def swiglu_spec(d_model: int, d_ff: int, in_ax="embed", mid_ax="mlp") -> dict:
    return {
        "gate": linear_spec(d_model, d_ff, in_ax, mid_ax),
        "up": linear_spec(d_model, d_ff, in_ax, mid_ax),
        "down": linear_spec(d_ff, d_model, mid_ax, in_ax),
    }


def swiglu(p, x, compute_dtype=None) -> jax.Array:
    g = linear(p["gate"], x, compute_dtype)
    u = linear(p["up"], x, compute_dtype)
    h = jax.nn.silu(g) * u
    h = shard_hint(h, ("batch", "seq", "mlp"))
    return linear(p["down"], h, compute_dtype)


def embedding_spec(vocab: int, dim: int) -> dict:
    return {"table": ParamSpec((vocab, dim), ("vocab", "embed"), "embed")}


def embed(p, ids, compute_dtype=None) -> jax.Array:
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def unembed(p, x) -> jax.Array:
    """Logits in fp32 for loss stability."""
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T


# ----------------------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dt = x.dtype
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)
