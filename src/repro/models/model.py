"""LM assembly: param specs, forward, loss, prefill, decode — for all 10 archs.

Layers are grouped into homogeneous *segments* (same block kind) and scanned with
``lax.scan`` + optional remat, so compile time and HLO size stay bounded at 61
layers.  Heterogeneous archs (deepseek first-dense, zamba2 shared-attn groups)
become multiple segments.  Caches mirror the segment structure, stacked on a
leading layer dim, and are scanned through during decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as BL
from repro.models import layers as L
from repro.models.layers import ParamSpec, shard_hint

tree_map = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    count: int


def layer_plan(cfg: ModelConfig) -> list[Segment]:
    if cfg.mixer == "rwkv6":
        return [Segment("rwkv6", cfg.num_layers)]
    if cfg.mixer == "mamba2":
        if cfg.shared_attn_period:
            inner = cfg.shared_attn_period
            groups = cfg.num_layers // inner
            tail = cfg.num_layers - groups * inner
            plan = [Segment("zamba_group", groups)]
            if tail:
                plan.append(Segment("mamba2", tail))
            return plan
        return [Segment("mamba2", cfg.num_layers)]
    base = "mla" if cfg.mixer == "mla" else "attn"
    if cfg.num_experts:
        plan = []
        if cfg.first_dense_layers:
            plan.append(Segment(f"{base}_dense", cfg.first_dense_layers))
        plan.append(Segment(f"{base}_moe", cfg.num_layers - cfg.first_dense_layers))
        return plan
    return [Segment(f"{base}_dense", cfg.num_layers)]


# ----------------------------------------------------------------------------------
# Param specs
# ----------------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    specs: dict[str, Any] = {}
    if cfg.num_codebooks:
        specs["embed"] = {
            "table": ParamSpec((cfg.num_codebooks, V, D), (None, "vocab", "embed"), "embed")
        }
    else:
        specs["embed"] = L.embedding_spec(V, D)
    if cfg.mixer == "rwkv6":
        specs["ln0"] = L.rms_norm_spec(D)
    for i, seg in enumerate(layer_plan(cfg)):
        specs[f"seg{i}"] = BL.stacked(BL.block_spec(cfg, seg.kind), seg.count)
    if cfg.shared_attn_period:
        specs["shared_attn"] = BL.shared_attn_spec(cfg)
    specs["final_norm"] = L.rms_norm_spec(D)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            specs["head"] = ParamSpec((cfg.num_codebooks, D, V), (None, "embed", "vocab"), "normal")
        else:
            specs["head"] = ParamSpec((D, V), ("embed", "vocab"), "normal")
    if cfg.mtp_depth:
        specs["mtp"] = {
            "proj": L.linear_spec(2 * D, D, "embed", "embed"),
            "block": BL.block_spec(cfg, "mla_dense" if cfg.mixer == "mla" else "attn_dense"),
            "norm": L.rms_norm_spec(D),
        }
    return specs


def init_params(cfg: ModelConfig, key: jax.Array):
    return L.materialize(param_specs(cfg), key, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig):
    return L.abstract_params(param_specs(cfg), jnp.dtype(cfg.param_dtype))


def param_logical_axes(cfg: ModelConfig):
    return L.logical_axes(param_specs(cfg))


def count_params_analytic(cfg: ModelConfig) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree_util.tree_leaves(
            param_specs(cfg), is_leaf=lambda x: isinstance(x, ParamSpec)
        )
    )


def count_active_params(cfg: ModelConfig) -> int:
    """Per-token active params (MoE: top-k + shared experts only)."""
    total = count_params_analytic(cfg)
    if not cfg.num_experts:
        return total
    D, F, E, K = cfg.d_model, cfg.moe_d_ff, cfg.num_experts, cfg.num_experts_per_tok
    moe_layers = cfg.num_layers - cfg.first_dense_layers
    per_expert = 3 * D * F
    total -= moe_layers * E * per_expert          # remove all routed experts
    total += moe_layers * K * per_expert          # add back the active ones
    return total


# ----------------------------------------------------------------------------------
# Embedding / head
# ----------------------------------------------------------------------------------


def embed_inputs(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    if cfg.num_codebooks:
        # tokens: (B,S,K); sum the K codebook embeddings
        tabs = params["embed"]["table"].astype(dt)          # (K,V,D)
        h = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), dt)
        for k in range(cfg.num_codebooks):
            h = h + jnp.take(tabs[k], tokens[..., k], axis=0)
    else:
        h = L.embed(params["embed"], tokens, dt)
    if cfg.num_image_tokens and "image_embeds" in batch:
        n = cfg.num_image_tokens
        img = batch["image_embeds"].astype(dt)              # (B,n,D)
        h = jnp.concatenate([img, h[:, n:]], axis=1)
    if cfg.mixer == "rwkv6":
        h = L.rms_norm(params["ln0"], h, cfg.norm_eps)
    return h


def logits_fn(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """h: (B,C,D) -> fp32 logits (B,C,V) or (B,C,K,V)."""
    hf = h.astype(jnp.float32)
    if cfg.num_codebooks:
        if cfg.tie_embeddings:
            tabs = params["embed"]["table"].astype(jnp.float32)
            return jnp.einsum("bcd,kvd->bckv", hf, tabs)
        return jnp.einsum("bcd,kdv->bckv", hf, params["head"].astype(jnp.float32))
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], h)
    return hf @ params["head"].astype(jnp.float32)


# ----------------------------------------------------------------------------------
# Forward (full sequence)
# ----------------------------------------------------------------------------------


def _remat_wrap(fn, cfg: ModelConfig, enable: bool):
    if not enable or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _run_segment_full(seg: Segment, seg_params, cfg: ModelConfig, h, positions, *,
                      moe_groups, want_cache, emb0, shared_p, impl, remat):
    def body(carry, xs):
        hh, aux = carry
        p = xs
        hh, cache, a = BL.block_full(
            seg.kind, p, cfg, hh, positions, moe_groups=moe_groups,
            want_cache=want_cache, emb0=emb0, shared_p=shared_p, impl=impl,
        )
        return (hh, aux + a), cache

    body = _remat_wrap(body, cfg, remat)
    if cfg.scan_layers and seg.count > 1:
        (h, aux), caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), seg_params)
    else:
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for i in range(seg.count):
            pi = tree_map(lambda x: x[i], seg_params)
            (h, aux), c = body((h, aux), pi)
            caches.append(c)
        if want_cache:
            caches = tree_map(lambda *xs: jnp.stack(xs), *caches)
        else:
            caches = None
    return h, caches, aux


def forward_full(params, cfg: ModelConfig, batch: dict, *, want_cache=False,
                 moe_groups=16, impl=None, remat=True):
    """Returns (h_final, caches per segment | None, aux_loss)."""
    h = embed_inputs(params, cfg, batch)
    h = shard_hint(h, ("batch", "seq", "embed"))
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    emb0 = h if cfg.shared_attn_period else None
    shared_p = params.get("shared_attn")
    aux = jnp.zeros((), jnp.float32)
    caches = []
    for i, seg in enumerate(layer_plan(cfg)):
        h, c, a = _run_segment_full(
            seg, params[f"seg{i}"], cfg, h, positions, moe_groups=moe_groups,
            want_cache=want_cache, emb0=emb0, shared_p=shared_p, impl=impl,
            remat=remat,
        )
        aux = aux + a
        caches.append(c)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    return h, (caches if want_cache else None), aux


# ----------------------------------------------------------------------------------
# Loss (chunked over sequence so fp32 logits never materialize at (B,S,V))
# ----------------------------------------------------------------------------------


def _ce_from_logits(logits, labels, mask):
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    zl = jnp.square(lse) * mask
    return jnp.sum(ce), jnp.sum(zl)


def chunked_ce(params, cfg: ModelConfig, h, labels, mask, chunk: int = 1024):
    """h: (B,S,D); labels: (B,S[,K]); mask: (B,S) fp32. Returns (ce_sum, z_sum, n)."""
    B, S, _ = h.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk

    def body(carry, xs):
        hc, lc, mc = xs

        def compute(hc, lc, mc):
            logits = logits_fn(params, cfg, hc)
            if cfg.num_codebooks:
                mce, mz = 0.0, 0.0
                for k in range(cfg.num_codebooks):
                    c, z = _ce_from_logits(logits[:, :, k], lc[..., k], mc)
                    mce, mz = mce + c, mz + z
                return mce / cfg.num_codebooks, mz / cfg.num_codebooks
            return _ce_from_logits(logits, lc, mc)

        ce, z = jax.checkpoint(compute)(hc, lc, mc)
        ce_s, z_s = carry
        return (ce_s + ce, z_s + z), None

    hs = h.reshape(B, nc, chunk, -1).swapaxes(0, 1)
    if cfg.num_codebooks:
        ls = labels.reshape(B, nc, chunk, -1).swapaxes(0, 1)
    else:
        ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, nc, chunk).swapaxes(0, 1)
    (ce, z), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
    return ce, z, jnp.maximum(jnp.sum(mask), 1.0)


def _shift_labels(cfg: ModelConfig, batch: dict):
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    B, S = tokens.shape[:2]
    mask = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"].astype(jnp.float32)
    if cfg.num_image_tokens:
        pos_ok = jnp.arange(S) >= max(cfg.num_image_tokens - 1, 0)
        mask = mask * pos_ok[None].astype(jnp.float32)
    return labels, mask


def loss_fn(params, cfg: ModelConfig, batch: dict, *, moe_groups=16, impl=None,
            z_loss: float = 1e-4):
    h, _, aux = forward_full(params, cfg, batch, moe_groups=moe_groups, impl=impl)
    labels, mask = _shift_labels(cfg, batch)
    ce, z, n = chunked_ce(params, cfg, h, labels, mask)
    loss = ce / n + z_loss * z / n + aux
    metrics = {"ce": ce / n, "aux": aux, "tokens": n}

    if cfg.mtp_depth and not cfg.num_codebooks:
        tokens = batch["tokens"]
        dt = jnp.dtype(cfg.compute_dtype)
        emb_next = L.embed(params["embed"], tokens, dt)
        x = jnp.concatenate(
            [h[:, :-1], emb_next[:, 1:]], axis=-1)
        x = L.linear(params["mtp"]["proj"], x, dt)
        B, S1, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S1)[None], (B, S1))
        kind = "mla_dense" if cfg.mixer == "mla" else "attn_dense"
        x, _, _ = BL.block_full(kind, params["mtp"]["block"], cfg, x, pos, impl=impl)
        x = L.rms_norm(params["mtp"]["norm"], x, cfg.norm_eps)
        # predict token t+2 at position t: labels shifted by 2
        mtp_labels = jnp.concatenate([tokens[:, 2:], tokens[:, -2:]], axis=1)[:, :S1]
        mtp_mask = jnp.ones((B, S1), jnp.float32).at[:, -2:].set(0.0) * mask[:, :S1]
        ce2, _, n2 = chunked_ce(params, cfg, x, mtp_labels, mtp_mask)
        loss = loss + 0.3 * ce2 / n2
        metrics["mtp_ce"] = ce2 / n2

    return loss, metrics


# ----------------------------------------------------------------------------------
# Decode / prefill
# ----------------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    """Returns ({path: (ShapeDtypeStruct)}, matching logical-axes tree)."""
    sds, axes = {}, {}
    for i, seg in enumerate(layer_plan(cfg)):
        entry = BL.cache_entry_spec(cfg, seg.kind, batch, max_seq)

        def expand(e):
            out_s, out_a = {}, {}
            for k, v in e.items():
                if isinstance(v, dict):
                    out_s[k], out_a[k] = expand(v)
                else:
                    shp, dt, ax = v
                    out_s[k] = jax.ShapeDtypeStruct((seg.count,) + shp, dt)
                    out_a[k] = ("layers",) + ax
            return out_s, out_a

        sds[f"seg{i}"], axes[f"seg{i}"] = expand(entry)
    sds["t"] = jax.ShapeDtypeStruct((), jnp.int32)
    axes["t"] = ()
    return sds, axes


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    sds, _ = cache_specs(cfg, batch, max_seq)
    return tree_map(lambda s: jnp.zeros(s.shape, s.dtype), sds)


def decode_step(params, cfg: ModelConfig, tokens_new, cache, *, impl=None):
    """tokens_new: (B,) or (B,K). Returns (fp32 logits (B,V)|(B,K,V), new cache)."""
    t = cache["t"]
    batch = {"tokens": tokens_new[:, None]}
    h = embed_inputs(params, cfg, batch)
    emb0 = h if cfg.shared_attn_period else None
    shared_p = params.get("shared_attn")
    new_cache: dict[str, Any] = {}
    for i, seg in enumerate(layer_plan(cfg)):
        seg_p = params[f"seg{i}"]
        seg_c = cache[f"seg{i}"]

        def body(h, xs):
            p, c = xs
            h, c = BL.block_decode(seg.kind, p, cfg, h, c, t, emb0=emb0,
                                   shared_p=shared_p, impl=impl)
            return h, c

        if cfg.scan_layers and seg.count > 1:
            h, new_c = jax.lax.scan(body, h, (seg_p, seg_c))
        else:
            cs = []
            for j in range(seg.count):
                pj = tree_map(lambda x: x[j], seg_p)
                cj = tree_map(lambda x: x[j], seg_c)
                h, cj = body(h, (pj, cj))
                cs.append(cj)
            new_c = tree_map(lambda *xs: jnp.stack(xs), *cs)
        new_cache[f"seg{i}"] = new_c
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = logits_fn(params, cfg, h)[:, 0]
    new_cache["t"] = t + 1
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: dict, max_seq: int, *, impl=None,
            moe_groups=16):
    """Full-sequence prefill; returns (last-position logits, cache of len max_seq)."""
    tokens = batch["tokens"]
    B, S = tokens.shape[:2]
    h, caches, _ = forward_full(params, cfg, batch, want_cache=True,
                                moe_groups=moe_groups, impl=impl, remat=False)
    full = init_cache(cfg, B, max_seq)

    def place(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        # sequence-indexed buffers: pad the prefill entries into [0:S]
        start = (0,) * dst.ndim
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)

    for i, seg in enumerate(layer_plan(cfg)):
        full[f"seg{i}"] = tree_map(place, full[f"seg{i}"], caches[i])
    full["t"] = jnp.asarray(S, jnp.int32)
    logits = logits_fn(params, cfg, h[:, -1:])[:, 0]  # h already final-normed
    return logits, full
