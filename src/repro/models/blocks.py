"""Per-layer blocks: spec + full-sequence + decode application, per block kind.

Kinds:
  attn_dense  pre-LN GQA attention + pre-LN SwiGLU
  attn_moe    pre-LN GQA attention + pre-LN MoE FFN
  mla_dense   pre-LN MLA attention + pre-LN SwiGLU
  mla_moe     pre-LN MLA attention + pre-LN MoE FFN (DeepSeek)
  mamba2      pre-LN Mamba2 mixer (no separate FFN)
  rwkv6       RWKV6 time-mix + channel-mix (LN-per-submodule)
  zamba_group ``inner`` Mamba2 layers + one shared-attention invocation
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rwkv as R
from repro.models import ssm as S


# ----------------------------------------------------------------------------------
# Specs
# ----------------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, kind: str) -> dict:
    D = cfg.d_model
    if kind in ("attn_dense", "attn_moe", "mla_dense", "mla_moe"):
        s = {
            "ln1": L.rms_norm_spec(D),
            "ln2": L.rms_norm_spec(D),
            "attn": A.mla_spec(cfg) if kind.startswith("mla") else A.gqa_spec(cfg),
        }
        if kind.endswith("moe"):
            s["ffn"] = M.moe_spec(cfg)
        else:
            s["ffn"] = L.swiglu_spec(D, cfg.d_ff)
        return s
    if kind == "mamba2":
        return {"ln1": L.rms_norm_spec(D), "mixer": S.mamba2_spec(cfg)}
    if kind == "rwkv6":
        return {
            "ln1": L.rms_norm_spec(D),
            "ln2": L.rms_norm_spec(D),
            "tmix": R.time_mix_spec(cfg),
            "cmix": R.channel_mix_spec(cfg),
        }
    if kind == "zamba_group":
        inner = cfg.shared_attn_period
        return {
            "mamba": stacked(block_spec(cfg, "mamba2"), inner),
            "shared_in": L.linear_spec(2 * D, D, "embed", "embed"),
        }
    raise ValueError(kind)


def shared_attn_spec(cfg: ModelConfig) -> dict:
    """The zamba2 shared transformer block (weights reused across invocations)."""
    return block_spec(cfg, "attn_dense")


def stacked(specs, n: int):
    return jax.tree_util.tree_map(
        lambda s: L.ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        specs,
        is_leaf=lambda x: isinstance(x, L.ParamSpec),
    )


# ----------------------------------------------------------------------------------
# Cache specs: (shape, dtype, logical_axes) descriptors per kind
# ----------------------------------------------------------------------------------


def cache_entry_spec(cfg: ModelConfig, kind: str, batch: int, max_seq: int) -> dict:
    dt = jnp.dtype(cfg.compute_dtype)
    if kind in ("attn_dense", "attn_moe"):
        shp = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        ax = ("batch", "cache_seq", "kv_heads_dim", None)
        return {"k": (shp, dt, ax), "v": (shp, dt, ax)}
    if kind in ("mla_dense", "mla_moe"):
        shp = (batch, max_seq, cfg.mla_cache_dim)
        return {"ckv": (shp, dt, ("batch", "cache_seq", None))}
    if kind == "mamba2":
        E, N, H, P, W = S._dims(cfg)
        return {
            "conv": ((batch, W - 1, E + 2 * N), dt, ("batch", None, "ssm_inner")),
            "ssm": ((batch, H, P, N), jnp.float32, ("batch", "ssm_heads_dim", None, None)),
        }
    if kind == "rwkv6":
        D, H, Dh = R._dims(cfg)
        return {
            "xt": ((batch, D), dt, ("batch", None)),
            "xc": ((batch, D), dt, ("batch", None)),
            "wkv": ((batch, H, Dh, Dh), jnp.float32, ("batch", "ssm_heads_dim", None, None)),
        }
    if kind == "zamba_group":
        inner = cfg.shared_attn_period
        mamba = cache_entry_spec(cfg, "mamba2", batch, max_seq)
        mamba = {
            k: ((inner,) + shp, d, ("layers",) + ax) for k, (shp, d, ax) in mamba.items()
        }
        kvshape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        kvax = ("batch", "cache_seq", "kv_heads_dim", None)
        return {"mamba": mamba, "shared_k": (kvshape, dt, kvax), "shared_v": (kvshape, dt, kvax)}
    raise ValueError(kind)


# ----------------------------------------------------------------------------------
# Full-sequence application (train / prefill)
# ----------------------------------------------------------------------------------


def block_full(kind, p, cfg: ModelConfig, h, positions, *, moe_groups=16,
               want_cache=False, emb0=None, shared_p=None, impl=None):
    """Returns (h, cache_entry | None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("attn_dense", "attn_moe", "mla_dense", "mla_moe"):
        xn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
        if kind.startswith("mla"):
            attn_out, kv = A.mla_full(p["attn"], cfg, xn, positions, impl=impl)
            if want_cache:
                cache = {"ckv": kv}
        else:
            attn_out, (k, v) = A.gqa_full(p["attn"], cfg, xn, positions, impl=impl)
            if want_cache:
                cache = {"k": k, "v": v}
        h = h + attn_out
        xn = L.rms_norm(p["ln2"], h, cfg.norm_eps)
        if kind.endswith("moe"):
            ffn_out, aux = M.moe_ffn(p["ffn"], cfg, xn, moe_groups)
        else:
            ffn_out = L.swiglu(p["ffn"], xn, jnp.dtype(cfg.compute_dtype))
        h = h + ffn_out
        return h, cache, aux

    if kind == "mamba2":
        xn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
        out, state = S.mamba2_full(p["mixer"], cfg, xn, want_state=want_cache, impl=impl)
        if want_cache:
            cache = {"conv": state[0], "ssm": state[1]}
        return h + out, cache, aux

    if kind == "rwkv6":
        xn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
        out, st = R.time_mix_full(p["tmix"], cfg, xn, want_state=want_cache, impl=impl)
        h = h + out
        xn2 = L.rms_norm(p["ln2"], h, cfg.norm_eps)
        if want_cache:
            cm_out, xc = R.channel_mix(p["cmix"], cfg, xn2, want_state=True)
            cache = {"xt": st[0], "xc": xc, "wkv": st[1]}
        else:
            cm_out = R.channel_mix(p["cmix"], cfg, xn2)
        return h + cm_out, cache, aux

    if kind == "zamba_group":
        inner = cfg.shared_attn_period
        mcaches = []
        for i in range(inner):
            pi = jax.tree_util.tree_map(lambda x: x[i], p["mamba"])
            h, ci, _ = block_full("mamba2", pi, cfg, h, positions,
                                  want_cache=want_cache, impl=impl)
            if want_cache:
                mcaches.append(ci)
        # shared attention invocation on concat(h, embedding stream)
        x_in = L.linear(p["shared_in"],
                        jnp.concatenate([h, emb0.astype(h.dtype)], axis=-1),
                        jnp.dtype(cfg.compute_dtype))
        hs, scache, _ = block_full("attn_dense", shared_p, cfg, x_in, positions,
                                   want_cache=want_cache, impl=impl)
        h = h + hs
        if want_cache:
            mstack = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *mcaches)
            cache = {"mamba": mstack, "shared_k": scache["k"], "shared_v": scache["v"]}
        return h, cache, aux

    raise ValueError(kind)


# ----------------------------------------------------------------------------------
# Decode application (one token)
# ----------------------------------------------------------------------------------


def block_decode(kind, p, cfg: ModelConfig, h, cache, t, *, emb0=None,
                 shared_p=None, impl=None):
    """Returns (h, cache)."""
    if kind in ("attn_dense", "attn_moe", "mla_dense", "mla_moe"):
        xn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
        if kind.startswith("mla"):
            attn_out, ckv = A.mla_decode(p["attn"], cfg, xn, cache["ckv"], t, impl=impl)
            cache = {"ckv": ckv}
        else:
            attn_out, (k, v) = A.gqa_decode(p["attn"], cfg, xn, cache["k"],
                                            cache["v"], t, impl=impl)
            cache = {"k": k, "v": v}
        h = h + attn_out
        xn = L.rms_norm(p["ln2"], h, cfg.norm_eps)
        if kind.endswith("moe"):
            ffn_out, _ = M.moe_ffn(p["ffn"], cfg, xn, moe_groups=1)
        else:
            ffn_out = L.swiglu(p["ffn"], xn, jnp.dtype(cfg.compute_dtype))
        return h + ffn_out, cache

    if kind == "mamba2":
        xn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
        out, (conv, ssm) = S.mamba2_decode(p["mixer"], cfg, xn, cache["conv"], cache["ssm"])
        return h + out, {"conv": conv, "ssm": ssm}

    if kind == "rwkv6":
        xn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
        out, (xt, wkv) = R.time_mix_decode(p["tmix"], cfg, xn, cache["xt"], cache["wkv"])
        h = h + out
        xn2 = L.rms_norm(p["ln2"], h, cfg.norm_eps)
        cm_out, xc = R.channel_mix(p["cmix"], cfg, xn2, x_prev0=cache["xc"], want_state=True)
        return h + cm_out, {"xt": xt, "xc": xc, "wkv": wkv}

    if kind == "zamba_group":
        inner = cfg.shared_attn_period
        new_m = []
        for i in range(inner):
            pi = jax.tree_util.tree_map(lambda x: x[i], p["mamba"])
            ci = jax.tree_util.tree_map(lambda x: x[i], cache["mamba"])
            h, ci = block_decode("mamba2", pi, cfg, h, ci, t, impl=impl)
            new_m.append(ci)
        x_in = L.linear(p["shared_in"],
                        jnp.concatenate([h, emb0.astype(h.dtype)], axis=-1),
                        jnp.dtype(cfg.compute_dtype))
        hs, skv = block_decode("attn_dense", shared_p, cfg, x_in,
                               {"k": cache["shared_k"], "v": cache["shared_v"]}, t,
                               impl=impl)
        h = h + hs
        mstack = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_m)
        return h, {"mamba": mstack, "shared_k": skv["k"], "shared_v": skv["v"]}

    raise ValueError(kind)
