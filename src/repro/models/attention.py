"""Attention mixers: MHA/GQA (+qkv-bias, qk_norm) and DeepSeek MLA.

Two execution paths per mixer:
  * ``*_full``  — train / prefill over a full sequence (causal).
  * ``*_decode``— one new token against a cache.  MLA decode runs in *absorbed*
    form (latent-space attention over the compressed KV cache, DeepSeek-style),
    so the per-head K/V are never materialized over the whole cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.models.layers import ParamSpec, shard_hint


# ----------------------------------------------------------------------------------
# GQA / MHA
# ----------------------------------------------------------------------------------


def gqa_spec(cfg: ModelConfig) -> dict:
    H, Hkv, D, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_model, cfg.head_dim
    s = {
        "wq": L.linear_spec(D, H * Dh, "embed", "heads", bias=cfg.qkv_bias),
        "wk": L.linear_spec(D, Hkv * Dh, "embed", "kv_heads", bias=cfg.qkv_bias),
        "wv": L.linear_spec(D, Hkv * Dh, "embed", "kv_heads", bias=cfg.qkv_bias),
        "wo": L.linear_spec(H * Dh, D, "heads", "embed"),
    }
    if cfg.qk_norm:
        s["q_norm"] = L.rms_norm_spec(Dh)
        s["k_norm"] = L.rms_norm_spec(Dh)
    return s


def _project_qkv(p, cfg: ModelConfig, x, positions, dt):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = L.linear(p["wq"], x, dt).reshape(B, S, H, Dh)
    k = L.linear(p["wk"], x, dt).reshape(B, S, Hkv, Dh)
    v = L.linear(p["wv"], x, dt).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = L.rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = L.rms_norm(p["k_norm"], k, cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_full(p, cfg: ModelConfig, x, positions, impl=None):
    """x: (B,S,D) -> (out, kv) ; kv returned for prefill cache construction."""
    dt = jnp.dtype(cfg.compute_dtype)
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, dt)
    q = shard_hint(q, ("batch", "seq", "heads_dim", None))
    k = shard_hint(k, ("batch", "seq", "kv_heads_dim", None))
    out = ops.attention(q, k, v, causal=True, impl=impl or cfg.attn_impl)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return L.linear(p["wo"], out, dt), (k, v)


def gqa_decode(p, cfg: ModelConfig, x, cache_k, cache_v, t, impl=None):
    """One-token decode.  x: (B,1,D); cache_k/v: (B,Smax,Hkv,Dh); t: scalar index."""
    dt = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    positions = jnp.broadcast_to(t, (B, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, dt)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), t, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), t, axis=1)
    out = ops.attention(
        q, cache_k.astype(dt), cache_v.astype(dt),
        causal=False, kv_len=t + 1, impl=impl or cfg.attn_impl, decode=True,
    )
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return L.linear(p["wo"], out, dt), (cache_k, cache_v)


# ----------------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ----------------------------------------------------------------------------------


def mla_spec(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    s: dict = {
        # KV down-projection: latent c_kv + shared rope key
        "wkv_a": L.linear_spec(D, cfg.kv_lora_rank + rope, "embed", None),
        "kv_norm": L.rms_norm_spec(cfg.kv_lora_rank),
        # up-projections from the latent
        "wk_b": ParamSpec((cfg.kv_lora_rank, H, nope), (None, "heads_dim", None), "normal"),
        "wv_b": ParamSpec((cfg.kv_lora_rank, H, vdim), (None, "heads_dim", None), "normal"),
        "wo": L.linear_spec(H * vdim, D, "heads", "embed"),
    }
    if cfg.q_lora_rank:
        s["wq_a"] = L.linear_spec(D, cfg.q_lora_rank, "embed", None)
        s["q_norm"] = L.rms_norm_spec(cfg.q_lora_rank)
        s["wq_b"] = ParamSpec(
            (cfg.q_lora_rank, H, nope + rope), (None, "heads_dim", None), "normal"
        )
    else:
        s["wq"] = ParamSpec((D, H, nope + rope), ("embed", "heads_dim", None), "normal")
    return s


def _mla_q(p, cfg: ModelConfig, x, positions, dt):
    B, S, _ = x.shape
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = L.rms_norm(p["q_norm"], L.linear(p["wq_a"], x, dt), cfg.norm_eps)
        q = jnp.einsum("bsr,rhd->bshd", cq, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bsD,Dhd->bshd", x.astype(dt), p["wq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg: ModelConfig, x, positions, dt):
    kv = L.linear(p["wkv_a"], x, dt)
    c_kv = L.rms_norm(p["kv_norm"], kv[..., : cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]          # (B,S,1,rope)
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_full(p, cfg: ModelConfig, x, positions, impl=None):
    """Naive (expanded) MLA for train/prefill; returns compressed cache."""
    dt = jnp.dtype(cfg.compute_dtype)
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions, dt)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions, dt)
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhd->bshd", c_kv, p["wv_b"].astype(dt))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, cfg.qk_rope_head_dim))],
        axis=-1,
    )
    out = ops.attention(q, k, v, causal=True, impl=impl or cfg.attn_impl)
    out = out.reshape(B, S, H * cfg.v_head_dim)
    return L.linear(p["wo"], out, dt), jnp.concatenate([c_kv, k_rope], axis=-1)


def mla_decode(p, cfg: ModelConfig, x, cache, t, impl=None):
    """Absorbed-form decode: attention in the 512-dim latent space.

    cache: (B, Smax, kv_lora_rank + rope_dim) compressed entries.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    H, R = cfg.num_heads, cfg.kv_lora_rank
    positions = jnp.broadcast_to(t, (B, 1))
    q_nope, q_rope = _mla_q(p, cfg, x, positions, dt)           # (B,1,H,*)
    c_new, kr_new = _mla_latent(p, cfg, x, positions, dt)       # (B,1,R), (B,1,rope)
    entry = jnp.concatenate([c_new, kr_new], axis=-1)
    cache = jax.lax.dynamic_update_slice_in_dim(cache, entry.astype(cache.dtype), t, axis=1)
    c_all = cache[..., :R].astype(dt)                           # (B,S,R)
    kr_all = cache[..., R:].astype(dt)                          # (B,S,rope)
    # absorb W_uk into q:  q_abs = q_nope @ W_uk  -> latent-space query
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, p["wk_b"].astype(dt))  # (B,1,H,R)
    q_cat = jnp.concatenate([q_abs, q_rope], axis=-1)           # (B,1,H,R+rope)
    k_cat = jnp.concatenate([c_all, kr_all], axis=-1)[:, :, None, :]    # (B,S,1,R+rope)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.qk_head_dim))        # true head-dim scale
    out_lat = ops.attention(
        q_cat, k_cat, c_all[:, :, None, :],
        causal=False, kv_len=t + 1, impl=impl or cfg.attn_impl, decode=True, scale=scale,
    )                                                            # (B,1,H,R)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, p["wv_b"].astype(dt))
    out = out.reshape(B, 1, H * cfg.v_head_dim)
    return L.linear(p["wo"], out, dt), cache
