"""Shared env-knob parsing.

Three runtime knobs follow the same contract — ``REPRO_RESTORE_WORKERS``,
``REPRO_HASH_WORKERS`` and ``REPRO_IO_BATCH``: a positive-integer value wins
outright; anything mangled (non-integer, zero, negative) degrades to the
caller's auto sizing with a logged warning.  An operator typo in a job
script must never turn into a ``ValueError`` at restore time, which is
exactly when the job can least afford to die.  This helper is the single
implementation of that parse; the knobs themselves live next to the code
they size.
"""
from __future__ import annotations

import logging
import os
from typing import Optional

log = logging.getLogger(__name__)


def env_positive_int(name: str, *,
                     logger: Optional[logging.Logger] = None) -> Optional[int]:
    """Parse ``$name`` as a positive integer.  Returns the value when valid,
    ``None`` when unset/empty, and ``None`` WITH a logged warning when set
    but mangled — the caller falls back to its auto sizing either way."""
    env = os.environ.get(name, "").strip()
    if not env:
        return None
    try:
        n = int(env)
    except ValueError:
        n = None
    if n is not None and n >= 1:
        return n
    (logger or log).warning(
        "ignoring invalid %s=%r (want a positive integer); "
        "falling back to auto sizing", name, env)
    return None
