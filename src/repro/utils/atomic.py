"""Atomic single-file publish helpers (mkstemp + ``os.replace``).

One hardened implementation of the write-tmp-then-rename idiom, shared by
every small-record publisher in the tree: the cache registry's inventory
entries, the requeue accounting file, and the I/O calibration cache.  The
tmp name is UNIQUE (``mkstemp`` in the target's own directory): a fixed
``<name>.tmp`` path would let two concurrent writers of the same key
interleave write/rename — one renames the other's half-written tmp,
publishing a file that parses but mixes two records.  ``mkstemp`` keeps
the rename same-filesystem (hence atomic), and each writer renames only
bytes it wrote in full.  The tmp is unlinked on any failure, so aborted
writes leave no litter behind.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path


def atomic_write_bytes(path, data: bytes) -> None:
    """Atomically publish ``data`` at ``path`` (unique tmp + ``os.replace``)."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=p.name + ".", suffix=".tmp",
                               dir=p.parent)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path, obj) -> None:
    """Atomically publish ``obj`` as JSON at ``path``."""
    atomic_write_bytes(path, json.dumps(obj).encode())
