"""Small pytree helpers used across the framework (no flax/optax available)."""
from __future__ import annotations

import re
from typing import Any, Callable

import jax
import numpy as np


def tree_map(f: Callable, *trees) -> Any:
    return jax.tree_util.tree_map(f, *trees)


def path_str(path) -> str:
    """Render a jax KeyPath as 'a/b/0/c'."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(f: Callable, tree, *rest) -> Any:
    return jax.tree_util.tree_map_with_path(f, tree, *rest)


def flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), v) for p, v in flat]


def unflatten_like(template, named: dict[str, Any]) -> Any:
    """Rebuild a pytree shaped like ``template`` from a {path: leaf} dict."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, old in flat:
        name = path_str(p)
        if name not in named:
            raise KeyError(f"missing leaf {name!r} while unflattening")
        leaves.append(named[name])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "shape")
    )


def tree_count(tree) -> int:
    return sum(
        int(np.prod(x.shape))
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "shape")
    )


_SAFE = re.compile(r"[^A-Za-z0-9_.\-]")


def safe_filename(name: str) -> str:
    return _SAFE.sub("_", name)
