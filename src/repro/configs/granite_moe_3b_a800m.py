"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert
vocab=49155, MoE 40 experts top-8.

The assignment line says "MoE 40e top-8" (its comment says 32e); we implement the
primary spec: 40 experts.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    num_experts_per_tok=8,
    moe_d_ff=512,
    rope_theta=1e4,
    compute_dtype="bfloat16",
    norm_eps=1e-6,
)
