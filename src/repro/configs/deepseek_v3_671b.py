"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048/expert vocab=129280,
MoE 256 routed (top-8) + 1 shared expert, MLA, MTP.

MLA: q_lora 1536, kv_lora 512, qk = 128 nope + 64 rope, v 128; decode runs in
absorbed (latent) form over the 576-dim compressed cache.  First 3 layers dense
(d_ff follows the expert width per the assigned spec).  MTP depth 1.
bf16 params/moments by default so the 512-chip multi-pod fits (see EXPERIMENTS).
[arXiv:2412.19437; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,                # dense-layer FFN width (first 3 layers)
    vocab_size=129280,
    mixer="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_experts_per_tok=8,
    moe_d_ff=2048,
    num_shared_experts=1,
    first_dense_layers=3,
    mtp_depth=1,
    rope_theta=1e4,
    norm_eps=1e-6,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
