"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens: 4 codebooks, summed embeddings,
4 LM heads.  The EnCodec frontend and delay-pattern interleaving are data-pipeline
stubs (``input_specs`` supplies codebook token ids (B,S,4)).  [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    num_codebooks=4,
    rope_theta=1e4,
    compute_dtype="bfloat16",
    norm_eps=1e-5,
)
