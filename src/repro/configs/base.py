"""Model / shape configuration system.

Every assigned architecture is expressed as a :class:`ModelConfig`.  A config is a
pure-data description; the model code in ``repro.models`` interprets it.  Each arch
module under ``repro.configs`` exports ``CONFIG`` (the exact published numbers) and the
registry maps ``--arch <id>`` to it.  ``reduced()`` derives the CPU-smoke-test variant.
"""
from __future__ import annotations

import dataclasses

# ----------------------------------------------------------------------------------
# Block kinds understood by repro.models.blocks
# ----------------------------------------------------------------------------------
ATTN = "attn"          # (GQA/MHA) attention mixer + dense FFN
MLA = "mla"            # DeepSeek multi-head latent attention + (MoE or dense) FFN
MAMBA2 = "mamba2"      # Mamba2 SSD mixer (its own gated FFN path inside)
RWKV6 = "rwkv6"        # RWKV6 time-mix + channel-mix


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavour -----------------------------------------------------
    mixer: str = ATTN                 # ATTN | MLA | MAMBA2 | RWKV6
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0

    # --- MLA (DeepSeek) ----------------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---------------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0       # leading layers that keep a dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    moe_dispatch_bits: int = 16       # 8 = int8-quantized dispatch all-to-all
                                      # (DeepSeek-V3 trains with FP8 dispatch)

    # --- SSM (Mamba2) --------------------------------------------------------------
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- RWKV6 ---------------------------------------------------------------------
    rwkv_lora_mix: int = 32           # ddlerp lora rank for r/k/v/g
    rwkv_lora_decay: int = 64         # decay lora rank

    # --- hybrid (zamba2) -------------------------------------------------------------
    shared_attn_period: int = 0       # apply the shared attention block every N layers

    # --- heads / embeddings -----------------------------------------------------------
    tie_embeddings: bool = False
    num_codebooks: int = 0            # musicgen: K codebooks, K lm heads
    mtp_depth: int = 0                # deepseek multi-token-prediction heads
    num_image_tokens: int = 0         # llava: stub patch-embedding count

    norm_eps: float = 1e-5

    # --- numerics / impl knobs ----------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_impl: str = "auto"           # auto | xla | xla_chunked | pallas | pallas_interpret
    remat: str = "full"               # full | dots | none
    scan_layers: bool = True

    def __post_init__(self):
        if self.mixer in (ATTN, MLA):
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.num_experts:
            assert self.num_experts_per_tok > 0 and self.moe_d_ff > 0, self.name

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def qk_head_dim(self) -> int:
        if self.mixer == MLA:
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.head_dim

    @property
    def v_dim(self) -> int:
        return self.v_head_dim if self.mixer == MLA else self.head_dim

    @property
    def mla_cache_dim(self) -> int:
        return self.kv_lora_rank + self.qk_rope_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports very long contexts (O(1)/O(chunk) state)."""
        return self.mixer in (MAMBA2, RWKV6) or (
            self.mixer == ATTN and self.shared_attn_period == 0 and self.family == "ssm"
        ) or self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops in the roofline)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (kind, seq_len, global_batch)."""

    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The assigned shape cells for an arch. long_500k only for sub-quadratic archs."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out


# Grad-accumulation microbatch counts for train_4k (global_batch=256), per arch.
# Chosen so per-microbatch activations fit v5e HBM alongside params+optimizer
# (see EXPERIMENTS.md §Dry-run).  Key: arch name -> num_microbatches.
TRAIN_MICROBATCHES: dict[str, int] = {
    "qwen2-0.5b": 4,
    "llama3.2-1b": 2,
    "qwen3-4b": 4,
    "granite-8b": 8,
    "zamba2-1.2b": 2,
    "llava-next-mistral-7b": 8,
    "granite-moe-3b-a800m": 4,
    "deepseek-v3-671b": 16,
    "musicgen-large": 4,
    "rwkv6-1.6b": 2,
}


# ----------------------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------------------
ARCH_IDS = [
    "qwen2-0.5b",
    "granite-8b",
    "qwen3-4b",
    "llama3.2-1b",
    "zamba2-1.2b",
    "llava-next-mistral-7b",
    "granite-moe-3b-a800m",
    "deepseek-v3-671b",
    "musicgen-large",
    "rwkv6-1.6b",
]

_MODULES = {
    "qwen2-0.5b": "qwen2_0_5b",
    "granite-8b": "granite_8b",
    "qwen3-4b": "qwen3_4b",
    "llama3.2-1b": "llama3_2_1b",
    "zamba2-1.2b": "zamba2_1_2b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "musicgen-large": "musicgen_large",
    "rwkv6-1.6b": "rwkv6_1_6b",
}


def get_config(arch: str) -> ModelConfig:
    import importlib

    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (shapes asserted, no NaNs)."""
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
        scan_layers=False,
        remat="none",
    )
    if cfg.mixer == MLA:
        kw.update(
            num_kv_heads=4,
            q_lora_rank=64,
            kv_lora_rank=32,
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=32,
        )
    if cfg.num_experts:
        kw.update(num_experts=8, num_experts_per_tok=2, moe_d_ff=64)
    if cfg.mixer == MAMBA2 or cfg.family == "hybrid":
        kw.update(ssm_state_dim=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.mixer == RWKV6:
        kw.update(head_dim=32, rwkv_lora_mix=8, rwkv_lora_decay=16)
    if cfg.shared_attn_period:
        kw.update(shared_attn_period=2)
    if cfg.num_image_tokens:
        kw.update(num_image_tokens=16)
    if cfg.first_dense_layers:
        kw.update(first_dense_layers=1)
    return cfg.replace(**kw)
