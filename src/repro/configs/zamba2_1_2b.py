"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.

Mamba2 backbone + shared attention blocks (weights reused across invocations,
input = concat(hidden, original embedding)).  Shared block applied every 6 mamba
layers (6 invocations, 2 tail layers).  Per-invocation LoRA adapters are omitted
(DESIGN.md simplification note).  [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    mixer="mamba2",
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_period=6,
    tie_embeddings=True,
    compute_dtype="bfloat16",
    norm_eps=1e-5,
)
