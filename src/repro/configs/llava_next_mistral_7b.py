"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

Mistral-7B backbone; anyres vision frontend is a STUB — ``input_specs`` supplies
precomputed patch embeddings (B, num_image_tokens, d_model) merged at the head of
the sequence.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_image_tokens=2880,      # ~5 anyres tiles x 576 patches
    rope_theta=1e6,
    compute_dtype="bfloat16",
    norm_eps=1e-5,
)
