"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.

RWKV6 "Finch": token-shift ddlerp, data-dependent per-channel decay, WKV6
recurrence, channel-mix FFN.  O(1) state -> runs the long_500k cell.
[arXiv:2404.05892; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # wkv heads = d_model / head_dim
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    mixer="rwkv6",
    rwkv_lora_mix=32,
    rwkv_lora_decay=64,
    compute_dtype="bfloat16",
    norm_eps=1e-5,
)
