"""Deterministic synthetic data pipeline with a checkpointable cursor.

The paper's DMTCP captures a process's open-file offsets so a restarted job
continues reading where it left off; the framework equivalent is an explicitly
checkpointable pipeline cursor.  ``state()``/``restore()`` round-trips exactly:
batch k after a restore is bit-identical to batch k of an uninterrupted run
(verified by tests/test_data_pipeline.py and the end-to-end preemption test).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self) -> dict:
        return {"seed": int(self.seed), "step": int(self.step)}

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticTokens:
    """Counter-based RNG: batch(step) depends only on (seed, step)."""

    def __init__(self, cfg: ModelConfig, batch_size: int, seq_len: int,
                 seed: int = 0, start_step: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self._state = PipelineState(seed=seed, step=start_step)

    # ------------------------------------------------------------------
    def state(self) -> PipelineState:
        return PipelineState(self._state.seed, self._state.step)

    def restore(self, state: PipelineState) -> None:
        self._state = PipelineState(state.seed, state.step)

    # ------------------------------------------------------------------
    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng([self._state.seed, step])
        shape = (self.batch_size, self.seq_len)
        if cfg.num_codebooks:
            shape = shape + (cfg.num_codebooks,)
        batch = {"tokens": rng.integers(0, cfg.vocab_size, size=shape, dtype=np.int32)}
        if cfg.num_image_tokens:
            batch["image_embeds"] = rng.standard_normal(
                (self.batch_size, cfg.num_image_tokens, cfg.d_model), dtype=np.float32)
        return batch

    def __next__(self) -> dict:
        b = self.batch_at(self._state.step)
        self._state.step += 1
        return b

    def __iter__(self):
        return self
