"""Ambient mesh/rules context for model-internal distribution decisions.

Step factories install the active mesh + rules here; deep model code (e.g. the
ring-attention dispatch in kernels/ops.py) reads it without threading mesh
objects through every layer signature.
"""
from __future__ import annotations

from contextvars import ContextVar

_MESH = ContextVar("repro_mesh", default=None)
_RULES = ContextVar("repro_rules", default=None)


class use_mesh_context:
    def __init__(self, mesh, rules=None):
        self.mesh = mesh
        self.rules = rules
        self._toks = None

    def __enter__(self):
        self._toks = (_MESH.set(self.mesh), _RULES.set(self.rules))
        return self

    def __exit__(self, *exc):
        _MESH.reset(self._toks[0])
        _RULES.reset(self._toks[1])


def current_mesh():
    return _MESH.get()


def current_rules():
    return _RULES.get()
