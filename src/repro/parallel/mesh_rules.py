"""Logical-axis -> mesh-axis resolution with divisibility fallback.

Every tensor in the framework is annotated with *logical* axis names (see
models/layers.py).  A :class:`Rules` object maps those names onto the physical
mesh.  Assignment is greedy in priority order: each mesh axis is used at most
once per tensor, and a candidate is skipped when the dim size doesn't divide the
mesh-axis size (the 40 heterogeneous arch cells make hand-tuning infeasible —
e.g. qwen2's 14 heads can't split 16-way, so they fall back to replicated while
its MLP still TP-shards).

This table IS the distribution strategy: FSDP = param "embed"/"expert" dims on
the data axis, TP = heads/mlp/vocab dims on the model axis, EP = expert dim on
(pod,data), DP = batch on (pod,data).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (priority, candidates) per logical axis name.  Lower priority assigns first.
# Candidates are tuples of mesh axes tried in order.
_DEFAULT_RULES: dict[str, tuple[int, list[tuple[str, ...]]]] = {
    # --- activations ---------------------------------------------------------
    "batch":          (0, [("pod", "data"), ("data",)]),
    "exp_group":      (0, [("pod", "data"), ("data",)]),
    "seq":            (5, []),                 # sequence parallelism: opt-in (perf pass)
    "cache_seq":      (4, [("model",)]),       # used when head dims can't shard
    "heads_dim":      (1, [("model",)]),
    "kv_heads_dim":   (1, [("model",)]),
    "ssm_heads_dim":  (1, [("model",)]),
    "mlp":            (1, [("model",)]),
    # --- params ---------------------------------------------------------------
    "expert":         (0, [("pod", "data"), ("data",)]),
    # MoE capacity slots: EP fallback when num_experts doesn't divide the data
    # axis (granite-moe's 40 experts on 16 shards) — slots shard instead, expert
    # compute stays fully local, dispatch/combine become bf16 all-to-alls.
    "moe_cap":        (1, [("pod", "data"), ("data",)]),
    "heads":          (1, [("model",)]),
    "kv_heads":       (1, [("model",)]),
    "vocab":          (1, [("model",)]),
    "ssm_inner":      (1, [("model",)]),
    "ssm_heads":      (3, []),                 # tiny per-head vectors: replicate
    "embed":          (2, [("data",)]),        # FSDP shard of the param matrix
    "layers":         (5, []),
}


class Rules:
    def __init__(self, mesh: Mesh, overrides: Optional[dict] = None,
                 fsdp: bool = True):
        self.mesh = mesh
        table = dict(_DEFAULT_RULES)
        if not fsdp:
            table["embed"] = (2, [])
        if overrides:
            table.update(overrides)
        self.table = table
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    # ------------------------------------------------------------------
    def spec(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        """Resolve one tensor's logical axes to a PartitionSpec."""
        assert len(axes) == len(shape), (axes, shape)
        order = sorted(
            range(len(axes)),
            key=lambda i: self.table.get(axes[i], (9, []))[0] if axes[i] else 9,
        )
        used: set[str] = set()
        assign: list[Optional[tuple[str, ...]]] = [None] * len(axes)
        for i in order:
            name = axes[i]
            if name is None or name not in self.table:
                continue
            for cand in self.table[name][1]:
                cand = tuple(a for a in cand if a in self.axis_sizes)
                if not cand or any(a in used for a in cand):
                    continue
                size = int(np.prod([self.axis_sizes[a] for a in cand]))
                if shape[i] % size != 0:
                    # try a shorter suffix of the candidate (e.g. ('data',) of
                    # ('pod','data')) before giving up
                    ok = False
                    for k in range(1, len(cand)):
                        sub = cand[k:]
                        ssize = int(np.prod([self.axis_sizes[a] for a in sub]))
                        if shape[i] % ssize == 0 and not any(a in used for a in sub):
                            cand, ok = sub, True
                            break
                    if not ok:
                        continue
                assign[i] = cand
                used.update(cand)
                break
        parts = [a if a is None else (a[0] if len(a) == 1 else a) for a in assign]
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def axis_group_size(self, name: str) -> int:
        """Total shard count the first viable candidate of ``name`` provides."""
        for cand in self.table.get(name, (9, []))[1]:
            cand = tuple(a for a in cand if a in self.axis_sizes)
            if cand:
                return int(np.prod([self.axis_sizes[a] for a in cand]))
        return 1

    # ------------------------------------------------------------------
    def tree_shardings(self, axes_tree, abstract_tree):
        """Matching trees of logical axes + ShapeDtypeStructs -> NamedShardings."""
        return jax.tree_util.tree_map(
            lambda ax, sds: self.sharding(ax, sds.shape),
            axes_tree,
            abstract_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x
            ),
        )

    # ------------------------------------------------------------------
    def activation_resolver(self):
        """Resolver installed via layers.use_shard_resolver for shard_hint calls.

        Divisibility IS enforced (uneven intermediate shards trigger involuntary
        full rematerialization in the SPMD partitioner — observed with qwen2's
        14 heads on a 16-way model axis)."""

        def resolve(axes, shape):
            try:
                return self.sharding(axes, shape)
            except Exception:
                return None

        return resolve


def batch_logical_axes(batch: dict) -> dict:
    """Logical axes for an input batch pytree."""
    out = {}
    for k, v in batch.items():
        if k == "tokens":
            out[k] = ("batch", "seq") + ((None,) if v.ndim == 3 else ())
        elif k == "image_embeds":
            out[k] = ("batch", None, None)
        elif k == "loss_mask":
            out[k] = ("batch", "seq")
        else:
            out[k] = (None,) * v.ndim
    return out
