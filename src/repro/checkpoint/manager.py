"""CheckpointManager: sharded, atomic, optionally async/incremental checkpoints.

Layout under the store (per tier):
  <prefix>/step_<N>/shard_w<world-id>.bin     one shard per worker
  <prefix>/step_<N>/wpart_<id>.json           per-worker manifest part
  <prefix>/step_<N>/MANIFEST.json             atomic commit marker (written LAST,
                                              by the coordinator / single worker)

A checkpoint exists iff MANIFEST.json exists — a preemption mid-write leaves no
manifest and the restart falls back to the previous step (two-phase commit, the
framework analogue of DMTCP's coordinator barrier).

Leaf ownership: leaf i belongs to worker (i % num_workers).  Restore reads every
worker part, so a checkpoint taken with N workers restores under M workers (the
MxN / elastic-restart property; mesh placement is re-derived by
core/virtualization.py).

Incremental mode (beyond-paper): a leaf whose crc32 is unchanged since the
previous *committed* checkpoint is not rewritten — its manifest entry points at
the older shard file.  GC keeps referenced base files alive.

I/O plane (see EXPERIMENTS.md): each leaf is CRC'd exactly once per save (a
zero-copy pass that doubles as the incremental diff), then streamed through
``TieredStore.put_stream`` into a v2 shard — no whole-shard buffer, and the
k-replica fan-out is an OS-level copy of the primary.  Restore is
leaf-granular: only the byte ranges the manifest actually references are read
from each shard, so an incremental/MxN restore no longer re-reads whole base
shards.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Optional

import numpy as np

from repro.checkpoint import serialization as SER
from repro.checkpoint.async_writer import AsyncWriter
from repro.checkpoint.store import TieredStore


def _step_dir(prefix: str, step: int) -> str:
    return f"{prefix}/step_{step:010d}"


class CheckpointManager:
    def __init__(self, store: TieredStore, *, tier: str = "shared",
                 worker_id: int = 0, num_workers: int = 1, replicas: int = 2,
                 mode: str = "sync", incremental: bool = False,
                 keep_last: int = 3, prefix: str = "ckpt",
                 shard_format: int = 2):
        assert mode in ("sync", "async")
        assert shard_format in (1, 2)      # 1 = legacy writer (compat tests)
        self.store = store
        self.tier = tier
        self.worker_id = worker_id
        self.num_workers = num_workers
        self.replicas = replicas
        self.mode = mode
        self.incremental = incremental
        self.keep_last = keep_last
        self.prefix = prefix
        self.shard_format = shard_format
        self._writer = AsyncWriter() if mode == "async" else None
        self._prev_manifest: Optional[dict] = None

    # ------------------------------------------------------------------
    def _my_leaves(self, records):
        return [
            (i, name, arr) for i, (name, arr) in enumerate(records)
            if i % self.num_workers == self.worker_id
        ]

    def save(self, step: int, tree, extra_meta: Optional[dict] = None) -> dict:
        """Snapshot + write this worker's shard.  Returns the worker part dict.

        In async mode the device->host snapshot happens here (the only quiesced
        section); serialization and store writes run on the writer pool.  Each
        leaf's CRC32 is computed exactly once per save, from a zero-copy byte
        view, and serves as both the incremental diff key and the stored shard
        checksum — see the ``diff`` comment below for where it is computed.
        """
        t0 = time.time()
        records = SER.tree_to_records(tree)            # snapshot (device_get)
        snap_s = time.time() - t0
        mine = self._my_leaves(records)
        sdir = _step_dir(self.prefix, step)
        shard_rel = f"{sdir}/shard_w{self.worker_id:05d}.bin"

        prev_entries = {}
        # The incremental diff needs every leaf's CRC before deciding what to
        # stream, so it pre-computes them (one zero-copy pass) and hands them
        # to the writer via ``crcs=``.  Without a diff, the CRC is instead
        # folded chunk-by-chunk inside the streaming writer, overlapped with
        # the replica disk writes.  Either way: exactly one CRC per leaf
        # (except shard_format=1, whose legacy writer re-CRCs internally —
        # compat path only).  In async v2 mode the writer-pool task fills the
        # folded CRCs into the returned part's entries (atomic per-field);
        # they are final once ``wait_writes()`` returns, which ``commit()``
        # always awaits before reading parts back.
        diff = self.incremental and self._prev_manifest is not None
        if diff:
            prev_entries = {
                e["path"]: e for e in self._prev_manifest["leaves"]
            }

        entries, to_write, crcs = [], [], {}
        pending = {}                        # name -> entry awaiting writer crc
        for idx, name, arr in mine:
            if diff or self.shard_format == 1:
                crc = SER.leaf_checksum(arr)
                prev = prev_entries.get(name)
                if prev is not None and prev["crc32"] == crc and prev.get("file"):
                    entries.append({**prev, "reused": True})
                    continue
                crcs[name] = crc
            else:
                crc = None
            to_write.append((name, arr))
            entry = {
                "path": name, "index": idx, "crc32": crc,
                "dtype": str(arr.dtype), "shape": list(arr.shape),
                "file": shard_rel, "reused": False,
            }
            if crc is None:
                pending[name] = entry
            entries.append(entry)

        part = {
            "worker_id": self.worker_id,
            "num_workers": self.num_workers,
            "step": step,
            "leaves": entries,
            "snapshot_s": snap_s,
            "meta": extra_meta or {},
        }

        def do_write():
            # the wpart references writer-computed CRCs, so in async mode the
            # whole body runs as one pool task; commit()'s wait_writes() is
            # the barrier before the manifest is cut
            if to_write:
                if self.shard_format == 1:     # legacy byte-identical v1 path
                    data = SER.write_shard_bytes(to_write, meta={"step": step})
                    self.store.put(self.tier, shard_rel, data,
                                   replicas=self.replicas)
                else:
                    footer = {}
                    self.store.put_stream(
                        self.tier, shard_rel,
                        lambda fp: footer.update(SER.write_shard_stream(
                            fp, to_write, meta={"step": step},
                            crcs=crcs or None)),
                        replicas=self.replicas)
                    for t in footer["tensors"]:
                        if t["path"] in pending:
                            pending[t["path"]]["crc32"] = t["crc32"]
            self.store.put(
                self.tier, f"{sdir}/wpart_{self.worker_id:05d}.json",
                json.dumps(part).encode(), replicas=self.replicas)

        if self._writer is not None:
            self._writer.submit(do_write)
        else:
            do_write()
        return part

    def wait_writes(self, timeout: Optional[float] = None) -> None:
        if self._writer is not None:
            self._writer.wait(timeout)

    # ------------------------------------------------------------------
    def commit(self, step: int, *, num_workers: Optional[int] = None,
               extra_meta: Optional[dict] = None) -> dict:
        """Coordinator-side: verify all worker parts exist, write MANIFEST last."""
        self.wait_writes()
        nw = num_workers or self.num_workers
        sdir = _step_dir(self.prefix, step)
        leaves = []
        meta: dict = {}
        for w in range(nw):
            raw = self.store.get(self.tier, f"{sdir}/wpart_{w:05d}.json")
            part = json.loads(raw.decode())
            leaves.extend(part["leaves"])
            meta.update(part.get("meta") or {})   # worker metas merge (w0 first)
        leaves.sort(key=lambda e: e["index"])
        meta.update(extra_meta or {})
        manifest = {
            "step": step,
            "num_workers": nw,
            "leaves": leaves,
            "committed_at": time.time(),
            "meta": meta,
        }
        self.store.put(self.tier, f"{sdir}/MANIFEST.json",
                       json.dumps(manifest).encode(), replicas=self.replicas)
        self._prev_manifest = manifest
        self.gc()
        return manifest

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        rels = self.store.list_prefix(self.tier, self.prefix)
        out = set()
        for r in rels:
            parts = Path(r).parts
            if len(parts) >= 2 and parts[-1] == "MANIFEST.json":
                out.add(int(parts[-2].split("_")[1]))
        return sorted(out)

    def read_manifest(self, step: int) -> dict:
        raw = self.store.get(self.tier, f"{_step_dir(self.prefix, step)}/MANIFEST.json")
        return json.loads(raw.decode())

    def restore(self, template, step: Optional[int] = None):
        """Returns (host_tree, manifest).

        Leaf-granular: for each shard file the manifest references, only the
        byte ranges of the referenced leaves are fetched (``read_shard_leaves``
        coalesces adjacent ones) — an incremental manifest that points one leaf
        at an old base shard reads just that leaf, not the whole base file.
        Per-leaf CRCs are pinned to the manifest values and payload bytes are
        verified against them; replica fallback happens inside the store.
        Reads both shard formats (v1 seed files and v2).
        """
        all_steps = self.steps()
        if not all_steps:
            raise FileNotFoundError("no committed checkpoint found")
        step = all_steps[-1] if step is None else step
        manifest = self.read_manifest(step)
        by_file: dict[str, list[dict]] = {}
        for e in manifest["leaves"]:
            by_file.setdefault(e["file"], []).append(e)
        named: dict[str, np.ndarray] = {}
        for rel, ents in by_file.items():
            tensors, _ = self.store.read_shard_leaves(
                self.tier, rel, [e["path"] for e in ents],
                expect_crcs={e["path"]: e["crc32"] for e in ents})
            for e in ents:
                named[e["path"]] = tensors[e["path"]]
        tree = SER.restore_tree(template, named)
        self._prev_manifest = manifest
        return tree, manifest

    # ------------------------------------------------------------------
    def gc(self) -> None:
        """Old manifests are always removed (a checkpoint 'exists' iff its
        manifest does); step dirs survive only while an incremental manifest in
        the kept set references their shard files."""
        steps = self.steps()
        keep = set(steps[-self.keep_last:]) if self.keep_last else set(steps)
        referenced_dirs = set()
        for s in keep:
            man = self.read_manifest(s)
            for e in man["leaves"]:
                referenced_dirs.add(str(Path(e["file"]).parent))
        for s in steps:
            if s in keep:
                continue
            sdir = _step_dir(self.prefix, s)
            if sdir in referenced_dirs:
                # keep the shard data, retire the manifest + parts.  The
                # retired step may have been written under a DIFFERENT worker
                # count (elastic restart), so the part count comes from the
                # step's own manifest — not this manager's num_workers.
                try:
                    nw = int(self.read_manifest(s).get("num_workers",
                                                       self.num_workers))
                except (FileNotFoundError, ValueError, KeyError):
                    nw = 0
                self.store.delete_file(self.tier, f"{sdir}/MANIFEST.json")
                if nw:
                    for w in range(nw):
                        self.store.delete_file(
                            self.tier, f"{sdir}/wpart_{w:05d}.json")
                else:   # manifest unreadable: sweep whatever parts exist
                    for rel in self.store.list_prefix(self.tier, sdir):
                        if Path(rel).name.startswith("wpart_"):
                            self.store.delete_file(self.tier, rel)
            else:
                self.store.delete_prefix(self.tier, sdir)

    def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
