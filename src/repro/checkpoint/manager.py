"""CheckpointManager: sharded, atomic, optionally async/incremental checkpoints.

Layout under the store (per tier):
  <prefix>/step_<N>/shard_w<world-id>.bin     one shard per worker
  <prefix>/step_<N>/wpart_<id>.json           per-worker manifest part
  <prefix>/step_<N>/MANIFEST.json             atomic commit marker (written LAST,
                                              by the coordinator / single worker)

A checkpoint exists iff MANIFEST.json exists — a preemption mid-write leaves no
manifest and the restart falls back to the previous step (two-phase commit, the
framework analogue of DMTCP's coordinator barrier).

Leaf ownership: leaf i belongs to worker (i % num_workers).  Restore reads every
worker part, so a checkpoint taken with N workers restores under M workers (the
MxN / elastic-restart property; mesh placement is re-derived by
core/virtualization.py).

Incremental mode (beyond-paper): a leaf whose crc32 is unchanged since the
previous *committed* checkpoint is not rewritten — its manifest entry points at
the older shard file.  GC keeps referenced base files alive.

Delta mode (``delta=True``, shard v3): the chunk-granular successor to
incremental — every leaf is split into fixed-size content-addressed chunks
and a save writes only the chunks whose hash changed since the parent step
(manifest v2 records the baseline+delta chain; GC reaps chunks by refcount).
Restores resolve each chunk against stale-local-cache -> peers -> shared, so
a warm-but-stale node fetches only the delta it is missing.

I/O plane (see EXPERIMENTS.md): each leaf is CRC'd exactly once per save (a
zero-copy pass that doubles as the incremental diff), then streamed through
``TieredStore.put_stream`` into a v2 shard — no whole-shard buffer, and the
k-replica fan-out is an OS-level copy of the primary.  Restore is
leaf-granular: only the byte ranges the manifest actually references are read
from each shard, so an incremental/MxN restore no longer re-reads whole base
shards.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint import serialization as SER
from repro.checkpoint.async_writer import AsyncWriter, WorkPool
from repro.checkpoint.policy import PROMOTE_POLICIES, CheckpointPolicy
from repro.checkpoint.restore_engine import ParallelRestorer
from repro.checkpoint.store import (TieredStore, chunk_refcounts, chunk_rel,
                                    manifest_chunk_hashes)

__all__ = ["CheckpointManager", "CheckpointPolicy", "PROMOTE_POLICIES"]

# how far behind a stale peer's cached step may be before the chunk plane
# stops considering it a source: chunk overlap decays with step distance, and
# past this lag the probe cost (per-chunk existence checks over the
# interconnect) outweighs the expected hits
STALE_PEER_MAX_LAG = 64

# cap on per-probe stat calls in validate_promoted_cache: a delta cache
# references one file per chunk, and the scheduler probes MANY nodes
PROBE_MAX_FILES = 64


def _step_dir(prefix: str, step: int) -> str:
    return f"{prefix}/step_{step:010d}"


def is_chunked_manifest(manifest: dict) -> bool:
    """True when any leaf resolves through the content-addressed chunk plane
    (v3 delta checkpoints) rather than a shard file.  Keyed on the presence
    of ``chunks`` — a zero-byte leaf legitimately has an EMPTY chunk list
    and must still restore through the chunk plane, not vanish."""
    return any("chunks" in e for e in manifest.get("leaves") or ())


def manifest_payload_map(manifest: dict, prefix: str) -> dict[str, tuple]:
    """Every payload file a manifest references, with what verifies it:
    ``rel -> ("shard", [leaf entries])`` for v1/v2 file-based leaves,
    ``rel -> ("chunk", chunk entry)`` for content-addressed chunks.  The
    single definition promotion, cache validation and the registry all share
    — so a delta checkpoint promotes/validates chunk-by-chunk exactly like a
    full one promotes shard-by-shard."""
    out: dict[str, tuple] = {}
    for e in manifest["leaves"]:
        if "chunks" in e:
            for c in e["chunks"]:
                out.setdefault(chunk_rel(prefix, c["hash"]), ("chunk", c))
        elif e.get("file"):
            out.setdefault(e["file"], ("shard", []))[1].append(e)
    return out


def committed_steps(store: TieredStore, tier: str, prefix: str) -> list[int]:
    """Steps with a MANIFEST.json on ``tier`` (a checkpoint exists iff its
    manifest does).  Module-level so schedulers can enumerate without
    constructing a manager."""
    out = set()
    for r in store.list_prefix(tier, prefix):
        parts = Path(r).parts
        if len(parts) >= 2 and parts[-1] == "MANIFEST.json":
            out.add(int(parts[-2].split("_")[1]))
    return sorted(out)


def validate_promoted_cache(store: TieredStore, *, tier: str = "shared",
                            promote_tier: str = "local",
                            prefix: str = "ckpt",
                            latest: Optional[int] = None) -> dict:
    """Scheduler-facing cache inventory: is ``promote_tier``'s promoted cache
    warm for the LATEST step committed on ``tier``?

    Invalidation-aware and cheap (no payload reads): the marker must parse
    (a torn ``PROMOTED.json`` is cold, not an error), its step must equal the
    latest committed step (a superseded marker is stale), the promoted
    manifest must parse and match, and referenced payload files (shards or
    chunks; sampled when a delta cache references more than
    ``PROBE_MAX_FILES`` of them) must exist in the promote tier at the
    source file's size (catching truncation).
    Deliberately advisory — deep CRC verification stays in the restore path,
    so a probe that wrongly says "warm" costs one cache miss, never stale
    bytes.

    Returns ``{"valid", "step", "latest", "files", "reason"}``.  A caller
    probing MANY nodes against one shared tier can pass ``latest`` (the
    newest committed step) to skip the per-node re-listing of the shared
    prefix — the listing is node-independent.
    """
    info: dict = {"valid": False, "step": None, "latest": None,
                  "files": 0, "reason": ""}
    if latest is None:
        steps = committed_steps(store, tier, prefix)
        latest = steps[-1] if steps else None
    info["latest"] = latest
    marker_rel = f"{prefix}/PROMOTED.json"
    try:
        marker = json.loads(store.get(promote_tier, marker_rel).decode())
        if not isinstance(marker, dict):
            raise ValueError("marker is not an object")
    except FileNotFoundError:
        # get() reports an unreadable-everywhere file as not-found; a marker
        # that exists but cannot be read is torn, not absent
        info["reason"] = ("torn promoted marker"
                         if store.exists(promote_tier, marker_rel)
                         else "no promoted marker")
        return info
    except (ValueError, OSError):
        info["reason"] = "torn promoted marker"
        return info
    info["step"] = step = marker.get("step")
    if info["latest"] is None:
        info["reason"] = "no committed checkpoint on source tier"
        return info
    if step != info["latest"]:
        info["reason"] = f"stale (cached step {step}, latest {info['latest']})"
        return info
    try:
        man = json.loads(store.get(
            promote_tier, f"{_step_dir(prefix, step)}/MANIFEST.json").decode())
        if man.get("step") != step:
            raise ValueError("promoted manifest step mismatch")
        rels = sorted(manifest_payload_map(man, prefix))
    except (FileNotFoundError, ValueError, OSError, KeyError, TypeError):
        info["reason"] = "damaged promoted manifest"
        return info
    probe = rels
    if len(rels) > PROBE_MAX_FILES:
        # a chunked (delta) cache can reference thousands of chunk files;
        # stat'ing them all would break this probe's "cheap, many nodes"
        # contract.  The probe is ADVISORY by design (deep verification
        # stays in the restore path), so an evenly-spaced sample bounds the
        # cost — a wrongly-warm verdict costs one cache miss, never stale
        # bytes
        stride = len(rels) / PROBE_MAX_FILES
        probe = [rels[int(i * stride)] for i in range(PROBE_MAX_FILES)]
    for rel in probe:
        try:
            cached = store.size(promote_tier, rel)
        except FileNotFoundError:
            info["reason"] = f"missing promoted file {rel}"
            return info
        try:
            src = store.size(tier, rel)
        except FileNotFoundError:
            src = cached            # source retired by GC: existence is enough
        if cached != src:
            info["reason"] = f"size mismatch for {rel} ({cached} != {src})"
            return info
    info["files"] = len(rels)
    info["valid"] = True
    info["reason"] = "warm"
    return info


class CheckpointManager:
    def __init__(self, store: TieredStore,
                 policy: Optional[CheckpointPolicy] = None, *,
                 worker_id: int = 0, num_workers: int = 1,
                 peer_roots: Optional[dict] = None,
                 node: Optional[str] = None, registry=None, **legacy):
        """``CheckpointManager(store, CheckpointPolicy(...), worker_id=...)``.

        The second argument carries POLICY (how checkpoints are written,
        kept, promoted, restored — see ``checkpoint/policy.py``); the
        keyword arguments carry IDENTITY (who this manager is inside the
        cluster: worker/world ids, peer hints, registry handle).  The old
        flat policy kwargs (``tier=``, ``delta=``, ``promote=``, …) still
        work through a deprecation shim that builds the policy for you.
        """
        if legacy:
            if policy is not None:
                raise TypeError(
                    "pass either a CheckpointPolicy or legacy policy "
                    f"keywords, not both: {sorted(legacy)}")
            unknown = set(legacy) - set(CheckpointPolicy.field_names())
            if unknown:
                raise TypeError(
                    f"unknown CheckpointManager keyword(s): {sorted(unknown)}")
            warnings.warn(
                "CheckpointManager policy keywords "
                f"({', '.join(sorted(legacy))}) are deprecated; pass a "
                "CheckpointPolicy as the second positional argument instead",
                DeprecationWarning, stacklevel=2)
            policy = CheckpointPolicy(**legacy)
        policy = policy if policy is not None else CheckpointPolicy()
        self.policy = policy
        self.store = store
        self.tier = policy.tier
        self.worker_id = worker_id
        self.num_workers = num_workers
        self.replicas = policy.replicas
        self.mode = policy.mode
        self.incremental = policy.incremental
        # delta mode: saves go through the content-addressed chunk plane —
        # only chunks whose hash changed since the parent step are written,
        # and the manifest records the baseline+delta chain.  rebase_every
        # bounds the chain length (metadata hygiene: content addressing means
        # a "rebaseline" costs no extra payload writes, it only resets the
        # chain the manifest reports).
        self.delta = policy.delta
        self.rebase_every = policy.rebase_every
        self.chunk_bytes = policy.chunk_bytes or SER.DELTA_CHUNK_BYTES
        self.keep_last = policy.keep_last
        self.prefix = policy.prefix
        self.shard_format = policy.shard_format
        # restore_workers: 0 = auto-sized pool, 1 = serial (legacy loop, kept
        # as the benchmark baseline), N = pool of N readers
        self.restore_workers = policy.restore_workers
        # fingerprint=True: delta saves stamp a 32-bit per-chunk fingerprint
        # into the manifest and use the parent step's fingerprints as a
        # dirty-chunk PRE-FILTER — fp-equal chunks skip blake2b entirely.
        # Opt-in because a dirty chunk colliding on 32 bits (p ~ 2^-32 per
        # chunk) would be silently treated as clean; the default path keeps
        # the full-hash guarantee.  hash_workers sizes the parallel chunk
        # hash engine (0 = auto / $REPRO_HASH_WORKERS, 1 = serial).
        self.fingerprint = policy.fingerprint
        # device_fp=True: dirty detection happens ON the accelerator —
        # ``save(step, tree)`` takes the live DEVICE tree, runs the chunk
        # fingerprint kernel over every resident leaf, and device_gets only
        # the chunks whose fingerprint differs from the pre-dump/parent
        # reference; clean chunks reuse the reference entries with zero
        # device->host bytes.  Entries always carry ``fp`` so the
        # comparison survives restarts (the manifest persists the vector).
        # Same 32-bit-collision trade-off as fingerprint=True, accepted by
        # opting in.  ``device_fp_impl`` picks the kernel backend
        # (auto=jnp oracle, pallas, pallas_interpret; env override for
        # tests and TPU rollout).
        self.device_fp = policy.device_fp
        self.device_fp_impl = os.environ.get("REPRO_DEVICE_FP_IMPL", "auto")
        self.hash_workers = policy.hash_workers
        # compress: per-chunk frame level in the dedup store (0 = frameless
        # raw bytes, the PR-8-and-earlier format).  Hashes/CRCs/fingerprints
        # are always over UNCOMPRESSED content, so mixing levels across
        # steps — or reading another manager's frameless chunks — is safe.
        self.compress = policy.compress
        # io_batch: ranges per batched read submission on restore (0 = env
        # knob $REPRO_IO_BATCH / default, 1 = per-range reads)
        self.io_batch = policy.io_batch
        self._hash_engine: Optional[SER.ChunkHashEngine] = None
        # pre-dump (precommit) state: hashed/pre-written snapshot of a step,
        # produced on a background pool, consumed by the next _save_delta
        self._predump: Optional[dict] = None
        self._predump_pending = False
        self._predumper: Optional[WorkPool] = None
        self.promote = policy.promote
        self.promote_tier = policy.promote_tier
        # peer fabric: scheduler-provided warm-peer hint ({name: local_root})
        # plus an optional CacheRegistry for decentralized discovery; ``node``
        # is this manager's own cluster-node identity (what it publishes
        # registry entries under, and what it excludes from peer lookups)
        self.peer_roots = {str(k): Path(v)
                           for k, v in (peer_roots or {}).items()}
        self.node = node
        self.registry = registry
        self._writer = AsyncWriter() if self.mode == "async" else None
        # write-behind promotion: one copier, small bound — a restore returns
        # as soon as state is materialized; the tee into the node-local tier
        # trails it (and at most two promotions can be pending)
        self._promoter = (WorkPool(max_inflight=2, workers=1,
                                   name="ckpt-promote")
                          if self.promote != "off" else None)
        self.promote_failures: list[str] = []
        self.promote_skipped = 0           # promotions dropped, pool was busy
        self.promote_cancelled = 0         # promotions aborted by GC mid-copy
        # in-flight promotion bookkeeping: gc() flags a step it is about to
        # delete so the write-behind copier aborts instead of publishing a
        # marker over half-copied, source-retired files.  Counted from
        # SCHEDULE time (not execution) so a promotion still queued behind a
        # busy copier is cancellable too, and counted per-step because the
        # same step can be scheduled more than once (eager commit + restore).
        self._promo_lock = threading.Lock()
        self._promo_inflight: dict[int, int] = {}
        self._promo_doomed: set[int] = set()
        self.last_restore_stats: Optional[dict] = None
        self.last_orphan_sweep: Optional[dict] = None
        self._prev_manifest: Optional[dict] = None

    # ------------------------------------------------------------------
    def _my_leaves(self, records):
        return [
            (i, name, arr) for i, (name, arr) in enumerate(records)
            if i % self.num_workers == self.worker_id
        ]

    def save(self, step: int, tree, extra_meta: Optional[dict] = None) -> dict:
        """Snapshot + write this worker's shard.  Returns the worker part dict.

        In async mode the device->host snapshot happens here (the only quiesced
        section); serialization and store writes run on the writer pool.  Each
        leaf's CRC32 is computed exactly once per save, from a zero-copy byte
        view, and serves as both the incremental diff key and the stored shard
        checksum — see the ``diff`` comment below for where it is computed.
        """
        if self.delta and self.device_fp:
            # device-resident dirty detection: NO full snapshot — the
            # fingerprint pass runs on the live tree and only fp-dirty
            # chunk ranges are device_get'd
            return self._save_delta_device(step, tree, extra_meta)
        t0 = time.time()
        records = SER.tree_to_records(tree)            # snapshot (device_get)
        snap_s = time.time() - t0
        if self.delta:
            return self._save_delta(step, records, snap_s, extra_meta)
        mine = self._my_leaves(records)
        sdir = _step_dir(self.prefix, step)
        shard_rel = f"{sdir}/shard_w{self.worker_id:05d}.bin"

        prev_entries = {}
        # The incremental diff needs every leaf's CRC before deciding what to
        # stream, so it pre-computes them (one zero-copy pass) and hands them
        # to the writer via ``crcs=``.  Without a diff, the CRC is instead
        # folded chunk-by-chunk inside the streaming writer, overlapped with
        # the replica disk writes.  Either way: exactly one CRC per leaf
        # (except shard_format=1, whose legacy writer re-CRCs internally —
        # compat path only).  In async v2 mode the writer-pool task fills the
        # folded CRCs into the returned part's entries (atomic per-field);
        # they are final once ``wait_writes()`` returns, which ``commit()``
        # always awaits before reading parts back.
        diff = self.incremental and self._prev_manifest is not None
        if diff:
            prev_entries = {
                e["path"]: e for e in self._prev_manifest["leaves"]
            }

        entries, to_write, crcs = [], [], {}
        pending = {}                        # name -> entry awaiting writer crc
        for idx, name, arr in mine:
            if diff or self.shard_format == 1:
                crc = SER.leaf_checksum(arr)
                prev = prev_entries.get(name)
                if prev is not None and prev["crc32"] == crc and prev.get("file"):
                    entries.append({**prev, "reused": True})
                    continue
                crcs[name] = crc
            else:
                crc = None
            to_write.append((name, arr))
            entry = {
                "path": name, "index": idx, "crc32": crc,
                "dtype": str(arr.dtype), "shape": list(arr.shape),
                "file": shard_rel, "reused": False,
            }
            if crc is None:
                pending[name] = entry
            entries.append(entry)

        part = {
            "worker_id": self.worker_id,
            "num_workers": self.num_workers,
            "step": step,
            "leaves": entries,
            "snapshot_s": snap_s,
            "meta": extra_meta or {},
        }

        def do_write():
            # the wpart references writer-computed CRCs, so in async mode the
            # whole body runs as one pool task; commit()'s wait_writes() is
            # the barrier before the manifest is cut
            if to_write:
                if self.shard_format == 1:     # legacy byte-identical v1 path
                    data = SER.write_shard_bytes(to_write, meta={"step": step})
                    self.store.put(self.tier, shard_rel, data,
                                   replicas=self.replicas)
                else:
                    footer = {}
                    self.store.put_stream(
                        self.tier, shard_rel,
                        lambda fp: footer.update(SER.write_shard_stream(
                            fp, to_write, meta={"step": step},
                            crcs=crcs or None)),
                        replicas=self.replicas)
                    for t in footer["tensors"]:
                        if t["path"] in pending:
                            pending[t["path"]]["crc32"] = t["crc32"]
            self.store.put(
                self.tier, f"{sdir}/wpart_{self.worker_id:05d}.json",
                json.dumps(part).encode(), replicas=self.replicas)

        if self._writer is not None:
            self._writer.submit(do_write)
        else:
            do_write()
        return part

    # -- delta (content-addressed chunk) save --------------------------
    def _parent_manifest(self) -> Optional[dict]:
        """The manifest a delta save/commit diffs against: the LATEST
        COMMITTED step on the store, with ``_prev_manifest`` as a same-step
        cache.  It must track the store, not this manager's last commit or
        restore: a distributed worker never commits (the coordinator does),
        so a baseline pinned at its restore-time manifest would (a) grow the
        per-step delta with total drift instead of per-step change and
        (b) eventually skip chunk writes against a manifest GC has already
        retired — referencing reaped chunks.  The latest committed manifest
        is always in the GC keep set, so its chunks cannot be reaped under
        an in-flight save."""
        try:
            steps = self.steps()
        except OSError:
            return self._prev_manifest
        if not steps:
            return self._prev_manifest
        latest = steps[-1]
        if (self._prev_manifest is not None
                and self._prev_manifest.get("step") == latest):
            return self._prev_manifest
        try:
            self._prev_manifest = self.read_manifest(latest)
        except (FileNotFoundError, ValueError, KeyError, OSError):
            return self._prev_manifest
        return self._prev_manifest

    @property
    def hash_engine(self) -> SER.ChunkHashEngine:
        """Lazily built parallel chunk hash/CRC engine (a WorkPool is only
        spun up on the first delta save that needs it — many short-lived
        managers never do)."""
        if self._hash_engine is None:
            self._hash_engine = SER.ChunkHashEngine(workers=self.hash_workers)
        return self._hash_engine

    # -- pre-dump (overlapped snapshot) ---------------------------------
    def precommit(self, step: int, tree,
                  extra_meta: Optional[dict] = None) -> dict:
        """CRIU-style pre-dump: snapshot now, hash/fingerprint/pre-write in
        the background, so the NEXT ``save()`` only pays for what changed
        since this call.

        The device->host snapshot happens here (the only step-visible part);
        chunking, fingerprinting, content hashing and the pre-write of
        new-vs-parent chunks all run on the writer pool (async mode) or a
        dedicated single-thread pool, overlapped with the following training
        step(s).  ``save()`` consumes the pre-dump: chunks whose live
        fingerprint equals the pre-dump fingerprint reuse the pre-computed
        hash/CRC and the already-written chunk file; only chunks dirtied
        AFTER the pre-dump are hashed and written inside the save stall.

        Pre-written chunks that the eventual save no longer references are
        orphans no manifest will ever name: the manifest-walking part of
        gc() cannot reap them, so the consuming save sweeps them directly
        when it is the only writer (see ``_save_delta``), and the
        coordinator's ``sweep_orphan_chunks`` pass reclaims them in
        multi-worker runs (barriered on the in-flight intent markers this
        pre-dump publishes).  Returns ``{"step", "snapshot_s"}``.
        """
        if not self.delta:
            raise ValueError("precommit requires delta mode")
        if self.device_fp:
            return self._precommit_device(step, tree)
        t0 = time.time()
        records = SER.tree_to_records(tree)        # snapshot (device_get)
        snap_s = time.time() - t0
        snap_bytes = sum(np.asarray(a).nbytes for _, a in records)
        mine = self._my_leaves(records)
        parent = self._parent_manifest()
        parent_hashes = manifest_chunk_hashes(parent) if parent else set()
        parent_leaves = {e["path"]: e["chunks"]
                         for e in (parent or {}).get("leaves", ())
                         if "chunks" in e}

        def do_predump():
            # intent marker FIRST: the coordinator's orphan sweep
            # (sweep_orphan_chunks) treats any fresh marker as "a writer may
            # be mid-flight" and backs off, so chunks this pre-dump is about
            # to write — referenced by no manifest yet — cannot be reaped
            # from under it
            marker_rel = self._inflight_rel("predump", step)
            self.store.put(self.tier, marker_rel,
                           json.dumps({"kind": "predump", "step": step,
                                       "worker": self.worker_id,
                                       "t": time.time()}).encode(),
                           replicas=1)
            # superseding an unconsumed pre-dump must not drop its write
            # set: those chunks are referenced by no manifest, so only the
            # consuming save's sweep can ever reclaim them.  Carrying them
            # forward keeps them in sweep scope (and skips re-writing any
            # this round re-produces).  Safe to read here: pre-dump tasks
            # run serially on one pool and _consume_predump drains it
            # before swapping.
            prev = self._predump
            if prev is not None and prev.get("chunk_bytes") != self.chunk_bytes:
                prev_leaves = {}
            else:
                prev_leaves = (prev or {}).get("leaves") or {}
            t1 = time.perf_counter()
            fps = {name: SER.fingerprint_chunks(
                       SER.as_byte_view(np.asarray(arr)), self.chunk_bytes)
                   for _, name, arr in mine}
            # iterative pre-copy (CRIU): at lead k the PREVIOUS lead's
            # entries (else the parent manifest's) are the reference — an
            # fp-clean chunk reuses its hash/CRC outright, so lead N-1
            # hashes only what dirtied since lead N-2, not the whole tree.
            # Same 32-bit trust the pre-dump consumption path already
            # accepts (fps are stamped on every pre-dump entry).
            known: dict = {}
            for _, name, _arr in mine:
                fp = fps[name]
                if name in prev_leaves:
                    refs = prev_leaves[name]["entries"]
                else:
                    refs = parent_leaves.get(name)
                if not refs:
                    continue
                kmap = {i: e for i, e in enumerate(refs)
                        if i < len(fp) and e.get("fp") is not None
                        and int(fp[i]) == int(e["fp"])}
                if kmap:
                    known[name] = kmap
            hashed, hstats = self.hash_engine.chunk_records(
                [(name, arr) for _, name, arr in mine], self.chunk_bytes,
                known=known, fps=fps)
            hash_s = time.perf_counter() - t1
            t1 = time.perf_counter()
            written: set = set((prev or {}).get("written") or ())
            cbytes: dict = dict((prev or {}).get("cbytes") or {})
            # markers travel with the write set they protect: a superseded
            # pre-dump's marker stays up until the save that consumes (and
            # sweeps) the carried chunks finally lands
            markers = list((prev or {}).get("markers") or ())
            markers.append(marker_rel)
            leaves = {}
            prewritten_n = 0
            for _, name, _arr in mine:
                entries, views, leaf_crc = hashed[name]
                leaves[name] = {"entries": entries, "crc32": leaf_crc}
                for e, v in zip(entries, views):
                    h = e["hash"]
                    if h in parent_hashes or h in written:
                        continue
                    # force=True for the same gc-race reason as the save
                    # path; the save re-checks existence before trusting a
                    # pre-written chunk, so a reap between now and then is
                    # repaired, not served
                    blob = (SER.frame_chunk(v, self.compress)
                            if self.compress else v)
                    self.store.put_chunk(self.tier, self.prefix, h, blob,
                                         replicas=self.replicas, force=True)
                    written.add(h)
                    cbytes[h] = len(blob)
                    prewritten_n += 1
            self._predump = {
                "step": step, "chunk_bytes": self.chunk_bytes,
                "leaves": leaves, "written": written, "markers": markers,
                "cbytes": cbytes,
                "hash_s": hash_s, "write_s": time.perf_counter() - t1,
                "chunks_hashed": hstats["chunks_hashed"],
                "chunks_prewritten": prewritten_n,
                "d2h_bytes": snap_bytes, "d2h_s": snap_s,
                "fp_device_s": 0.0,
                "chunks_clean_device": 0,
            }

        self._predump_pending = True
        pool = self._writer
        if pool is None:
            if self._predumper is None:
                # bound 2: one executing + one queued pre-dump; a third
                # precommit back-pressures rather than pinning snapshots
                self._predumper = WorkPool(max_inflight=2, workers=1,
                                           name="ckpt-predump")
            pool = self._predumper
        pool.submit(do_predump)
        return {"step": step, "snapshot_s": snap_s}

    def _consume_predump(self) -> Optional[dict]:
        """Claim the latest pre-dump for the save in progress (waiting out a
        still-running background phase — training finishing early shrinks
        the overlap win, never corrupts).  Chunk-size changes invalidate."""
        if not self._predump_pending and self._predump is None:
            return None
        if self._predump_pending:
            pool = self._writer if self._writer is not None else self._predumper
            if pool is not None:
                pool.wait()
            self._predump_pending = False
        pre, self._predump = self._predump, None
        if pre is not None and pre.get("chunk_bytes") != self.chunk_bytes:
            # invalidated pre-dump: its chunks become coordinator-sweep fodder
            # the moment the intent markers come down (no save will ever
            # reference or sweep them itself)
            for rel in pre.get("markers") or ():
                self.store.delete_file(self.tier, rel)
            return None
        return pre

    def _precommit_device(self, step: int, tree) -> dict:
        """Device-side pre-dump: the fingerprint pass and the ranged D2H of
        dirty chunk runs happen HERE on the training thread (donation-safe
        — no deferred device reads), so the step-visible cost is already
        proportional to what dirtied; hashing and the pre-write then run on
        the pool as usual.  At lead k the previous lead's entries are the
        fp reference, so iterative pre-dumps each touch only the bytes that
        changed since the one before (CRIU pre-copy)."""
        t0 = time.time()
        # drain (don't consume) any running pre-dump so its entries are
        # readable as this round's reference
        self.wait_predump()
        prev = self._predump
        prev_ok = (prev is not None
                   and prev.get("chunk_bytes") == self.chunk_bytes)
        prev_leaves = (prev.get("leaves") or {}) if prev_ok else {}
        prev_written = (prev.get("written") or set()) if prev_ok else set()
        from repro.utils.tree import flatten_with_names

        named = flatten_with_names(tree)
        mine = [(i, name, leaf) for i, (name, leaf) in enumerate(named)
                if i % self.num_workers == self.worker_id]
        parent = self._parent_manifest()
        parent_hashes = manifest_chunk_hashes(parent) if parent else set()
        parent_leaves = {e["path"]: e for e in (parent or {}).get(
            "leaves", ()) if "chunks" in e}

        def refs_for(name):
            if name in prev_leaves:
                return prev_leaves[name]["entries"]
            pl = parent_leaves.get(name)
            return pl["chunks"] if pl else None

        def trust(h):
            # no existence probe at pre-dump time — the consuming save
            # re-verifies every pre-written hash before trusting it, so a
            # reap between now and then is repaired there
            return h in parent_hashes or h in prev_written

        plans, dstats = self._device_scan(mine, refs_for, trust)
        snap_s = time.time() - t0

        def do_predump():
            # marker-first + carry semantics identical to the host pre-dump
            # above; see the comments there
            marker_rel = self._inflight_rel("predump", step)
            self.store.put(self.tier, marker_rel,
                           json.dumps({"kind": "predump", "step": step,
                                       "worker": self.worker_id,
                                       "t": time.time()}).encode(),
                           replicas=1)
            prev2 = self._predump
            written: set = set((prev2 or {}).get("written") or ())
            cbytes: dict = dict((prev2 or {}).get("cbytes") or {})
            markers = list((prev2 or {}).get("markers") or ())
            markers.append(marker_rel)
            t1 = time.perf_counter()
            hashed, hashed_n = self._plans_to_leaves(plans)
            hash_s = time.perf_counter() - t1
            t1 = time.perf_counter()
            leaves = {}
            prewritten_n = 0
            for _idx, name, _dtype, _shape, _nbytes, _slots in plans:
                entries, views, leaf_crc = hashed[name]
                leaves[name] = {"entries": entries, "crc32": leaf_crc}
                for e, v in zip(entries, views):
                    h = e["hash"]
                    # v is None for fp-clean slots: their bytes never left
                    # the device, and their chunk is already durable (parent
                    # manifest or a previous lead's pre-write)
                    if h in parent_hashes or h in written or v is None:
                        continue
                    blob = (SER.frame_chunk(v, self.compress)
                            if self.compress else v)
                    self.store.put_chunk(self.tier, self.prefix, h, blob,
                                         replicas=self.replicas, force=True)
                    written.add(h)
                    cbytes[h] = len(blob)
                    prewritten_n += 1
            self._predump = {
                "step": step, "chunk_bytes": self.chunk_bytes,
                "leaves": leaves, "written": written, "markers": markers,
                "cbytes": cbytes,
                "hash_s": hash_s, "write_s": time.perf_counter() - t1,
                "chunks_hashed": hashed_n,
                "chunks_prewritten": prewritten_n,
                "d2h_bytes": dstats["d2h_bytes"],
                "d2h_s": dstats["d2h_s"],
                "fp_device_s": dstats["fp_device_s"],
                "chunks_clean_device": dstats["chunks_clean_device"],
            }

        self._predump_pending = True
        pool = self._writer
        if pool is None:
            if self._predumper is None:
                self._predumper = WorkPool(max_inflight=2, workers=1,
                                           name="ckpt-predump")
            pool = self._predumper
        pool.submit(do_predump)
        return {"step": step, "snapshot_s": snap_s,
                "fp_device_s": dstats["fp_device_s"],
                "d2h_bytes": dstats["d2h_bytes"],
                "d2h_s": dstats["d2h_s"]}

    def _save_delta(self, step: int, records, snap_s: float,
                    extra_meta: Optional[dict]) -> dict:
        """Chunk-plane save: every leaf is chunked/hashed/CRC'd concurrently
        (all chunks in flight across the hash engine's pool), then only
        chunks absent from the parent manifest are written to the dedup
        store (``chunks/<hh>/<hash>``) — save cost is proportional to the
        CHANGE RATE, not the model size.  A payload-free v3 index file
        records the leaf -> chunk mapping next to the wpart.

        Two pre-filters can shrink the hash pass itself:

        * a consumed pre-dump (``precommit``): chunks whose live fingerprint
          matches the pre-dump's reuse its hash/CRC AND its already-written
          chunk file — the stall pays only for bytes dirtied after the
          pre-dump;
        * ``fingerprint=True``: same comparison against the fingerprints
          stamped into the PARENT manifest, with no pre-dump needed.

        Per-phase wall times land in ``part["delta"]`` (``fp_s``/``hash_s``/
        ``diff_s``/``write_s`` and the step-visible ``stall_s``) so the
        bench measures, not infers."""
        t_entry = time.perf_counter()
        mine = self._my_leaves(records)
        sdir = _step_dir(self.prefix, step)
        index_rel = f"{sdir}/shard_w{self.worker_id:05d}.chunks"
        parent = self._parent_manifest()
        parent_hashes = manifest_chunk_hashes(parent) if parent else set()
        # carried compressed sizes: a reused chunk's on-disk frame size is
        # whatever the step that WROTE it recorded — levels can change
        # between steps without rewriting anything
        parent_cbytes = {c["hash"]: c["cbytes"]
                         for e in (parent or {}).get("leaves", ())
                         for c in (e.get("chunks") or ())
                         if "cbytes" in c}
        pre = self._consume_predump()
        pre_leaves = (pre or {}).get("leaves") or {}
        pre_written = (pre or {}).get("written") or set()
        pre_cbytes = (pre or {}).get("cbytes") or {}
        pre_markers = (pre or {}).get("markers") or []
        parent_leaves = {}
        if self.fingerprint and parent is not None:
            parent_leaves = {e["path"]: e for e in parent["leaves"]
                             if "chunks" in e}

        # fingerprint pre-filter: per-chunk fp of the LIVE bytes, compared
        # positionally against the pre-dump state first, else the parent
        # manifest.  fp-equal chunks skip blake2b (the engine still checks
        # per-chunk nbytes, so a reshaped leaf can never alias).  The 32-bit
        # fp never NAMES a chunk — blake2b does — it only decides which
        # chunks need renaming.
        t0 = time.perf_counter()
        items = []
        known: dict = {}
        fps_by_name: dict = {}
        for idx, name, arr in mine:
            arr = np.asarray(arr)
            items.append((name, arr))
            ref_entries = None
            if name in pre_leaves:
                ref_entries = pre_leaves[name]["entries"]
            elif name in parent_leaves:
                ref_entries = parent_leaves[name]["chunks"]
            if ref_entries is None and not self.fingerprint:
                continue          # nothing to compare and nothing to stamp
            fp = SER.fingerprint_chunks(SER.as_byte_view(arr),
                                        self.chunk_bytes)
            fps_by_name[name] = fp
            if not ref_entries:
                continue
            kmap = {i: e for i, e in enumerate(ref_entries)
                    if i < len(fp) and e.get("fp") is not None
                    and int(fp[i]) == int(e["fp"])}
            if kmap:
                known[name] = kmap
        fp_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        hashed, hstats = self.hash_engine.chunk_records(
            items, self.chunk_bytes, known=known,
            fps=fps_by_name if (self.fingerprint or fps_by_name) else None)
        hash_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        entries: list[dict] = []
        new_views: dict[str, object] = {}     # hash -> zero-copy byte view
        chunks_total = bytes_total = 0
        for idx, name, arr in mine:
            arr = np.asarray(arr)
            chunks, views, leaf_crc = hashed[name]
            nbytes = sum(c["nbytes"] for c in chunks)
            fresh = 0
            for c, v in zip(chunks, views):
                chunks_total += 1
                bytes_total += c["nbytes"]
                if c["hash"] in parent_cbytes:
                    c["cbytes"] = parent_cbytes[c["hash"]]
                if c["hash"] in parent_hashes:
                    continue
                fresh += 1
                # dedup at diff time: unchanged-since-parent chunks (the
                # parent manifest is always in the GC keep set, so its
                # chunks cannot be reaped under us) and duplicates within
                # this save are never queued for writing
                if c["hash"] not in new_views:
                    new_views[c["hash"]] = v
            entries.append({
                "path": name, "index": idx, "crc32": leaf_crc,
                "dtype": str(arr.dtype), "shape": list(arr.shape),
                "nbytes": nbytes, "chunks": chunks,
                "reused": not fresh,
            })
        diff_s = time.perf_counter() - t0
        part = {
            "worker_id": self.worker_id,
            "num_workers": self.num_workers,
            "step": step,
            "leaves": entries,
            "snapshot_s": snap_s,
            "meta": extra_meta or {},
            "delta": {
                "chunk_bytes": self.chunk_bytes,
                "chunks_total": chunks_total,
                "bytes_total": bytes_total,
                "chunks_new": len(new_views),
                "bytes_new": sum(v.nbytes for v in new_views.values()),
                "parent_step": parent["step"] if parent else None,
                "chunks_hashed": hstats["chunks_hashed"],
                "chunks_fp_clean": hstats["chunks_known"],
                "hash_workers": hstats["hash_workers"],
                "predump_step": pre["step"] if pre else None,
                "fp_s": fp_s, "hash_s": hash_s, "diff_s": diff_s,
                # D2H accounting, host-path baseline: save() snapshotted the
                # ENTIRE tree before this method ran, so the device->host
                # cost is the full payload regardless of churn — exactly
                # the contrast the delta_save_device bench row draws
                "d2h_bytes": sum(
                    np.asarray(a).nbytes for _, a in records),
                "d2h_s": snap_s,
                "fp_device_s": 0.0,
                "chunks_clean_device": 0,
            },
        }
        return self._finish_delta(step, part, entries, new_views,
                                  pre=pre, parent=parent,
                                  snap_s=snap_s, t_entry=t_entry)

    def _finish_delta(self, step: int, part: dict, entries: list,
                      new_views: dict, *, pre: Optional[dict],
                      parent: Optional[dict], snap_s: float,
                      t_entry: float) -> dict:
        """Shared write tail of the host (``_save_delta``) and device
        (``_save_delta_device``) delta paths: intent marker, chunk writes,
        single-worker orphan sweep, v3 index, wpart, marker teardown, and
        the stall stamp.  ``new_views`` maps hash -> byte view; the device
        path may map a hash to ``None`` when the bytes were never fetched
        (clean since the pre-dump, pre-written, existence-verified during
        the save's sync phase) — if such a chunk vanishes before the write
        loop re-checks it, the save fails LOUDLY (no manifest is cut; the
        two-phase commit keeps the previous step restorable) rather than
        committing a dangling reference."""
        sdir = _step_dir(self.prefix, step)
        index_rel = f"{sdir}/shard_w{self.worker_id:05d}.chunks"
        parent_hashes = manifest_chunk_hashes(parent) if parent else set()
        pre_written = (pre or {}).get("written") or set()
        pre_cbytes = (pre or {}).get("cbytes") or {}
        pre_markers = (pre or {}).get("markers") or []

        def do_write():
            # store writes only; the diff above already decided what moves.
            # force=True: a chunk outside the parent manifest is written even
            # if a file with its hash exists — bare existence could be a
            # doomed old step's copy that a concurrent gc is about to reap
            # (the rewrite is idempotent; unchanged-since-parent chunks never
            # reach this loop, so the dedup win is untouched).  Chunks the
            # pre-dump already wrote are skipped after an existence
            # re-check — a pre-dump chunk reaped since is rewritten (same
            # residual TOCTOU family the force=True note documents).
            # intent marker before the first chunk write: fresh markers make
            # the coordinator's sweep_orphan_chunks back off, so chunks of
            # this not-yet-committed step are never mistaken for orphans
            save_marker = self._inflight_rel("save", step)
            self.store.put(self.tier, save_marker,
                           json.dumps({"kind": "save", "step": step,
                                       "worker": self.worker_id,
                                       "t": time.time()}).encode(),
                           replicas=1)
            t1 = time.perf_counter()
            written_b = written_c = predumped = cbytes_b = 0
            cbytes_out: dict[str, int] = {}
            for h, v in new_views.items():
                if h in pre_written and self.store.exists(
                        self.tier, chunk_rel(self.prefix, h)):
                    predumped += 1
                    if h in pre_cbytes:
                        cbytes_out[h] = pre_cbytes[h]
                    continue
                if v is None:
                    # device path, clean-since-pre-dump chunk: the bytes were
                    # never gathered off the device because the pre-written
                    # file existed during the sync phase.  Gone now means a
                    # reap won the race (same TOCTOU family the force=True
                    # note documents) — with no bytes in hand the only safe
                    # move is to abort this save before any manifest names
                    # the hash; the previous committed step stays restorable
                    raise RuntimeError(
                        f"pre-written chunk {h} disappeared before the "
                        f"step-{step} write; aborting save (no manifest cut)")
                # the frame wraps the STORED bytes only: h stays the blake2b
                # of the raw view, so dedup/fingerprints are codec-blind
                blob = (SER.frame_chunk(v, self.compress)
                        if self.compress else v)
                if self.store.put_chunk(self.tier, self.prefix, h, blob,
                                        replicas=self.replicas, force=True):
                    written_c += 1
                    written_b += v.nbytes
                    cbytes_out[h] = len(blob)
                    cbytes_b += len(blob)
            if self.compress and cbytes_out:
                for e in entries:
                    for c in e["chunks"]:
                        if c["hash"] in cbytes_out:
                            c["cbytes"] = cbytes_out[c["hash"]]
            part["delta"]["chunks_written"] = written_c
            part["delta"]["bytes_written"] = written_b
            part["delta"]["cbytes_written"] = cbytes_b
            part["delta"]["chunks_predumped"] = predumped
            if pre_written and self.num_workers == 1:
                # pre-dumped chunks the live state no longer contains are
                # referenced by NO manifest ever — gc() walks manifests, so
                # they would leak forever.  Single-worker only: with
                # concurrent workers a same-content chunk could legitimately
                # belong to another worker's in-flight save; those orphans
                # are reclaimed by the coordinator-side sweep_orphan_chunks
                # pass instead (gc() runs it, barriered on the in-flight
                # intent markers).  The spare set mirrors gc()'s contract — a
                # chunk stays while ANY kept manifest references it: content
                # can recur from an older retained step whose hash the
                # parent manifest does not carry, and a pre-write of that
                # hash lands on the very file the old step still resolves
                # through.
                final = {c["hash"] for e in entries for c in e["chunks"]}
                cands = pre_written - final - parent_hashes
                keep_hashes: Optional[set] = set()
                parent_step = parent["step"] if parent else None
                if cands:          # fully-consumed pre-dump: no reads at all
                    try:
                        all_steps = self.steps()
                        kept = (all_steps[-self.keep_last:] if self.keep_last
                                else all_steps)
                        for s in kept:
                            if s != parent_step:
                                keep_hashes |= manifest_chunk_hashes(
                                    self.read_manifest(s))
                    except (FileNotFoundError, ValueError, KeyError, OSError):
                        # can't prove a chunk unreferenced: leak it (bounded,
                        # reclaimed by a later sweep) rather than tear a
                        # restorable step
                        keep_hashes = None
                if keep_hashes is not None:
                    for h in sorted(cands - keep_hashes):
                        self.store.delete_file(self.tier,
                                               chunk_rel(self.prefix, h))
            # the v3 index file is the format's on-disk artifact for tooling
            # and disaster recovery (a manifest can be rebuilt from index
            # files alone); the restore path reads the manifest, so one
            # replica of this few-KB file is plenty
            self.store.put(
                self.tier, index_rel,
                SER.write_chunk_index_bytes(entries, meta={"step": step},
                                            chunk_bytes=self.chunk_bytes),
                replicas=1)
            # write_s is final BEFORE the wpart is serialized, so the phase
            # timing actually reaches disk (the wpart put it excludes is a
            # few KB of JSON)
            part["delta"]["write_s"] = time.perf_counter() - t1
            self.store.put(
                self.tier, f"{sdir}/wpart_{self.worker_id:05d}.json",
                json.dumps(part).encode(), replicas=self.replicas)
            # markers come down only AFTER the wpart is durable: from here on
            # the sweep sees this save's chunks through the wpart's refs, so
            # the handoff leaves no window where they are unprotected.  The
            # consumed pre-dump's markers come down with it — surviving
            # pre-written orphans are now sweepable by design (single-worker
            # managers swept them above; multi-worker ones leave them to the
            # coordinator's gc pass).
            for rel in pre_markers:
                self.store.delete_file(self.tier, rel)
            self.store.delete_file(self.tier, save_marker)

        # the step-visible pause attributable to this save call: snapshot +
        # everything that ran synchronously here (in async mode the writes
        # are off-thread, so stall covers fp/hash/diff only).  In async mode
        # stall_s must be set BEFORE the handoff — the writer thread
        # serializes ``part`` into the wpart, and a training-thread dict
        # insert during that json.dumps can tear the write; post-submit cost
        # on this thread is a queue append, so nothing visible is lost.
        if self._writer is not None:
            part["delta"]["stall_s"] = snap_s + (time.perf_counter() - t_entry)
            self._writer.submit(do_write)
        else:
            do_write()
            part["delta"]["stall_s"] = snap_s + (time.perf_counter() - t_entry)
        return part

    # -- device-resident dirty detection (delta + device_fp) ------------
    def _device_scan(self, mine, refs_for, trust):
        """Fingerprint every owned leaf ON DEVICE and gather only fp-dirty
        chunk ranges host-side.

        ``mine``: [(index, name, leaf)] with leaves still device-resident
        (numpy trees ride the same path through ``leaf_words``'s host fast
        path).  ``refs_for(name)`` returns the reference entry list (the
        previous pre-dump's first, else the parent manifest's) or None.
        ``trust(hash)`` says whether an fp-clean chunk may be reused
        WITHOUT bytes in hand — callers answer with the parent-manifest
        keep-set plus whatever existence guarantee fits their phase; a
        distrusted clean chunk is simply reclassified dirty and refetched.

        Every device read happens HERE, synchronously on the calling
        (training) thread — donation-safety: nothing defers a read of a
        buffer the next jitted step might invalidate.  Dirty slots are
        coalesced into runs and each run is one ranged ``device_get`` of
        the covering ELEMENT span (chunk boundaries need not align with
        the leaf's itemsize — the byte view into the fetched span is
        re-offset).

        Returns ``(plans, stats)``: per-leaf
        ``(index, name, dtype, shape, nbytes, slots)`` with slots
        ``(nbytes, fp, ref_entry_or_None, view_or_None)`` — exactly one of
        entry/view is set — and the D2H accounting stats.
        """
        from repro.kernels import ops as KOPS

        t0 = time.perf_counter()
        fps = KOPS.tree_chunk_fingerprints(
            [(name, leaf) for _, name, leaf in mine], self.chunk_bytes,
            impl=self.device_fp_impl)
        fp_device_s = time.perf_counter() - t0

        cb = self.chunk_bytes
        d2h_bytes, d2h_s, clean = 0, 0.0, 0
        plans = []
        for idx, name, leaf in mine:
            shape = list(leaf.shape)
            itemsize = leaf.dtype.itemsize
            nelems = int(np.prod(shape, dtype=np.int64)) if shape else 1
            nbytes = nelems * itemsize
            nchunks = -(-nbytes // cb) if nbytes else 0
            fp = fps.get(name)
            refs = refs_for(name)
            slots: list = [None] * nchunks
            dirty = []
            for i in range(nchunks):
                sn = min(cb, nbytes - i * cb)
                fpi = int(fp[i])
                e = refs[i] if refs and i < len(refs) else None
                if (e is not None and e.get("fp") is not None
                        and int(e["fp"]) == fpi and e.get("nbytes") == sn
                        and trust(e["hash"])):
                    slots[i] = (sn, fpi, e, None)
                    clean += 1
                else:
                    slots[i] = (sn, fpi, None, None)
                    dirty.append(i)
            if dirty:
                flat = leaf.reshape(-1)
                runs, a, b = [], dirty[0], dirty[0]
                for s in dirty[1:]:
                    if s == b + 1:
                        b = s
                    else:
                        runs.append((a, b))
                        a = b = s
                runs.append((a, b))
                for a, b in runs:
                    b0 = a * cb
                    b1 = min((b + 1) * cb, nbytes)
                    e0 = b0 // itemsize
                    e1 = -(-b1 // itemsize)
                    t1 = time.perf_counter()
                    seg = np.ascontiguousarray(np.asarray(flat[e0:e1]))
                    d2h_s += time.perf_counter() - t1
                    d2h_bytes += seg.nbytes
                    segb = memoryview(seg.view(np.uint8).reshape(-1))
                    off = b0 - e0 * itemsize
                    for s in range(a, b + 1):
                        sn, fpi, _, _ = slots[s]
                        sb = off + (s - a) * cb
                        slots[s] = (sn, fpi, None, segb[sb:sb + sn])
            plans.append((idx, name, str(leaf.dtype), shape, nbytes, slots))
        stats = {"fp_device_s": fp_device_s, "d2h_s": d2h_s,
                 "d2h_bytes": d2h_bytes, "chunks_clean_device": clean}
        return plans, stats

    def _plans_to_leaves(self, plans):
        """Scan plans -> ``{name: (entries, views, leaf_crc)}``: dirty slots
        are digested on the hash engine pool (all leaves in flight at
        once), clean slots copy the reference entry into a FRESH dict (a
        cached parent manifest is never mutated).  Every entry carries
        ``fp`` — the device path persists the fingerprint vector
        unconditionally so the next restartable comparison never needs the
        bytes.  Returns ``(leaves, chunks_hashed)``."""
        todo: list = []                      # (entries, slot index, view)
        shaped: dict = {}
        for _idx, name, _dtype, _shape, _nbytes, slots in plans:
            entries: list = [None] * len(slots)
            views: list = [None] * len(slots)
            for i, (sn, fpi, e, v) in enumerate(slots):
                if e is not None:
                    entries[i] = {"hash": e["hash"], "nbytes": sn,
                                  "crc32": e["crc32"], "fp": fpi}
                else:
                    entries[i] = {"nbytes": sn, "fp": fpi}
                    views[i] = v
                    todo.append((entries, i, v))
            shaped[name] = (entries, views)
        digests = self.hash_engine.digest_views([v for _, _, v in todo])
        for (entries, i, _v), (h, crc) in zip(todo, digests):
            e = entries[i]
            entries[i] = {"hash": h, "nbytes": e["nbytes"], "crc32": crc,
                          "fp": e["fp"]}
        leaves = {}
        for name, (entries, views) in shaped.items():
            leaf_crc = 0
            for e in entries:
                leaf_crc = SER.crc32_combine(leaf_crc, e["crc32"],
                                             e["nbytes"])
            leaves[name] = (entries, views, leaf_crc)
        return leaves, len(todo)

    def _save_delta_device(self, step: int, tree,
                           extra_meta: Optional[dict]) -> dict:
        """Delta save with dirty detection on the accelerator: the Pallas/
        jnp fingerprint pass runs over the LIVE device-resident leaves, and
        only fp-dirty chunk runs cross the device->host link — at low churn
        the D2H bill drops from the full model to ~the changed bytes
        (``d2h_bytes`` in ``part["delta"]`` measures it).  Clean chunks
        reuse the pre-dump/parent entries verbatim; pre-written-but-
        uncommitted hashes are existence-verified synchronously here and
        refetched from the device if a reap won the race."""
        t_entry = time.perf_counter()
        from repro.utils.tree import flatten_with_names

        named = flatten_with_names(tree)
        mine = [(i, name, leaf) for i, (name, leaf) in enumerate(named)
                if i % self.num_workers == self.worker_id]
        parent = self._parent_manifest()
        parent_hashes = manifest_chunk_hashes(parent) if parent else set()
        parent_cbytes = {c["hash"]: c["cbytes"]
                         for e in (parent or {}).get("leaves", ())
                         for c in (e.get("chunks") or ())
                         if "cbytes" in c}
        pre = self._consume_predump()
        pre_leaves = (pre or {}).get("leaves") or {}
        pre_written = (pre or {}).get("written") or set()
        parent_leaves = {e["path"]: e for e in (parent or {}).get(
            "leaves", ()) if "chunks" in e}

        def refs_for(name):
            if name in pre_leaves:
                return pre_leaves[name]["entries"]
            pl = parent_leaves.get(name)
            return pl["chunks"] if pl else None

        def trust(h):
            if h in parent_hashes:
                return True     # GC keep set: cannot be reaped under us
            return h in pre_written and self.store.exists(
                self.tier, chunk_rel(self.prefix, h))

        plans, dstats = self._device_scan(mine, refs_for, trust)
        t0 = time.perf_counter()
        leaves, hashed_n = self._plans_to_leaves(plans)
        hash_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        entries: list[dict] = []
        new_views: dict[str, object] = {}
        new_sizes: dict[str, int] = {}
        chunks_total = bytes_total = 0
        for idx, name, dtype, shape, nbytes, _slots in plans:
            chunks, views, leaf_crc = leaves[name]
            fresh = 0
            for c, v in zip(chunks, views):
                chunks_total += 1
                bytes_total += c["nbytes"]
                if c["hash"] in parent_cbytes:
                    c["cbytes"] = parent_cbytes[c["hash"]]
                if c["hash"] in parent_hashes:
                    continue
                fresh += 1
                # keep a real view if ANY duplicate slot fetched one — the
                # write loop can then repair a reaped pre-write instead of
                # aborting on the None placeholder
                if (c["hash"] not in new_views
                        or (new_views[c["hash"]] is None and v is not None)):
                    new_views[c["hash"]] = v
                    new_sizes[c["hash"]] = c["nbytes"]
            entries.append({
                "path": name, "index": idx, "crc32": leaf_crc,
                "dtype": dtype, "shape": shape,
                "nbytes": nbytes, "chunks": chunks,
                "reused": not fresh,
            })
        diff_s = time.perf_counter() - t0
        part = {
            "worker_id": self.worker_id,
            "num_workers": self.num_workers,
            "step": step,
            "leaves": entries,
            "snapshot_s": 0.0,              # no full snapshot on this path
            "meta": extra_meta or {},
            "delta": {
                "chunk_bytes": self.chunk_bytes,
                "chunks_total": chunks_total,
                "bytes_total": bytes_total,
                "chunks_new": len(new_views),
                "bytes_new": sum(new_sizes.values()),
                "parent_step": parent["step"] if parent else None,
                "chunks_hashed": hashed_n,
                "chunks_fp_clean": dstats["chunks_clean_device"],
                "hash_workers": self.hash_engine.workers,
                "predump_step": pre["step"] if pre else None,
                "fp_s": dstats["fp_device_s"],
                "hash_s": hash_s, "diff_s": diff_s,
                "d2h_bytes": dstats["d2h_bytes"],
                "d2h_s": dstats["d2h_s"],
                "fp_device_s": dstats["fp_device_s"],
                "chunks_clean_device": dstats["chunks_clean_device"],
            },
        }
        return self._finish_delta(step, part, entries, new_views,
                                  pre=pre, parent=parent,
                                  snap_s=0.0, t_entry=t_entry)

    def wait_writes(self, timeout: Optional[float] = None) -> None:
        if self._writer is not None:
            self._writer.wait(timeout)

    def wait_predump(self, timeout: Optional[float] = None) -> Optional[dict]:
        """Drain a pending background pre-dump without consuming it (tests/
        shutdown; ``save()`` itself waits via ``_consume_predump``).

        Returns the drained pre-dump's accounting stats (``step``,
        ``hash_s``/``write_s``, ``chunks_hashed``/``chunks_prewritten`` and
        the D2H plane: ``d2h_bytes``/``d2h_s``/``fp_device_s``/
        ``chunks_clean_device``) or None if no pre-dump is buffered — the
        iterative-pre-copy bench reads these to show each lead hashing only
        what dirtied since the lead before."""
        pool = self._writer if self._writer is not None else self._predumper
        if self._predump_pending and pool is not None:
            pool.wait(timeout)
        pre = self._predump
        if pre is None:
            return None
        return {k: pre[k] for k in (
            "step", "hash_s", "write_s", "chunks_hashed",
            "chunks_prewritten", "d2h_bytes", "d2h_s", "fp_device_s",
            "chunks_clean_device") if k in pre}

    # ------------------------------------------------------------------
    def commit(self, step: int, *, num_workers: Optional[int] = None,
               extra_meta: Optional[dict] = None) -> dict:
        """Coordinator-side: verify all worker parts exist, write MANIFEST last."""
        self.wait_writes()
        nw = num_workers or self.num_workers
        sdir = _step_dir(self.prefix, step)
        leaves = []
        meta: dict = {}
        for w in range(nw):
            raw = self.store.get(self.tier, f"{sdir}/wpart_{w:05d}.json")
            part = json.loads(raw.decode())
            leaves.extend(part["leaves"])
            meta.update(part.get("meta") or {})   # worker metas merge (w0 first)
        leaves.sort(key=lambda e: e["index"])
        meta.update(extra_meta or {})
        manifest = {
            "step": step,
            "num_workers": nw,
            "leaves": leaves,
            "committed_at": time.time(),
            "meta": meta,
        }
        if any("chunks" in e for e in leaves):
            # manifest v2: record the baseline+delta chain.  The manifest is
            # SELF-CONTAINED (it lists every chunk each leaf needs, not just
            # the new ones), so the chain is provenance/observability — GC
            # and restore never have to walk ancestors.  rebase_every bounds
            # the reported chain; content addressing makes the rebaseline
            # free (unchanged chunks are never re-written).
            manifest["manifest_version"] = 2
            parent = self._parent_manifest()
            chain, baseline, parent_step = [step], step, None
            if parent is not None and is_chunked_manifest(parent):
                pdelta = parent.get("delta") or {}
                pchain = pdelta.get("chain") or [parent["step"]]
                if len(pchain) < self.rebase_every:
                    parent_step = parent["step"]
                    chain = pchain + [step]
                    baseline = pdelta.get("baseline", parent["step"])
            manifest["delta"] = {
                "baseline": baseline, "parent": parent_step, "chain": chain,
                "chunk_bytes": self.chunk_bytes,
            }
        self.store.put(self.tier, f"{sdir}/MANIFEST.json",
                       json.dumps(manifest).encode(), replicas=self.replicas)
        self._prev_manifest = manifest
        self.gc()
        if self.promote == "eager":
            # keep the node-local cache tracking the newest commit so a
            # restart on this node never touches the shared tier
            self._schedule_promotion(manifest)
        return manifest

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        return committed_steps(self.store, self.tier, self.prefix)

    def cache_inventory(self) -> dict:
        """Validate this manager's promoted cache against its primary tier —
        see ``validate_promoted_cache``.  Usable whatever the promote policy
        (``off`` just probes whatever a previous run left behind)."""
        return validate_promoted_cache(
            self.store, tier=self.tier, promote_tier=self.promote_tier,
            prefix=self.prefix)

    def read_manifest(self, step: int) -> dict:
        raw = self.store.get(self.tier, f"{_step_dir(self.prefix, step)}/MANIFEST.json")
        return json.loads(raw.decode())

    @staticmethod
    def _by_file(manifest: dict) -> dict[str, list[dict]]:
        by_file: dict[str, list[dict]] = {}
        for e in manifest["leaves"]:
            if e.get("file"):           # chunked leaves resolve via the
                by_file.setdefault(e["file"], []).append(e)   # chunk plane
        return by_file

    def _engine(self) -> ParallelRestorer:
        """One restore engine per restore call, carrying this policy's
        worker count and batched-submission width."""
        return ParallelRestorer(self.store, workers=self.restore_workers,
                                io_batch=self.io_batch)

    def _restore_chunked(self, sources: list[str], manifest: dict,
                         tee=None):
        """Chunk-plane restore against an ordered source list (stale local
        cache first, then peers, then the primary tier): every chunk resolves
        independently down the list, so a warm-but-stale node reads its
        unchanged chunks locally and fetches only the missing delta.
        ``tee`` (see ``ParallelRestorer.restore_chunked``) observes each
        verified chunk — the follower-cache write-behind hangs off it."""
        leaves = manifest["leaves"]
        chunked = [e for e in leaves if "chunks" in e]
        engine = self._engine()
        named, st = engine.restore_chunked(sources, chunked,
                                           prefix=self.prefix, tee=tee)
        stats = {"mode": "chunked", "tier": sources[-1], "delta": True,
                 **st.as_dict()}
        by_file = self._by_file(manifest)
        if by_file:     # mixed manifest (mode switched mid-run): file leaves
            named2, st2 = (engine.restore_multi(sources, by_file)
                           if len(sources) > 1
                           else engine.restore(sources[0], by_file))
            named.update(named2)
            stats["bytes_read"] += st2.bytes_read
            stats["tasks"] += st2.tasks
            stats["files"] += st2.files
            stats["replica_fallbacks"] += st2.replica_fallbacks
            for t, n in st2.bytes_by_tier.items():
                stats["bytes_by_tier"][t] = (
                    stats["bytes_by_tier"].get(t, 0) + n)
        return named, stats

    def _restore_files(self, tier: str, manifest: dict):
        """Fetch every manifest-referenced leaf from ``tier``.  Returns
        ({leaf_path: array}, stats).  ``restore_workers=1`` keeps the serial
        per-shard loop (the pre-engine path, and the benchmark baseline);
        anything else fans out through the ParallelRestorer.  Chunked (v3)
        manifests route through the chunk plane whatever the worker count."""
        if is_chunked_manifest(manifest):
            return self._restore_chunked([tier], manifest)
        by_file = self._by_file(manifest)
        if self.restore_workers == 1:
            named: dict[str, np.ndarray] = {}
            for rel, ents in by_file.items():
                tensors, _ = self.store.read_shard_leaves(
                    tier, rel, [e["path"] for e in ents],
                    expect_crcs={e["path"]: e["crc32"] for e in ents})
                for e in ents:
                    named[e["path"]] = tensors[e["path"]]
            return named, {"mode": "serial", "tier": tier,
                           "files": len(by_file), "workers": 1}
        engine = self._engine()
        named, st = engine.restore(tier, by_file)
        return named, {"mode": "parallel", "tier": tier, **st.as_dict()}

    def restore(self, template, step: Optional[int] = None, *,
                sources="auto", promote: Optional[bool] = None,
                follower_cache: bool = False):
        """Unified restore entry.  Returns (host_tree, manifest).

        Dispatches on the MANIFEST (v1/v2 shard files vs v3 chunk plane),
        not on which method the caller picked — the old ``restore_chunked``
        and ``restore_from_peers`` names survive only as deprecated aliases
        of this.  ``last_restore_stats`` is always populated with one schema
        (see ``_finalize_stats``) whatever path served the bytes.

        ``sources`` — ``"auto"`` (default) plans the full cascade: promoted
        cache hit -> peer fabric -> own-stale-cache + primary tier.  An
        explicit tier name or ordered list of tier names (e.g.
        ``["local", "shared"]``) restores from exactly those, skipping
        discovery — the serving-fleet follower uses this to pin its fetch
        plan.

        ``promote`` — ``None`` follows the manager's promote policy;
        ``False`` forces a READ-ONLY restore: no promotion is scheduled and
        a damaged promoted cache is missed, never invalidated (no marker
        deletion).  Serving-fleet followers restore read-only mid-swap so a
        concurrent decode replica never sees its cache torn down under it.

        Leaf-granular: for each shard file the manifest references, only the
        byte ranges of the referenced leaves are fetched, coalesced into
        contiguous runs and (by default) issued in parallel, largest-first,
        across a read pool bounded by each tier's concurrency spec — see
        restore_engine.py.  Per-leaf CRCs are pinned to the manifest values
        and payload bytes are verified against them; replica fallback is
        per-range.  Reads both shard formats (v1 seed files and v2).

        With ``promote != "off"`` a restore served from the primary tier is
        teed write-behind into ``promote_tier`` so the NEXT restart on this
        node reads node-local bytes only (the paper's container-image-cache
        effect); a restore whose step is already promoted is served entirely
        from the promoted copy.

        Peer fabric: when this node is cold but warm peers are known (a
        scheduler hint in ``peer_roots`` and/or a ``CacheRegistry``), the
        restore is planned multi-source — local cache, warm peers round-robin,
        then shared — and the promotion tee copies from the peer too, so one
        cold restart warms this node without touching the shared tier at all.

        ``follower_cache=True`` (serving-fleet followers) parks every chunk
        this restore fetched remotely into ``promote_tier`` as content-
        addressed files — NO promotion marker is written, so the read-only
        contract of ``promote=False`` holds — and, when a registry + node
        name are configured, advertises the synced step as a follower-cache
        entry (``CacheRegistry.publish_follower``).  Replica N+1 of the
        fleet then pulls the delta from replica N instead of the shared
        tier.  Only chunked (v3) manifests advertise; tee failures (disk
        full on the local tier, ...) suppress the advertisement but never
        fail the restore.
        """
        all_steps = self.steps()
        if not all_steps:
            raise FileNotFoundError("no committed checkpoint found")
        step = all_steps[-1] if step is None else step
        mutate = promote is not False
        named = manifest = stats = None
        follower = tee = None
        if follower_cache:
            follower = {"teed": 0, "failures": 0}
            tee = self._follower_tee(follower)
        if isinstance(sources, str) and sources != "auto":
            sources = [sources]
        if sources == "auto":
            if self._promoter is not None or not mutate:
                got = self._restore_promoted(step, mutate=mutate)
                if got is not None:
                    named, manifest, stats = got
            if named is None and (self.peer_roots
                                  or self.registry is not None):
                got = self._restore_from_peers(step, mutate=mutate, tee=tee)
                if got is not None:
                    named, manifest, stats = got
            if named is None:
                manifest = self.read_manifest(step)
                if (is_chunked_manifest(manifest)
                        and self.promote_tier != self.tier):
                    # the node's own — possibly STALE — promoted cache joins
                    # the source list: content-addressed chunks stay valid
                    # whatever step the cache marker names, so a requeued
                    # warm-but-stale node reads unchanged chunks locally and
                    # pays the primary tier only for the delta
                    named, stats = self._restore_chunked(
                        [self.promote_tier, self.tier], manifest, tee=tee)
                else:
                    named, stats = self._restore_files(self.tier, manifest)
                if mutate:
                    self._schedule_promotion(manifest)
        else:
            # pinned source plan: the manifest still comes from the primary
            # tier (the commit marker lives there), payload bytes from
            # exactly the tiers the caller listed, in order
            sources = list(sources)
            if not sources:
                raise ValueError("sources must be 'auto' or a non-empty "
                                 "tier list")
            manifest = self.read_manifest(step)
            if is_chunked_manifest(manifest):
                named, stats = self._restore_chunked(sources, manifest,
                                                     tee=tee)
            elif len(sources) == 1:
                named, stats = self._restore_files(sources[0], manifest)
            else:
                engine = self._engine()
                named, st = engine.restore_multi(sources,
                                                 self._by_file(manifest))
                stats = {"mode": "parallel", "tier": sources[-1],
                         **st.as_dict()}
            if mutate:
                self._schedule_promotion(manifest)
        tree = SER.restore_tree(template, named)
        self._prev_manifest = manifest
        self.last_restore_stats = self._finalize_stats(stats, manifest)
        if follower is not None:
            self.last_restore_stats["chunks_teed"] = follower["teed"]
            self.last_restore_stats["follower_advertised"] = (
                self._advertise_follower(manifest, follower))
        return tree, manifest

    # every restore path lands stats in this shape; path-specific keys only
    # ever ADD information (``promoted``/``peer`` stay falsy off-path)
    _STAT_DEFAULTS = {
        "mode": None, "tier": None, "workers": 1, "files": 0, "tasks": 0,
        "bytes_read": 0, "bytes_by_tier": {}, "replica_fallbacks": 0,
        "chunks": 0, "chunk_refs": 0, "sources": None,
        "promoted": None, "peer": False, "peer_tiers": [], "delta": False,
        "chunks_teed": 0, "follower_advertised": False,
    }

    def _finalize_stats(self, stats: dict, manifest: dict) -> dict:
        """Normalize ``last_restore_stats`` to one schema whatever path
        served the restore (serial shard loop, parallel engine, chunk
        plane, promoted cache, peers): every key in ``_STAT_DEFAULTS`` is
        present, plus ``step``/``manifest_version``."""
        out = dict(self._STAT_DEFAULTS)
        out["bytes_by_tier"] = {}
        out["peer_tiers"] = []
        out.update(stats)
        if out["sources"] is None:
            out["sources"] = [out["tier"]]
        out["step"] = manifest.get("step")
        out["manifest_version"] = manifest.get("manifest_version", 1)
        return out

    def restore_chunked(self, template, step: Optional[int] = None):
        """Deprecated alias of the unified ``restore`` (which dispatches on
        manifest version, so a chunked checkpoint routes through the chunk
        plane without the caller picking a method)."""
        warnings.warn(
            "CheckpointManager.restore_chunked is deprecated; the unified "
            "restore dispatches on manifest version",
            DeprecationWarning, stacklevel=2)
        return self.restore(template, step)

    def restore_from_peers(self, template, step: Optional[int] = None):
        """Deprecated alias of the unified ``restore`` (whose auto source
        plan already prefers the peer fabric when peers are known)."""
        warnings.warn(
            "CheckpointManager.restore_from_peers is deprecated; the unified "
            "restore plans peer sources automatically",
            DeprecationWarning, stacklevel=2)
        return self.restore(template, step)

    # -- peer cache fabric ---------------------------------------------
    def _peer_sources(self, step: int) -> tuple[list[str], list[str]]:
        """Registered peer tiers whose promoted cache can serve ``step``,
        bucketed ``(exact, stale)`` in ONE marker sweep (each candidate's
        ``PROMOTED.json`` is a remote read over the latency-carrying peer
        tier — re-reading it per bucket would double the planning cost of
        exactly the warm-restart path this fabric optimizes).

        Candidates come from the scheduler hint (``peer_roots``) merged with
        the registry; each one's marker is re-read from the peer itself
        before it is trusted, so a stale inventory entry — a peer that GC'd
        or superseded its cache — is skipped, never served.  ``exact`` peers
        cache EXACTLY ``step`` (the only ones the full-shard fabric can
        use); ``stale`` peers hold a parseable cache of some other step —
        useless for shard files, but a chunk-plane restore resolves per
        content hash, so a stale peer still serves every chunk the target
        step shares with its cached one.

        FOLLOWER-cache entries (a serving replica that synced ``step`` and
        advertised its chunk inventory — see ``CacheRegistry
        .publish_follower``) fold into the ``stale`` bucket at their
        advertised lag, exact-step followers first: they own no marker to
        re-read (the node's ``PROMOTED.json`` belongs to whatever promoted
        the node last), so the entry's step is taken on trust — chunk-only
        and CRC-pinned, a lying follower costs a per-chunk fallback, never
        wrong bytes.  They never join ``exact``: no marker, no manifest, no
        shard files."""
        cands: dict[str, tuple[Path, str, Optional[int]]] = {}
        for name, root in sorted(self.peer_roots.items()):
            if self.node is not None and name == self.node:
                continue
            cands[name] = (Path(root), self.promote_tier, None)
        if self.registry is not None:
            entries = dict(self.registry.warm_peers(step,
                                                    exclude=(self.node,)))
            for name, e in self.registry.near_peers(
                    step, exclude=(self.node,),
                    max_lag=STALE_PEER_MAX_LAG).items():
                entries.setdefault(name, e)
            for name, e in entries.items():
                trusted_lag = (abs(int(e["step"]) - step)
                               if e.get("kind") == "follower" else None)
                cands.setdefault(
                    name, (Path(e["local_root"]), e.get("tier", "local"),
                           trusted_lag))
        exact: list[str] = []
        stale: list[tuple[int, str]] = []
        for name, (root, via, follower_lag) in cands.items():
            tier = self.store.add_peer(name, root, via_tier=via)
            if follower_lag is not None:
                stale.append((follower_lag, tier))
                continue
            try:
                marker = json.loads(
                    self.store.get(tier, self._marker_rel()).decode())
                if not isinstance(marker, dict):
                    continue
                cached = int(marker.get("step"))
            except (FileNotFoundError, ValueError, TypeError, OSError):
                continue
            if cached == step:
                exact.append(tier)
            elif abs(cached - step) <= STALE_PEER_MAX_LAG:
                # ordered by the MARKER's actual lag (the registry claim may
                # be outdated): the nearer the cached step, the larger the
                # expected chunk overlap, so the better the source
                stale.append((abs(cached - step), tier))
        return exact, [t for _, t in sorted(stale)]

    def _restore_from_peers(self, step: int, *, mutate: bool = True,
                            tee=None):
        """Multi-source restore of ``step`` from peers' promoted caches.
        Returns (named, manifest, stats) or None to fall through.
        ``mutate=False`` suppresses the promotion tee (read-only follower).

        Full-shard (v1/v2) manifests keep the PR-4 fabric: only exact-step
        warm peers can serve, the manifest itself comes from a peer's
        promoted copy, and every range task falls back peer -> peer ->
        shared.  Chunked (v3) manifests widen the source list with STALE
        peers and this node's own stale cache — content-addressed chunks
        are step-agnostic, so a requeued node fetches only the delta chunks
        it is missing, peers first.  Leaf/chunk CRCs from the manifest are
        enforced on every payload byte whatever the source, and the
        promotion tee is pointed at the peers first so the warm-up copy
        avoids the shared tier too."""
        peer_tiers, stale_tiers = self._peer_sources(step)
        man_rel = f"{_step_dir(self.prefix, step)}/MANIFEST.json"
        manifest = None
        for t in peer_tiers:
            try:
                man = json.loads(self.store.get(t, man_rel).decode())
                if man.get("step") != step:
                    raise ValueError("peer manifest step mismatch")
                manifest = man
                break
            except (FileNotFoundError, ValueError, OSError, KeyError):
                continue
        if manifest is None:
            # no exact-step peer could serve the manifest: only the chunk
            # plane can still profit (from stale peers), and the manifest
            # is a tiny primary-tier read next to the payload it unlocks
            if not stale_tiers:
                return None
            try:
                manifest = self.read_manifest(step)
            except (FileNotFoundError, ValueError, KeyError):
                return None
            if not is_chunked_manifest(manifest):
                return None
        if is_chunked_manifest(manifest):
            peers = peer_tiers + [t for t in stale_tiers
                                  if t not in peer_tiers]
            if not peers:
                return None           # plain stale-local + primary path
            sources = [self.promote_tier] + peers + [self.tier]
            try:
                named, stats = self._restore_chunked(sources, manifest,
                                                     tee=tee)
            except (SER.ChecksumError, OSError, ValueError, KeyError):
                return None
            stats.update({"tier": "peer", "peer": True, "peer_tiers": peers})
            if mutate:
                self._schedule_promotion(manifest,
                                         src_tiers=peers + [self.tier])
            return named, manifest, stats
        if not peer_tiers:
            return None
        sources = [self.promote_tier] + peer_tiers + [self.tier]
        engine = self._engine()
        try:
            named, st = engine.restore_multi(sources, self._by_file(manifest))
        except (SER.ChecksumError, OSError, ValueError, KeyError):
            return None          # peers useless end to end: plain shared path
        stats = {"mode": "parallel", "tier": "peer", "peer": True,
                 "peer_tiers": peer_tiers, **st.as_dict()}
        if mutate:
            self._schedule_promotion(manifest,
                                     src_tiers=peer_tiers + [self.tier])
        return named, manifest, stats

    # -- follower cache (serving-fleet replica-to-replica) -------------
    def _follower_tee(self, state: dict):
        """Write-behind for the serving fleet: park every chunk the restore
        fetched from a NON-local source in this node's promote tier as a
        plain content-addressed file (the on-disk FILE bytes — framed when
        the step was written compressed — so the parked copy is
        byte-identical to the source replica).  The promotion MARKER is never
        written — the follower does not own ``PROMOTED.json`` — so the
        ``promote=False`` read-only contract holds; what the tee builds is
        exactly the inventory ``publish_follower`` advertises.  Runs on the
        restore worker threads; per-chunk failures are counted (they
        suppress the advertisement), never raised — the cache is advisory
        and the restore result is already CRC-verified."""
        lock = threading.Lock()

        def tee(rel: str, data: bytes, src_tier: str) -> None:
            if src_tier == self.promote_tier:
                return          # already local: nothing to park
            try:
                if not self.store.exists(self.promote_tier, rel):
                    self.store.put(self.promote_tier, rel, bytes(data),
                                   replicas=1)
                with lock:
                    state["teed"] += 1
            except OSError:
                with lock:
                    state["failures"] += 1

        return tee

    def _advertise_follower(self, manifest: dict, state: dict) -> bool:
        """Publish this node's follower-cache entry for the step just
        restored (chunk plane only — the entry is chunk-only by contract).
        Advisory: any failure leaves the fleet on the shared tier, never
        fails the restore."""
        if (self.registry is None or not self.node
                or not is_chunked_manifest(manifest)
                or state["failures"]):
            return False
        local_root = self.store.tier_roots.get(self.promote_tier,
                                               self.store.root)
        delta = manifest.get("delta") or {}
        try:
            self.registry.publish_follower(
                self.node, step=int(manifest["step"]),
                local_root=local_root, tier=self.promote_tier,
                baseline_step=delta.get("baseline"),
                chunk_count=len(manifest_chunk_hashes(manifest)))
            return True
        except (OSError, ValueError, KeyError):
            return False

    # -- shared -> local tier promotion --------------------------------
    def _marker_rel(self) -> str:
        return f"{self.prefix}/PROMOTED.json"

    def _read_marker(self) -> Optional[dict]:
        try:
            return json.loads(
                self.store.get(self.promote_tier, self._marker_rel()).decode())
        except (FileNotFoundError, ValueError):
            return None

    def invalidate_promoted(self) -> None:
        """Drop the promoted-tier cache (marker first, so a concurrent reader
        never trusts files being deleted under it); the registry entry — the
        cluster-visible claim — comes off with it, so no peer keeps sourcing
        from a cache that is going away."""
        if self.registry is not None and self.node:
            try:
                self.registry.withdraw(self.node)
                self.registry.withdraw_follower(self.node)
            except OSError:
                pass    # advisory inventory: a failed withdraw must never
                        # kill the restore/gc path that is invalidating
        self.store.delete_file(self.promote_tier, self._marker_rel())
        self.store.delete_prefix(self.promote_tier, self.prefix)

    def _promo_register(self, step: int) -> None:
        with self._promo_lock:
            self._promo_inflight[step] = self._promo_inflight.get(step, 0) + 1

    def _promo_unregister(self, step: int) -> None:
        with self._promo_lock:
            n = self._promo_inflight.get(step, 0) - 1
            if n <= 0:
                self._promo_inflight.pop(step, None)
                self._promo_doomed.discard(step)
            else:
                self._promo_inflight[step] = n

    def _schedule_promotion(self, manifest: dict,
                            src_tiers: Optional[list[str]] = None) -> None:
        """Best-effort, never blocking: a busy promotion pool means this
        promotion is dropped (counted), not that the training thread waits
        on a cache copy.  Registered BEFORE submission so gc() can cancel a
        promotion that is still queued behind a busy copier — not only one
        already executing."""
        if self._promoter is None:
            return
        step = manifest["step"]
        self._promo_register(step)

        def task(man=manifest, srcs=src_tiers, s=step):
            try:
                self._promote_now(man, src_tiers=srcs)
            finally:
                self._promo_unregister(s)

        if not self._promoter.try_submit(task):
            self.promote_skipped += 1
            self._promo_unregister(step)

    def _restore_promoted(self, step: int, *, mutate: bool = True):
        """Serve a restore entirely from the promoted tier when its cached
        step matches.  A stale marker (a newer step committed since the
        promotion — manifest-driven invalidation) just misses: the cached
        FILES are deliberately left in place so the follow-up promotion can
        reuse still-referenced incremental base shards and only copy the
        delta; ``_promote_now`` retires whatever the new manifest no longer
        references.  ``mutate=False`` (read-only follower restore) treats a
        damaged cache as a plain miss — it must never delete the marker of
        a cache some OTHER consumer on this node may be serving from."""
        marker = self._read_marker()
        if marker is None or marker.get("step") != step:
            return None
        try:
            raw = self.store.get(
                self.promote_tier, f"{_step_dir(self.prefix, step)}/MANIFEST.json")
            manifest = json.loads(raw.decode())
            if manifest.get("step") != step:
                raise ValueError("promoted manifest step mismatch")
            named, stats = self._restore_files(self.promote_tier, manifest)
            stats["promoted"] = True
            return named, manifest, stats
        except (FileNotFoundError, ValueError, KeyError, OSError,
                SER.ChecksumError):
            # damaged/evicted cache: drop it and fall back to the source tier
            if mutate:
                self.invalidate_promoted()
            return None

    def _promote_cancelled(self, step: int) -> bool:
        with self._promo_lock:
            return step in self._promo_doomed

    def _promote_now(self, manifest: dict,
                     src_tiers: Optional[list[str]] = None) -> None:
        """Write-behind tee of one committed checkpoint into the promote
        tier.  Incremental-friendly: shard files the previous marker already
        promoted are kept in place (an unchanged multi-GB base shard is never
        re-copied per commit); only missing files are OS-copied and
        CRC-verified against the manifest, and files the new manifest no
        longer references are retired.  The marker comes off FIRST and is
        republished LAST (two-phase — a torn promotion is invisible and gets
        cleaned by the next one).  ``src_tiers`` orders where the copy reads
        from (peer tiers first after a peer-served restore; default the
        primary tier) with per-file fallback down the list.  A promotion
        whose step ``gc()`` starts deleting mid-copy is cancelled before any
        marker is published.  Failures are recorded, never raised: promotion
        is an opportunistic cache."""
        step = manifest["step"]
        # a doom flag set while this promotion was QUEUED must survive into
        # execution, so entry only adds a registration — never clears flags
        self._promo_register(step)
        try:
            self._promote_locked(manifest, step,
                                 src_tiers or [self.tier])
        finally:
            self._promo_unregister(step)

    def _promote_locked(self, manifest: dict, step: int,
                        src_tiers: list[str]) -> None:
        marker = self._read_marker()
        cached = marker.get("step") if marker is not None else None
        if cached == step:
            return
        if cached is not None and cached > step and cached in self.steps():
            return      # never clobber a warmer cache with an older step
        try:
            pmap = manifest_payload_map(manifest, self.prefix)
            have = set(marker.get("files") or []) if marker is not None else set()
            self.store.delete_file(self.promote_tier, self._marker_rel())
            if cached is not None:
                self.store.delete_file(
                    self.promote_tier,
                    f"{_step_dir(self.prefix, cached)}/MANIFEST.json")
            for rel in have - set(pmap):
                self.store.delete_file(self.promote_tier, rel)
            copied: list[str] = []       # this run's copies, for cancel undo
            for rel in sorted(pmap):
                if self._promote_cancelled(step):
                    self._abort_cancelled(step, copied)
                    return          # gc is deleting this step: no marker
                if rel in have and self.store.exists(self.promote_tier, rel):
                    continue        # already promoted + CRC-verified (for a
                    # delta step this skips every unchanged chunk the stale
                    # cache already holds — the tee copies only the delta)
                self._copy_promoted(rel, pmap[rel], src_tiers)
                copied.append(rel)
            if self._promote_cancelled(step):
                self._abort_cancelled(step, copied)
                return
            sdir = _step_dir(self.prefix, step)
            self.store.put(self.promote_tier, f"{sdir}/MANIFEST.json",
                           json.dumps(manifest).encode(), replicas=1)
            self.store.put(
                self.promote_tier, self._marker_rel(),
                json.dumps({"step": step, "files": sorted(pmap),
                            "promoted_at": time.time()}).encode(),
                replicas=1)
            if self.registry is not None and self.node:
                try:
                    delta = manifest.get("delta") or {}
                    chunk_count = sum(1 for k in pmap
                                      if pmap[k][0] == "chunk")
                    # the registry is a SUMMARY inventory: peers re-read the
                    # node's marker before trusting it, so the per-chunk
                    # list (which scales with model size) stays in the local
                    # marker; the registry carries only the shard files plus
                    # chunk_count/baseline_step
                    self.registry.publish(
                        self.node, step=step,
                        files=sorted(r for r in pmap
                                     if pmap[r][0] == "shard"),
                        local_root=self.store.tier_roots.get(
                            self.promote_tier, self.store.root),
                        tier=self.promote_tier,
                        baseline_step=delta.get("baseline"),
                        chunk_count=chunk_count or None)
                except OSError as e:
                    # the registry is ADVISORY: an unwritable inventory must
                    # not invalidate the (complete, CRC-verified, marker-
                    # published) local cache it merely advertises
                    self.promote_failures.append(
                        f"registry publish step {step}: {e!r}")
        except Exception as e:  # noqa: BLE001 — cache miss, not a failure
            self.promote_failures.append(f"step {step}: {e!r}")
            self.invalidate_promoted()

    def _abort_cancelled(self, step: int, copied: list[str]) -> None:
        """A cancelled promotion must not leak its partial copies: no marker
        will ever reference them, so nothing else would retire them.  Only
        THIS run's copies go — files inherited from the previous marker stay
        for the follow-up promotion to reuse."""
        self.promote_cancelled += 1
        for rel in copied:
            try:
                self.store.delete_file(self.promote_tier, rel)
            except OSError:
                pass                # best-effort: orphans are data, not harm

    def _copy_promoted(self, rel: str, payload: tuple,
                       src_tiers: list[str]) -> None:
        """Copy + CRC-verify one payload file (a shard or a single chunk)
        into the promote tier from the first source that yields intact bytes
        (a peer dying mid-promotion falls back to the next peer, then the
        primary tier)."""
        kind, info = payload
        last: Optional[Exception] = None
        for src in src_tiers:
            try:
                self.store.copy_file(src, rel, self.promote_tier)
                if kind == "chunk":
                    # unframe_chunk verifies the raw CRC whether the copied
                    # file is a frameless chunk or a compressed frame — the
                    # promoted copy is the FILE, so both must verify
                    data = self.store.get(self.promote_tier, rel)
                    SER.unframe_chunk(data, info["nbytes"],
                                      crc32=info["crc32"])
                else:
                    self.store.read_shard_leaves(
                        self.promote_tier, rel, [e["path"] for e in info],
                        expect_crcs={e["path"]: e["crc32"] for e in info})
                return
            except Exception as e:  # noqa: BLE001 — try the next source
                last = e
        raise last if last is not None else FileNotFoundError(rel)

    def prefetch_latest(self, step: Optional[int] = None) -> Optional[int]:
        """Eager promotion: schedule a write-behind copy of the latest (or
        given) committed step into the promote tier without restoring it —
        call at job start so the restart after the NEXT preemption is served
        node-locally.  Returns the step scheduled, or None."""
        if self._promoter is None:
            return None
        all_steps = self.steps()
        if not all_steps:
            return None
        step = all_steps[-1] if step is None else step
        if (marker := self._read_marker()) is not None and marker.get("step") == step:
            return step                    # already cached: skip the I/O
        manifest = self.read_manifest(step)
        self._schedule_promotion(manifest)
        return step

    def wait_promotions(self, timeout: Optional[float] = None) -> None:
        if self._promoter is not None:
            self._promoter.wait(timeout)

    # -- multi-worker orphan-chunk sweep --------------------------------
    def _inflight_rel(self, kind: str, step: int) -> str:
        return (f"{self.prefix}/inflight/"
                f"{kind}_{step:010d}_w{self.worker_id:05d}.json")

    def _fresh_inflight(self, now: float, stale_s: float) -> list[str]:
        """In-flight intent markers that are still live.  A marker older
        than ``stale_s`` belongs to a writer that died mid-save (a live one
        re-publishes per save/pre-dump); it is retired here so one crashed
        worker cannot block orphan reclamation forever."""
        fresh: list[str] = []
        for rel in sorted(self.store.list_prefix(
                self.tier, f"{self.prefix}/inflight")):
            try:
                t = float(json.loads(
                    self.store.get(self.tier, rel).decode())["t"])
            except (FileNotFoundError, ValueError, TypeError, KeyError,
                    OSError):
                t = None             # torn marker: age it out via mtime
                try:
                    t = self.store.mtime(self.tier, rel)
                except (FileNotFoundError, OSError):
                    continue
            if now - t > stale_s:
                self.store.delete_file(self.tier, rel)
                continue
            fresh.append(rel)
        return fresh

    def _uncommitted_chunk_refs(self, committed: set) -> set:
        """Chunk hashes referenced by wparts of steps with NO manifest yet —
        an in-flight commit's payload, which the sweep must treat exactly
        like kept-manifest refs (the file plane's gc has the same rule:
        never touch an uncommitted step dir)."""
        out: set = set()
        for rel in self.store.list_prefix(self.tier, self.prefix):
            parts = Path(rel).parts
            if (len(parts) < 2 or not parts[-2].startswith("step_")
                    or not parts[-1].startswith("wpart_")):
                continue
            if int(parts[-2].split("_")[1]) in committed:
                continue
            try:
                part = json.loads(self.store.get(self.tier, rel).decode())
            except (FileNotFoundError, ValueError, OSError):
                raise ValueError(f"unreadable in-flight wpart {rel}")
            for e in part.get("leaves") or ():
                out.update(c["hash"] for c in e.get("chunks") or ())
        return out

    def sweep_orphan_chunks(self, *,
                            stale_marker_s: float = 900.0) -> dict:
        """Coordinator-side reclamation of chunk files NO referent explains:
        ``chunk_digests`` minus kept-manifest refs, minus uncommitted-wpart
        refs, minus this manager's own pending pre-dump writes.  What
        remains is multi-worker pre-dump fallout — chunks pre-written for a
        step whose save no longer contains them — which the per-save sweep
        deliberately leaves alone when other writers exist (see
        ``_save_delta``).

        Barriered against in-flight saves three ways: any FRESH intent
        marker (``<prefix>/inflight/``, published by every delta save and
        pre-dump before its first chunk write) defers the whole sweep;
        markers are re-checked after candidate collection so a save that
        started mid-sweep also defers it; and a candidate whose file mtime
        is at/after the sweep's start is skipped — a writer that raced past
        both marker checks re-touched it.  Crashed writers' markers age out
        after ``stale_marker_s``.

        Returns ``{"reaped": [hashes], "skipped": reason|None}`` (also
        stored as ``last_orphan_sweep``)."""
        t0 = time.time()
        info: dict = {"reaped": [], "skipped": None}
        self.last_orphan_sweep = info
        if self._predump_pending:
            # own pre-dump still materializing on the pool: its write set is
            # unknown here, and its marker may not be on disk yet
            info["skipped"] = "own pre-dump pending"
            return info
        if self._fresh_inflight(t0, stale_marker_s):
            info["skipped"] = "in-flight saves"
            return info
        digests = self.store.chunk_digests(self.tier, self.prefix)
        if not digests:
            return info
        try:
            steps = self.steps()
            kept = steps[-self.keep_last:] if self.keep_last else steps
            keep: set = set()
            for s in kept:
                keep |= manifest_chunk_hashes(self.read_manifest(s))
            keep |= self._uncommitted_chunk_refs(set(steps))
        except (FileNotFoundError, ValueError, KeyError, OSError):
            # can't PROVE a chunk unreferenced: leak it (bounded, the next
            # sweep retries) rather than tear a restorable step
            info["skipped"] = "unreadable manifest or wpart"
            return info
        if self._predump is not None:
            keep |= set(self._predump.get("written") or ())
        cands = sorted(digests - keep)
        if not cands:
            return info
        if self._fresh_inflight(time.time(), stale_marker_s):
            info["skipped"] = "in-flight saves"
            return info
        for h in cands:
            rel = chunk_rel(self.prefix, h)
            try:
                if self.store.mtime(self.tier, rel) >= t0:
                    continue          # (re)written since the sweep started
            except (FileNotFoundError, OSError):
                continue
            self.store.delete_file(self.tier, rel)
            info["reaped"].append(h)
        return info

    # ------------------------------------------------------------------
    def gc(self) -> None:
        """Old manifests are always removed (a checkpoint 'exists' iff its
        manifest does); step dirs survive only while an incremental manifest
        in the kept set references their shard files.  Content-addressed
        chunks are reaped by REFCOUNT, not by step: a chunk stays on disk
        while ANY kept manifest references it (delta chains share most of
        their chunks, so per-step deletion would tear live data), and is
        deleted exactly when its count drops to zero."""
        steps = self.steps()
        keep = set(steps[-self.keep_last:]) if self.keep_last else set(steps)
        referenced_dirs = set()
        kept_manifests = []
        for s in keep:
            man = self.read_manifest(s)
            kept_manifests.append(man)
            for e in man["leaves"]:
                if e.get("file"):
                    referenced_dirs.add(str(Path(e["file"]).parent))
        # retired manifests are read BEFORE anything is deleted: their chunk
        # references are the reap candidates below
        retired_manifests = []
        for s in steps:
            if s not in keep:
                try:
                    retired_manifests.append(self.read_manifest(s))
                except (FileNotFoundError, ValueError, KeyError):
                    continue
        doomed = [s for s in steps
                  if s not in keep
                  and _step_dir(self.prefix, s) not in referenced_dirs]
        if doomed and self._promoter is not None:
            # GC/promotion race: the write-behind copier may be mid-copy of a
            # step whose shared shards are about to vanish.  Flag it so the
            # copier aborts before publishing a marker, and drop any marker
            # already naming a doomed step (marker first — a reader must
            # never trust files being deleted under it).
            with self._promo_lock:
                for s in doomed:
                    if s in self._promo_inflight:
                        self._promo_doomed.add(s)
            marker = self._read_marker()
            if marker is not None and marker.get("step") in doomed:
                self.invalidate_promoted()
        for s in steps:
            if s in keep:
                continue
            sdir = _step_dir(self.prefix, s)
            if sdir in referenced_dirs:
                # keep the shard data, retire the manifest + parts.  The
                # retired step may have been written under a DIFFERENT worker
                # count (elastic restart), so the part count comes from the
                # step's own manifest — not this manager's num_workers.
                try:
                    nw = int(self.read_manifest(s).get("num_workers",
                                                       self.num_workers))
                except (FileNotFoundError, ValueError, KeyError):
                    nw = 0
                self.store.delete_file(self.tier, f"{sdir}/MANIFEST.json")
                if nw:
                    for w in range(nw):
                        self.store.delete_file(
                            self.tier, f"{sdir}/wpart_{w:05d}.json")
                else:   # manifest unreadable: sweep whatever parts exist
                    for rel in self.store.list_prefix(self.tier, sdir):
                        if Path(rel).name.startswith("wpart_"):
                            self.store.delete_file(self.tier, rel)
            else:
                self.store.delete_prefix(self.tier, sdir)
        # chunk plane: refcount-aware reaping.  A chunk is reaped when the
        # manifests RETIRED this cycle referenced it and its refcount across
        # the KEPT manifests is zero (each manifest is self-contained, so
        # ancestors of a kept delta step pin nothing beyond what it lists).
        # Deliberately NOT "every on-disk chunk not in a kept manifest": a
        # worker may have already written chunks for a step whose manifest
        # is not committed yet — like the file plane, which never touches
        # uncommitted step dirs, gc must not eat an in-flight save.
        live = set(chunk_refcounts(kept_manifests))
        for h in sorted(set(chunk_refcounts(retired_manifests)) - live):
            self.store.delete_file(self.tier, chunk_rel(self.prefix, h))
        if self.delta and self.num_workers > 1:
            # multi-worker pre-dump fallout is invisible to the manifest
            # walk above (orphans are referenced by no manifest at all);
            # the coordinator — the only caller of gc(), via commit() —
            # reclaims it here, barriered on the in-flight intent markers
            self.sweep_orphan_chunks()

    def close(self) -> None:
        try:
            if self._writer is not None:
                self._writer.close()
        finally:
            try:
                if self._predumper is not None:
                    self._predumper.close()
            finally:
                try:
                    if self._hash_engine is not None:
                        self._hash_engine.close()
                finally:
                    if self._promoter is not None:
                        self._promoter.close()
