"""CheckpointManager: sharded, atomic, optionally async/incremental checkpoints.

Layout under the store (per tier):
  <prefix>/step_<N>/shard_w<world-id>.bin     one shard per worker
  <prefix>/step_<N>/wpart_<id>.json           per-worker manifest part
  <prefix>/step_<N>/MANIFEST.json             atomic commit marker (written LAST,
                                              by the coordinator / single worker)

A checkpoint exists iff MANIFEST.json exists — a preemption mid-write leaves no
manifest and the restart falls back to the previous step (two-phase commit, the
framework analogue of DMTCP's coordinator barrier).

Leaf ownership: leaf i belongs to worker (i % num_workers).  Restore reads every
worker part, so a checkpoint taken with N workers restores under M workers (the
MxN / elastic-restart property; mesh placement is re-derived by
core/virtualization.py).

Incremental mode (beyond-paper): a leaf whose crc32 is unchanged since the
previous *committed* checkpoint is not rewritten — its manifest entry points at
the older shard file.  GC keeps referenced base files alive.

I/O plane (see EXPERIMENTS.md): each leaf is CRC'd exactly once per save (a
zero-copy pass that doubles as the incremental diff), then streamed through
``TieredStore.put_stream`` into a v2 shard — no whole-shard buffer, and the
k-replica fan-out is an OS-level copy of the primary.  Restore is
leaf-granular: only the byte ranges the manifest actually references are read
from each shard, so an incremental/MxN restore no longer re-reads whole base
shards.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint import serialization as SER
from repro.checkpoint.async_writer import AsyncWriter, WorkPool
from repro.checkpoint.restore_engine import ParallelRestorer
from repro.checkpoint.store import TieredStore

PROMOTE_POLICIES = ("off", "on_restore", "eager")


def _step_dir(prefix: str, step: int) -> str:
    return f"{prefix}/step_{step:010d}"


def committed_steps(store: TieredStore, tier: str, prefix: str) -> list[int]:
    """Steps with a MANIFEST.json on ``tier`` (a checkpoint exists iff its
    manifest does).  Module-level so schedulers can enumerate without
    constructing a manager."""
    out = set()
    for r in store.list_prefix(tier, prefix):
        parts = Path(r).parts
        if len(parts) >= 2 and parts[-1] == "MANIFEST.json":
            out.add(int(parts[-2].split("_")[1]))
    return sorted(out)


def validate_promoted_cache(store: TieredStore, *, tier: str = "shared",
                            promote_tier: str = "local",
                            prefix: str = "ckpt",
                            latest: Optional[int] = None) -> dict:
    """Scheduler-facing cache inventory: is ``promote_tier``'s promoted cache
    warm for the LATEST step committed on ``tier``?

    Invalidation-aware and cheap (no payload reads): the marker must parse
    (a torn ``PROMOTED.json`` is cold, not an error), its step must equal the
    latest committed step (a superseded marker is stale), the promoted
    manifest must parse and match, and every referenced shard file must exist
    in the promote tier at the source file's size (catching truncation).
    Deliberately advisory — deep CRC verification stays in the restore path,
    so a probe that wrongly says "warm" costs one cache miss, never stale
    bytes.

    Returns ``{"valid", "step", "latest", "files", "reason"}``.  A caller
    probing MANY nodes against one shared tier can pass ``latest`` (the
    newest committed step) to skip the per-node re-listing of the shared
    prefix — the listing is node-independent.
    """
    info: dict = {"valid": False, "step": None, "latest": None,
                  "files": 0, "reason": ""}
    if latest is None:
        steps = committed_steps(store, tier, prefix)
        latest = steps[-1] if steps else None
    info["latest"] = latest
    marker_rel = f"{prefix}/PROMOTED.json"
    try:
        marker = json.loads(store.get(promote_tier, marker_rel).decode())
        if not isinstance(marker, dict):
            raise ValueError("marker is not an object")
    except FileNotFoundError:
        # get() reports an unreadable-everywhere file as not-found; a marker
        # that exists but cannot be read is torn, not absent
        info["reason"] = ("torn promoted marker"
                         if store.exists(promote_tier, marker_rel)
                         else "no promoted marker")
        return info
    except (ValueError, OSError):
        info["reason"] = "torn promoted marker"
        return info
    info["step"] = step = marker.get("step")
    if info["latest"] is None:
        info["reason"] = "no committed checkpoint on source tier"
        return info
    if step != info["latest"]:
        info["reason"] = f"stale (cached step {step}, latest {info['latest']})"
        return info
    try:
        man = json.loads(store.get(
            promote_tier, f"{_step_dir(prefix, step)}/MANIFEST.json").decode())
        if man.get("step") != step:
            raise ValueError("promoted manifest step mismatch")
        rels = sorted({e["file"] for e in man["leaves"]})
    except (FileNotFoundError, ValueError, OSError, KeyError, TypeError):
        info["reason"] = "damaged promoted manifest"
        return info
    for rel in rels:
        try:
            cached = store.size(promote_tier, rel)
        except FileNotFoundError:
            info["reason"] = f"missing promoted file {rel}"
            return info
        try:
            src = store.size(tier, rel)
        except FileNotFoundError:
            src = cached            # source retired by GC: existence is enough
        if cached != src:
            info["reason"] = f"size mismatch for {rel} ({cached} != {src})"
            return info
    info["files"] = len(rels)
    info["valid"] = True
    info["reason"] = "warm"
    return info


class CheckpointManager:
    def __init__(self, store: TieredStore, *, tier: str = "shared",
                 worker_id: int = 0, num_workers: int = 1, replicas: int = 2,
                 mode: str = "sync", incremental: bool = False,
                 keep_last: int = 3, prefix: str = "ckpt",
                 shard_format: int = 2, restore_workers: int = 0,
                 promote: str = "off", promote_tier: str = "local",
                 peer_roots: Optional[dict] = None,
                 node: Optional[str] = None, registry=None):
        assert mode in ("sync", "async")
        assert shard_format in (1, 2)      # 1 = legacy writer (compat tests)
        assert promote in PROMOTE_POLICIES
        # the promote tier is a CACHE whose invalidation deletes files —
        # pointing it at the primary tier would let a stale-cache cleanup
        # destroy the committed checkpoints themselves
        assert (
            promote == "off" or promote_tier != tier
        ), "promote_tier must differ from the primary checkpoint tier"
        self.store = store
        self.tier = tier
        self.worker_id = worker_id
        self.num_workers = num_workers
        self.replicas = replicas
        self.mode = mode
        self.incremental = incremental
        self.keep_last = keep_last
        self.prefix = prefix
        self.shard_format = shard_format
        # restore_workers: 0 = auto-sized pool, 1 = serial (legacy loop, kept
        # as the benchmark baseline), N = pool of N readers
        self.restore_workers = restore_workers
        self.promote = promote
        self.promote_tier = promote_tier
        # peer fabric: scheduler-provided warm-peer hint ({name: local_root})
        # plus an optional CacheRegistry for decentralized discovery; ``node``
        # is this manager's own cluster-node identity (what it publishes
        # registry entries under, and what it excludes from peer lookups)
        self.peer_roots = {str(k): Path(v)
                           for k, v in (peer_roots or {}).items()}
        self.node = node
        self.registry = registry
        self._writer = AsyncWriter() if mode == "async" else None
        # write-behind promotion: one copier, small bound — a restore returns
        # as soon as state is materialized; the tee into the node-local tier
        # trails it (and at most two promotions can be pending)
        self._promoter = (WorkPool(max_inflight=2, workers=1,
                                   name="ckpt-promote")
                          if promote != "off" else None)
        self.promote_failures: list[str] = []
        self.promote_skipped = 0           # promotions dropped, pool was busy
        self.promote_cancelled = 0         # promotions aborted by GC mid-copy
        # in-flight promotion bookkeeping: gc() flags a step it is about to
        # delete so the write-behind copier aborts instead of publishing a
        # marker over half-copied, source-retired files.  Counted from
        # SCHEDULE time (not execution) so a promotion still queued behind a
        # busy copier is cancellable too, and counted per-step because the
        # same step can be scheduled more than once (eager commit + restore).
        self._promo_lock = threading.Lock()
        self._promo_inflight: dict[int, int] = {}
        self._promo_doomed: set[int] = set()
        self.last_restore_stats: Optional[dict] = None
        self._prev_manifest: Optional[dict] = None

    # ------------------------------------------------------------------
    def _my_leaves(self, records):
        return [
            (i, name, arr) for i, (name, arr) in enumerate(records)
            if i % self.num_workers == self.worker_id
        ]

    def save(self, step: int, tree, extra_meta: Optional[dict] = None) -> dict:
        """Snapshot + write this worker's shard.  Returns the worker part dict.

        In async mode the device->host snapshot happens here (the only quiesced
        section); serialization and store writes run on the writer pool.  Each
        leaf's CRC32 is computed exactly once per save, from a zero-copy byte
        view, and serves as both the incremental diff key and the stored shard
        checksum — see the ``diff`` comment below for where it is computed.
        """
        t0 = time.time()
        records = SER.tree_to_records(tree)            # snapshot (device_get)
        snap_s = time.time() - t0
        mine = self._my_leaves(records)
        sdir = _step_dir(self.prefix, step)
        shard_rel = f"{sdir}/shard_w{self.worker_id:05d}.bin"

        prev_entries = {}
        # The incremental diff needs every leaf's CRC before deciding what to
        # stream, so it pre-computes them (one zero-copy pass) and hands them
        # to the writer via ``crcs=``.  Without a diff, the CRC is instead
        # folded chunk-by-chunk inside the streaming writer, overlapped with
        # the replica disk writes.  Either way: exactly one CRC per leaf
        # (except shard_format=1, whose legacy writer re-CRCs internally —
        # compat path only).  In async v2 mode the writer-pool task fills the
        # folded CRCs into the returned part's entries (atomic per-field);
        # they are final once ``wait_writes()`` returns, which ``commit()``
        # always awaits before reading parts back.
        diff = self.incremental and self._prev_manifest is not None
        if diff:
            prev_entries = {
                e["path"]: e for e in self._prev_manifest["leaves"]
            }

        entries, to_write, crcs = [], [], {}
        pending = {}                        # name -> entry awaiting writer crc
        for idx, name, arr in mine:
            if diff or self.shard_format == 1:
                crc = SER.leaf_checksum(arr)
                prev = prev_entries.get(name)
                if prev is not None and prev["crc32"] == crc and prev.get("file"):
                    entries.append({**prev, "reused": True})
                    continue
                crcs[name] = crc
            else:
                crc = None
            to_write.append((name, arr))
            entry = {
                "path": name, "index": idx, "crc32": crc,
                "dtype": str(arr.dtype), "shape": list(arr.shape),
                "file": shard_rel, "reused": False,
            }
            if crc is None:
                pending[name] = entry
            entries.append(entry)

        part = {
            "worker_id": self.worker_id,
            "num_workers": self.num_workers,
            "step": step,
            "leaves": entries,
            "snapshot_s": snap_s,
            "meta": extra_meta or {},
        }

        def do_write():
            # the wpart references writer-computed CRCs, so in async mode the
            # whole body runs as one pool task; commit()'s wait_writes() is
            # the barrier before the manifest is cut
            if to_write:
                if self.shard_format == 1:     # legacy byte-identical v1 path
                    data = SER.write_shard_bytes(to_write, meta={"step": step})
                    self.store.put(self.tier, shard_rel, data,
                                   replicas=self.replicas)
                else:
                    footer = {}
                    self.store.put_stream(
                        self.tier, shard_rel,
                        lambda fp: footer.update(SER.write_shard_stream(
                            fp, to_write, meta={"step": step},
                            crcs=crcs or None)),
                        replicas=self.replicas)
                    for t in footer["tensors"]:
                        if t["path"] in pending:
                            pending[t["path"]]["crc32"] = t["crc32"]
            self.store.put(
                self.tier, f"{sdir}/wpart_{self.worker_id:05d}.json",
                json.dumps(part).encode(), replicas=self.replicas)

        if self._writer is not None:
            self._writer.submit(do_write)
        else:
            do_write()
        return part

    def wait_writes(self, timeout: Optional[float] = None) -> None:
        if self._writer is not None:
            self._writer.wait(timeout)

    # ------------------------------------------------------------------
    def commit(self, step: int, *, num_workers: Optional[int] = None,
               extra_meta: Optional[dict] = None) -> dict:
        """Coordinator-side: verify all worker parts exist, write MANIFEST last."""
        self.wait_writes()
        nw = num_workers or self.num_workers
        sdir = _step_dir(self.prefix, step)
        leaves = []
        meta: dict = {}
        for w in range(nw):
            raw = self.store.get(self.tier, f"{sdir}/wpart_{w:05d}.json")
            part = json.loads(raw.decode())
            leaves.extend(part["leaves"])
            meta.update(part.get("meta") or {})   # worker metas merge (w0 first)
        leaves.sort(key=lambda e: e["index"])
        meta.update(extra_meta or {})
        manifest = {
            "step": step,
            "num_workers": nw,
            "leaves": leaves,
            "committed_at": time.time(),
            "meta": meta,
        }
        self.store.put(self.tier, f"{sdir}/MANIFEST.json",
                       json.dumps(manifest).encode(), replicas=self.replicas)
        self._prev_manifest = manifest
        self.gc()
        if self.promote == "eager":
            # keep the node-local cache tracking the newest commit so a
            # restart on this node never touches the shared tier
            self._schedule_promotion(manifest)
        return manifest

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        return committed_steps(self.store, self.tier, self.prefix)

    def cache_inventory(self) -> dict:
        """Validate this manager's promoted cache against its primary tier —
        see ``validate_promoted_cache``.  Usable whatever the promote policy
        (``off`` just probes whatever a previous run left behind)."""
        return validate_promoted_cache(
            self.store, tier=self.tier, promote_tier=self.promote_tier,
            prefix=self.prefix)

    def read_manifest(self, step: int) -> dict:
        raw = self.store.get(self.tier, f"{_step_dir(self.prefix, step)}/MANIFEST.json")
        return json.loads(raw.decode())

    @staticmethod
    def _by_file(manifest: dict) -> dict[str, list[dict]]:
        by_file: dict[str, list[dict]] = {}
        for e in manifest["leaves"]:
            by_file.setdefault(e["file"], []).append(e)
        return by_file

    def _restore_files(self, tier: str, manifest: dict):
        """Fetch every manifest-referenced leaf from ``tier``.  Returns
        ({leaf_path: array}, stats).  ``restore_workers=1`` keeps the serial
        per-shard loop (the pre-engine path, and the benchmark baseline);
        anything else fans out through the ParallelRestorer."""
        by_file = self._by_file(manifest)
        if self.restore_workers == 1:
            named: dict[str, np.ndarray] = {}
            for rel, ents in by_file.items():
                tensors, _ = self.store.read_shard_leaves(
                    tier, rel, [e["path"] for e in ents],
                    expect_crcs={e["path"]: e["crc32"] for e in ents})
                for e in ents:
                    named[e["path"]] = tensors[e["path"]]
            return named, {"mode": "serial", "tier": tier,
                           "files": len(by_file), "workers": 1}
        engine = ParallelRestorer(self.store, workers=self.restore_workers)
        named, st = engine.restore(tier, by_file)
        return named, {"mode": "parallel", "tier": tier, **st.as_dict()}

    def restore(self, template, step: Optional[int] = None):
        """Returns (host_tree, manifest).

        Leaf-granular: for each shard file the manifest references, only the
        byte ranges of the referenced leaves are fetched, coalesced into
        contiguous runs and (by default) issued in parallel, largest-first,
        across a read pool bounded by each tier's concurrency spec — see
        restore_engine.py.  Per-leaf CRCs are pinned to the manifest values
        and payload bytes are verified against them; replica fallback is
        per-range.  Reads both shard formats (v1 seed files and v2).

        With ``promote != "off"`` a restore served from the primary tier is
        teed write-behind into ``promote_tier`` so the NEXT restart on this
        node reads node-local bytes only (the paper's container-image-cache
        effect); a restore whose step is already promoted is served entirely
        from the promoted copy.

        Peer fabric: when this node is cold but warm peers are known (a
        scheduler hint in ``peer_roots`` and/or a ``CacheRegistry``), the
        restore is planned multi-source — local cache, warm peers round-robin,
        then shared — and the promotion tee copies from the peer too, so one
        cold restart warms this node without touching the shared tier at all.
        """
        all_steps = self.steps()
        if not all_steps:
            raise FileNotFoundError("no committed checkpoint found")
        step = all_steps[-1] if step is None else step
        named = manifest = stats = None
        if self._promoter is not None:
            got = self._restore_promoted(step)
            if got is not None:
                named, manifest, stats = got
        if named is None and (self.peer_roots or self.registry is not None):
            got = self._restore_from_peers(step)
            if got is not None:
                named, manifest, stats = got
        if named is None:
            manifest = self.read_manifest(step)
            named, stats = self._restore_files(self.tier, manifest)
            self._schedule_promotion(manifest)
        tree = SER.restore_tree(template, named)
        self._prev_manifest = manifest
        self.last_restore_stats = stats
        return tree, manifest

    # -- peer cache fabric ---------------------------------------------
    def _peer_sources(self, step: int) -> list[str]:
        """Registered peer tiers whose promoted cache is warm for exactly
        ``step``.  Candidates come from the scheduler hint (``peer_roots``)
        merged with the registry; each one's ``PROMOTED.json`` is re-read
        from the peer itself before it is trusted, so a stale inventory
        entry — a peer that GC'd or superseded its cache — is skipped, never
        served."""
        cands: dict[str, tuple[Path, str]] = {}
        for name, root in self.peer_roots.items():
            if self.node is not None and name == self.node:
                continue
            cands[name] = (Path(root), self.promote_tier)
        if self.registry is not None:
            for name, e in self.registry.warm_peers(
                    step, exclude=(self.node,)).items():
                cands.setdefault(
                    name, (Path(e["local_root"]), e.get("tier", "local")))
        tiers: list[str] = []
        for name in sorted(cands):
            root, via = cands[name]
            tier = self.store.add_peer(name, root, via_tier=via)
            try:
                marker = json.loads(
                    self.store.get(tier, self._marker_rel()).decode())
                if not isinstance(marker, dict) or marker.get("step") != step:
                    continue                    # stale/foreign: never served
            except (FileNotFoundError, ValueError, OSError):
                continue
            tiers.append(tier)
        return tiers

    def _restore_from_peers(self, step: int):
        """Multi-source restore of ``step`` from warm peers' promoted caches.
        Returns (named, manifest, stats) or None to fall through to the
        shared tier.  The manifest comes from a peer's promoted copy (step
        pinned; leaf CRCs from it are enforced on every payload byte
        whatever the source), every range task falls back peer -> peer ->
        shared, and the promotion tee is pointed at the peers first so the
        warm-up copy avoids the shared tier too."""
        peer_tiers = self._peer_sources(step)
        if not peer_tiers:
            return None
        man_rel = f"{_step_dir(self.prefix, step)}/MANIFEST.json"
        manifest = None
        for t in peer_tiers:
            try:
                man = json.loads(self.store.get(t, man_rel).decode())
                if man.get("step") != step:
                    raise ValueError("peer manifest step mismatch")
                manifest = man
                break
            except (FileNotFoundError, ValueError, OSError, KeyError):
                continue
        if manifest is None:
            return None
        sources = [self.promote_tier] + peer_tiers + [self.tier]
        engine = ParallelRestorer(self.store, workers=self.restore_workers)
        try:
            named, st = engine.restore_multi(sources, self._by_file(manifest))
        except (SER.ChecksumError, OSError, ValueError, KeyError):
            return None          # peers useless end to end: plain shared path
        stats = {"mode": "parallel", "tier": "peer", "peer": True,
                 "peer_tiers": peer_tiers, **st.as_dict()}
        self._schedule_promotion(manifest,
                                 src_tiers=peer_tiers + [self.tier])
        return named, manifest, stats

    # -- shared -> local tier promotion --------------------------------
    def _marker_rel(self) -> str:
        return f"{self.prefix}/PROMOTED.json"

    def _read_marker(self) -> Optional[dict]:
        try:
            return json.loads(
                self.store.get(self.promote_tier, self._marker_rel()).decode())
        except (FileNotFoundError, ValueError):
            return None

    def invalidate_promoted(self) -> None:
        """Drop the promoted-tier cache (marker first, so a concurrent reader
        never trusts files being deleted under it); the registry entry — the
        cluster-visible claim — comes off with it, so no peer keeps sourcing
        from a cache that is going away."""
        if self.registry is not None and self.node:
            try:
                self.registry.withdraw(self.node)
            except OSError:
                pass    # advisory inventory: a failed withdraw must never
                        # kill the restore/gc path that is invalidating
        self.store.delete_file(self.promote_tier, self._marker_rel())
        self.store.delete_prefix(self.promote_tier, self.prefix)

    def _promo_register(self, step: int) -> None:
        with self._promo_lock:
            self._promo_inflight[step] = self._promo_inflight.get(step, 0) + 1

    def _promo_unregister(self, step: int) -> None:
        with self._promo_lock:
            n = self._promo_inflight.get(step, 0) - 1
            if n <= 0:
                self._promo_inflight.pop(step, None)
                self._promo_doomed.discard(step)
            else:
                self._promo_inflight[step] = n

    def _schedule_promotion(self, manifest: dict,
                            src_tiers: Optional[list[str]] = None) -> None:
        """Best-effort, never blocking: a busy promotion pool means this
        promotion is dropped (counted), not that the training thread waits
        on a cache copy.  Registered BEFORE submission so gc() can cancel a
        promotion that is still queued behind a busy copier — not only one
        already executing."""
        if self._promoter is None:
            return
        step = manifest["step"]
        self._promo_register(step)

        def task(man=manifest, srcs=src_tiers, s=step):
            try:
                self._promote_now(man, src_tiers=srcs)
            finally:
                self._promo_unregister(s)

        if not self._promoter.try_submit(task):
            self.promote_skipped += 1
            self._promo_unregister(step)

    def _restore_promoted(self, step: int):
        """Serve a restore entirely from the promoted tier when its cached
        step matches.  A stale marker (a newer step committed since the
        promotion — manifest-driven invalidation) just misses: the cached
        FILES are deliberately left in place so the follow-up promotion can
        reuse still-referenced incremental base shards and only copy the
        delta; ``_promote_now`` retires whatever the new manifest no longer
        references."""
        marker = self._read_marker()
        if marker is None or marker.get("step") != step:
            return None
        try:
            raw = self.store.get(
                self.promote_tier, f"{_step_dir(self.prefix, step)}/MANIFEST.json")
            manifest = json.loads(raw.decode())
            if manifest.get("step") != step:
                raise ValueError("promoted manifest step mismatch")
            named, stats = self._restore_files(self.promote_tier, manifest)
            stats["promoted"] = True
            return named, manifest, stats
        except (FileNotFoundError, ValueError, KeyError, OSError,
                SER.ChecksumError):
            # damaged/evicted cache: drop it and fall back to the source tier
            self.invalidate_promoted()
            return None

    def _promote_cancelled(self, step: int) -> bool:
        with self._promo_lock:
            return step in self._promo_doomed

    def _promote_now(self, manifest: dict,
                     src_tiers: Optional[list[str]] = None) -> None:
        """Write-behind tee of one committed checkpoint into the promote
        tier.  Incremental-friendly: shard files the previous marker already
        promoted are kept in place (an unchanged multi-GB base shard is never
        re-copied per commit); only missing files are OS-copied and
        CRC-verified against the manifest, and files the new manifest no
        longer references are retired.  The marker comes off FIRST and is
        republished LAST (two-phase — a torn promotion is invisible and gets
        cleaned by the next one).  ``src_tiers`` orders where the copy reads
        from (peer tiers first after a peer-served restore; default the
        primary tier) with per-file fallback down the list.  A promotion
        whose step ``gc()`` starts deleting mid-copy is cancelled before any
        marker is published.  Failures are recorded, never raised: promotion
        is an opportunistic cache."""
        step = manifest["step"]
        # a doom flag set while this promotion was QUEUED must survive into
        # execution, so entry only adds a registration — never clears flags
        self._promo_register(step)
        try:
            self._promote_locked(manifest, step,
                                 src_tiers or [self.tier])
        finally:
            self._promo_unregister(step)

    def _promote_locked(self, manifest: dict, step: int,
                        src_tiers: list[str]) -> None:
        marker = self._read_marker()
        cached = marker.get("step") if marker is not None else None
        if cached == step:
            return
        if cached is not None and cached > step and cached in self.steps():
            return      # never clobber a warmer cache with an older step
        try:
            by_file = self._by_file(manifest)
            have = set(marker.get("files") or []) if marker is not None else set()
            self.store.delete_file(self.promote_tier, self._marker_rel())
            if cached is not None:
                self.store.delete_file(
                    self.promote_tier,
                    f"{_step_dir(self.prefix, cached)}/MANIFEST.json")
            for rel in have - set(by_file):
                self.store.delete_file(self.promote_tier, rel)
            copied: list[str] = []       # this run's copies, for cancel undo
            for rel, ents in by_file.items():
                if self._promote_cancelled(step):
                    self._abort_cancelled(step, copied)
                    return          # gc is deleting this step: no marker
                if rel in have and self.store.exists(self.promote_tier, rel):
                    continue        # already promoted + CRC-verified
                self._copy_promoted(rel, ents, src_tiers)
                copied.append(rel)
            if self._promote_cancelled(step):
                self._abort_cancelled(step, copied)
                return
            sdir = _step_dir(self.prefix, step)
            self.store.put(self.promote_tier, f"{sdir}/MANIFEST.json",
                           json.dumps(manifest).encode(), replicas=1)
            self.store.put(
                self.promote_tier, self._marker_rel(),
                json.dumps({"step": step, "files": sorted(by_file),
                            "promoted_at": time.time()}).encode(),
                replicas=1)
            if self.registry is not None and self.node:
                try:
                    self.registry.publish(
                        self.node, step=step, files=sorted(by_file),
                        local_root=self.store.tier_roots.get(
                            self.promote_tier, self.store.root),
                        tier=self.promote_tier)
                except OSError as e:
                    # the registry is ADVISORY: an unwritable inventory must
                    # not invalidate the (complete, CRC-verified, marker-
                    # published) local cache it merely advertises
                    self.promote_failures.append(
                        f"registry publish step {step}: {e!r}")
        except Exception as e:  # noqa: BLE001 — cache miss, not a failure
            self.promote_failures.append(f"step {step}: {e!r}")
            self.invalidate_promoted()

    def _abort_cancelled(self, step: int, copied: list[str]) -> None:
        """A cancelled promotion must not leak its partial copies: no marker
        will ever reference them, so nothing else would retire them.  Only
        THIS run's copies go — files inherited from the previous marker stay
        for the follow-up promotion to reuse."""
        self.promote_cancelled += 1
        for rel in copied:
            try:
                self.store.delete_file(self.promote_tier, rel)
            except OSError:
                pass                # best-effort: orphans are data, not harm

    def _copy_promoted(self, rel: str, ents: list[dict],
                       src_tiers: list[str]) -> None:
        """Copy + CRC-verify one shard file into the promote tier from the
        first source that yields intact bytes (a peer dying mid-promotion
        falls back to the next peer, then the primary tier)."""
        last: Optional[Exception] = None
        for src in src_tiers:
            try:
                self.store.copy_file(src, rel, self.promote_tier)
                self.store.read_shard_leaves(
                    self.promote_tier, rel, [e["path"] for e in ents],
                    expect_crcs={e["path"]: e["crc32"] for e in ents})
                return
            except Exception as e:  # noqa: BLE001 — try the next source
                last = e
        raise last if last is not None else FileNotFoundError(rel)

    def prefetch_latest(self, step: Optional[int] = None) -> Optional[int]:
        """Eager promotion: schedule a write-behind copy of the latest (or
        given) committed step into the promote tier without restoring it —
        call at job start so the restart after the NEXT preemption is served
        node-locally.  Returns the step scheduled, or None."""
        if self._promoter is None:
            return None
        all_steps = self.steps()
        if not all_steps:
            return None
        step = all_steps[-1] if step is None else step
        if (marker := self._read_marker()) is not None and marker.get("step") == step:
            return step                    # already cached: skip the I/O
        manifest = self.read_manifest(step)
        self._schedule_promotion(manifest)
        return step

    def wait_promotions(self, timeout: Optional[float] = None) -> None:
        if self._promoter is not None:
            self._promoter.wait(timeout)

    # ------------------------------------------------------------------
    def gc(self) -> None:
        """Old manifests are always removed (a checkpoint 'exists' iff its
        manifest does); step dirs survive only while an incremental manifest in
        the kept set references their shard files."""
        steps = self.steps()
        keep = set(steps[-self.keep_last:]) if self.keep_last else set(steps)
        referenced_dirs = set()
        for s in keep:
            man = self.read_manifest(s)
            for e in man["leaves"]:
                referenced_dirs.add(str(Path(e["file"]).parent))
        doomed = [s for s in steps
                  if s not in keep
                  and _step_dir(self.prefix, s) not in referenced_dirs]
        if doomed and self._promoter is not None:
            # GC/promotion race: the write-behind copier may be mid-copy of a
            # step whose shared shards are about to vanish.  Flag it so the
            # copier aborts before publishing a marker, and drop any marker
            # already naming a doomed step (marker first — a reader must
            # never trust files being deleted under it).
            with self._promo_lock:
                for s in doomed:
                    if s in self._promo_inflight:
                        self._promo_doomed.add(s)
            marker = self._read_marker()
            if marker is not None and marker.get("step") in doomed:
                self.invalidate_promoted()
        for s in steps:
            if s in keep:
                continue
            sdir = _step_dir(self.prefix, s)
            if sdir in referenced_dirs:
                # keep the shard data, retire the manifest + parts.  The
                # retired step may have been written under a DIFFERENT worker
                # count (elastic restart), so the part count comes from the
                # step's own manifest — not this manager's num_workers.
                try:
                    nw = int(self.read_manifest(s).get("num_workers",
                                                       self.num_workers))
                except (FileNotFoundError, ValueError, KeyError):
                    nw = 0
                self.store.delete_file(self.tier, f"{sdir}/MANIFEST.json")
                if nw:
                    for w in range(nw):
                        self.store.delete_file(
                            self.tier, f"{sdir}/wpart_{w:05d}.json")
                else:   # manifest unreadable: sweep whatever parts exist
                    for rel in self.store.list_prefix(self.tier, sdir):
                        if Path(rel).name.startswith("wpart_"):
                            self.store.delete_file(self.tier, rel)
            else:
                self.store.delete_prefix(self.tier, sdir)

    def close(self) -> None:
        try:
            if self._writer is not None:
                self._writer.close()
        finally:
            if self._promoter is not None:
                self._promoter.close()
