"""CheckpointManager: sharded, atomic, optionally async/incremental checkpoints.

Layout under the store (per tier):
  <prefix>/step_<N>/shard_w<world-id>.bin     one shard per worker
  <prefix>/step_<N>/wpart_<id>.json           per-worker manifest part
  <prefix>/step_<N>/MANIFEST.json             atomic commit marker (written LAST,
                                              by the coordinator / single worker)

A checkpoint exists iff MANIFEST.json exists — a preemption mid-write leaves no
manifest and the restart falls back to the previous step (two-phase commit, the
framework analogue of DMTCP's coordinator barrier).

Leaf ownership: leaf i belongs to worker (i % num_workers).  Restore reads every
worker part, so a checkpoint taken with N workers restores under M workers (the
MxN / elastic-restart property; mesh placement is re-derived by
core/virtualization.py).

Incremental mode (beyond-paper): a leaf whose crc32 is unchanged since the
previous *committed* checkpoint is not rewritten — its manifest entry points at
the older shard file.  GC keeps referenced base files alive.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Optional

import numpy as np

from repro.checkpoint import serialization as SER
from repro.checkpoint.async_writer import AsyncWriter
from repro.checkpoint.store import TieredStore


def _step_dir(prefix: str, step: int) -> str:
    return f"{prefix}/step_{step:010d}"


class CheckpointManager:
    def __init__(self, store: TieredStore, *, tier: str = "shared",
                 worker_id: int = 0, num_workers: int = 1, replicas: int = 2,
                 mode: str = "sync", incremental: bool = False,
                 keep_last: int = 3, prefix: str = "ckpt"):
        assert mode in ("sync", "async")
        self.store = store
        self.tier = tier
        self.worker_id = worker_id
        self.num_workers = num_workers
        self.replicas = replicas
        self.mode = mode
        self.incremental = incremental
        self.keep_last = keep_last
        self.prefix = prefix
        self._writer = AsyncWriter() if mode == "async" else None
        self._prev_manifest: Optional[dict] = None

    # ------------------------------------------------------------------
    def _my_leaves(self, records):
        return [
            (i, name, arr) for i, (name, arr) in enumerate(records)
            if i % self.num_workers == self.worker_id
        ]

    def save(self, step: int, tree, extra_meta: Optional[dict] = None) -> dict:
        """Snapshot + write this worker's shard.  Returns the worker part dict.

        In async mode the device->host snapshot happens here (the only quiesced
        section); serialization and store writes run on the writer thread.
        """
        t0 = time.time()
        records = SER.tree_to_records(tree)            # snapshot (device_get)
        snap_s = time.time() - t0
        mine = self._my_leaves(records)
        sdir = _step_dir(self.prefix, step)
        shard_rel = f"{sdir}/shard_w{self.worker_id:05d}.bin"

        prev_entries = {}
        if self.incremental and self._prev_manifest:
            prev_entries = {
                e["path"]: e for e in self._prev_manifest["leaves"]
            }

        entries, to_write = [], []
        for idx, name, arr in mine:
            crc = SER.leaf_checksum(arr)
            prev = prev_entries.get(name)
            if prev is not None and prev["crc32"] == crc and prev.get("file"):
                entries.append({**prev, "reused": True})
            else:
                to_write.append((name, arr))
                entries.append({
                    "path": name, "index": idx, "crc32": crc,
                    "dtype": str(arr.dtype), "shape": list(arr.shape),
                    "file": shard_rel, "reused": False,
                })

        part = {
            "worker_id": self.worker_id,
            "num_workers": self.num_workers,
            "step": step,
            "leaves": entries,
            "snapshot_s": snap_s,
            "meta": extra_meta or {},
        }

        def do_write():
            if to_write:
                data = SER.write_shard_bytes(to_write, meta={"step": step})
                self.store.put(self.tier, shard_rel, data, replicas=self.replicas)
            self.store.put(
                self.tier, f"{sdir}/wpart_{self.worker_id:05d}.json",
                json.dumps(part).encode(), replicas=self.replicas)

        if self._writer is not None:
            self._writer.submit(do_write)
        else:
            do_write()
        return part

    def wait_writes(self, timeout: Optional[float] = None) -> None:
        if self._writer is not None:
            self._writer.wait(timeout)

    # ------------------------------------------------------------------
    def commit(self, step: int, *, num_workers: Optional[int] = None,
               extra_meta: Optional[dict] = None) -> dict:
        """Coordinator-side: verify all worker parts exist, write MANIFEST last."""
        self.wait_writes()
        nw = num_workers or self.num_workers
        sdir = _step_dir(self.prefix, step)
        leaves = []
        meta: dict = {}
        for w in range(nw):
            raw = self.store.get(self.tier, f"{sdir}/wpart_{w:05d}.json")
            part = json.loads(raw.decode())
            leaves.extend(part["leaves"])
            meta.update(part.get("meta") or {})   # worker metas merge (w0 first)
        leaves.sort(key=lambda e: e["index"])
        meta.update(extra_meta or {})
        manifest = {
            "step": step,
            "num_workers": nw,
            "leaves": leaves,
            "committed_at": time.time(),
            "meta": meta,
        }
        self.store.put(self.tier, f"{sdir}/MANIFEST.json",
                       json.dumps(manifest).encode(), replicas=self.replicas)
        self._prev_manifest = manifest
        self.gc()
        return manifest

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        rels = self.store.list_prefix(self.tier, self.prefix)
        out = set()
        for r in rels:
            parts = Path(r).parts
            if len(parts) >= 2 and parts[-1] == "MANIFEST.json":
                out.add(int(parts[-2].split("_")[1]))
        return sorted(out)

    def read_manifest(self, step: int) -> dict:
        raw = self.store.get(self.tier, f"{_step_dir(self.prefix, step)}/MANIFEST.json")
        return json.loads(raw.decode())

    def restore(self, template, step: Optional[int] = None):
        """Returns (host_tree, manifest).  Verifies per-leaf crcs; replica
        fallback happens inside the store."""
        all_steps = self.steps()
        if not all_steps:
            raise FileNotFoundError("no committed checkpoint found")
        step = all_steps[-1] if step is None else step
        manifest = self.read_manifest(step)
        by_file: dict[str, list[dict]] = {}
        for e in manifest["leaves"]:
            by_file.setdefault(e["file"], []).append(e)
        named: dict[str, np.ndarray] = {}
        for rel, ents in by_file.items():
            tensors, _ = self.store.get_verified(self.tier, rel)
            for e in ents:
                arr = tensors[e["path"]]
                if SER.leaf_checksum(arr) != e["crc32"]:
                    raise SER.ChecksumError(f"manifest crc mismatch: {e['path']}")
                named[e["path"]] = arr
        tree = SER.restore_tree(template, named)
        self._prev_manifest = manifest
        return tree, manifest

    # ------------------------------------------------------------------
    def gc(self) -> None:
        """Old manifests are always removed (a checkpoint 'exists' iff its
        manifest does); step dirs survive only while an incremental manifest in
        the kept set references their shard files."""
        steps = self.steps()
        keep = set(steps[-self.keep_last:]) if self.keep_last else set(steps)
        referenced_dirs = set()
        for s in keep:
            man = self.read_manifest(s)
            for e in man["leaves"]:
                referenced_dirs.add(str(Path(e["file"]).parent))
        for s in steps:
            if s in keep:
                continue
            sdir = _step_dir(self.prefix, s)
            if sdir in referenced_dirs:
                # keep the shard data, retire the manifest + parts
                self.store.delete_file(self.tier, f"{sdir}/MANIFEST.json")
                for w in range(self.num_workers):
                    self.store.delete_file(self.tier, f"{sdir}/wpart_{w:05d}.json")
            else:
                self.store.delete_prefix(self.tier, sdir)

    def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
