"""Checkpoint shard serialization: pytree <-> binary shard files.

Format (one file per worker shard):
  [8B magic 'RPRCKPT1'][4B header_len][header JSON][raw tensor bytes...]
Header: {"tensors": [{"path","dtype","shape","offset","nbytes","crc32"}...],
         "meta": {...}, "file_crc32": ...}

CRC32 per tensor (the DMTCP paper stores redundant images; we store checksummed
shards + k replicas — integrity is checked on read and the store falls back to
another replica on mismatch).  Pure numpy/zlib; no pickle for tensor data.
"""
from __future__ import annotations

import io
import json
import struct
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.tree import flatten_with_names, unflatten_like

MAGIC = b"RPRCKPT1"


def tree_to_records(tree) -> list[tuple[str, np.ndarray]]:
    out = []
    for name, leaf in flatten_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        out.append((name, arr))
    return out


def leaf_checksum(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).view(np.uint8).tobytes())


def write_shard_bytes(records: list[tuple[str, np.ndarray]],
                      meta: Optional[dict] = None) -> bytes:
    tensors = []
    blobs = []
    offset = 0
    for name, arr in records:
        arr = np.asarray(arr)
        shape = list(arr.shape)          # before ascontiguousarray (it is >=1-d)
        arr = np.ascontiguousarray(arr)
        raw = arr.tobytes()
        tensors.append({
            "path": name,
            "dtype": str(arr.dtype),
            "shape": shape,
            "offset": offset,
            "nbytes": len(raw),
            "crc32": zlib.crc32(raw),
        })
        blobs.append(raw)
        offset += len(raw)
    header = json.dumps({"tensors": tensors, "meta": meta or {}}).encode()
    buf = io.BytesIO()
    buf.write(MAGIC)
    buf.write(struct.pack("<I", len(header)))
    buf.write(header)
    for raw in blobs:
        buf.write(raw)
    return buf.getvalue()


def read_shard_bytes(data: bytes, *, verify: bool = True):
    """Returns ({path: np.ndarray}, meta)."""
    if data[:8] != MAGIC:
        raise ValueError("bad checkpoint shard magic")
    (hlen,) = struct.unpack("<I", data[8:12])
    header = json.loads(data[12 : 12 + hlen].decode())
    base = 12 + hlen
    out = {}
    for t in header["tensors"]:
        raw = data[base + t["offset"] : base + t["offset"] + t["nbytes"]]
        if verify and zlib.crc32(raw) != t["crc32"]:
            raise ChecksumError(f"crc mismatch for tensor {t['path']}")
        arr = np.frombuffer(raw, dtype=np.dtype(t["dtype"])).reshape(t["shape"])
        out[t["path"]] = arr
    return out, header["meta"]


class ChecksumError(RuntimeError):
    pass


def write_shard(path: Path, records, meta=None) -> dict:
    data = write_shard_bytes(records, meta)
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_bytes(data)
    tmp.rename(path)
    return {"nbytes": len(data), "crc32": zlib.crc32(data)}


def read_shard(path: Path, *, verify: bool = True):
    return read_shard_bytes(Path(path).read_bytes(), verify=verify)


def restore_tree(template, named: dict[str, np.ndarray]):
    """Rebuild a pytree shaped like ``template`` from {path: array}."""
    return unflatten_like(template, named)
