"""Checkpoint shard serialization: pytree <-> binary shard files.

Three on-disk formats (see EXPERIMENTS.md for the byte-level spec):

v1 (legacy, read-compatible, header-first):
  [8B magic 'RPRCKPT1'][4B header_len][header JSON][raw tensor bytes...]
  Header: {"tensors": [{"path","dtype","shape","offset","nbytes","crc32"}...],
           "meta": {...}}; tensor offsets are relative to the end of the header.

v2 (footer-last, written in a single streaming pass):
  [8B magic 'RPRCKPT2'][raw tensor bytes...][footer JSON]
  [8B footer_len (<Q)][8B magic 'RPRCKPT2']
  Footer: same schema as the v1 header but tensor offsets are ABSOLUTE file
  offsets, so a reader can fetch any single leaf with one ranged read after
  parsing the footer (found from the fixed-size 16-byte trailer).

v3 (content-addressed chunk index; the delta-checkpoint plane):
  [8B magic 'RPRCKPT3'][index JSON][8B index_len (<Q)][8B magic 'RPRCKPT3']
  The index maps each leaf to a LIST OF FIXED-SIZE CHUNKS:
  {"tensors": [{"path","dtype","shape","nbytes","crc32",
                "chunks": [{"hash","nbytes","crc32"}...]}...],
   "meta": {...}, "format": 3, "chunk_bytes": N}
  A v3 file carries NO payload: chunk bytes live in the store's dedup chunk
  plane (``chunks/<hash-prefix>/<hash>``, see store.py), named by content
  hash, so a chunk shared by two steps — or two leaves — exists on disk
  exactly once and a delta save writes only the chunks whose hash changed
  since the parent step.  ``crc32`` on the tensor entry is the WHOLE-LEAF
  crc (the same value v1/v2 store), so a chunk-assembled leaf is verified
  byte-identical to what a full shard restore would produce.

The v2 writer is zero-copy: each leaf's bytes are exposed as a ``memoryview``
(no ``tobytes()`` materialization), its CRC32 is computed once from that view
(or taken from a precomputed map so the save path CRCs each leaf exactly once),
and the view is handed straight to the sink file object.  Peak extra host
memory is therefore one OS write buffer, not one full shard.

CRC32 per tensor (the DMTCP paper stores redundant images; we store checksummed
shards + k replicas — integrity is checked on read and the store falls back to
another replica on mismatch).  Pure numpy/zlib; no pickle for tensor data.
"""
from __future__ import annotations

import functools
import hashlib
import io
import json
import logging
import os
import struct
import zlib
from pathlib import Path
from typing import BinaryIO, Callable, Optional

import jax
import numpy as np

from repro.utils.env import env_positive_int
from repro.utils.tree import flatten_with_names, unflatten_like

log = logging.getLogger(__name__)

MAGIC = b"RPRCKPT1"      # v1: header-first
MAGIC2 = b"RPRCKPT2"     # v2: footer-last, absolute offsets, streamable
MAGIC3 = b"RPRCKPT3"     # v3: payload-free content-addressed chunk index
TRAILER_LEN = 16         # <Q footer_len> + trailing magic (v2 and v3)
# Streaming granularity: CRC/write are chunked so a corrupted mmap'd page or a
# slow sink never pins more than this much per step; views are zero-copy so
# chunking costs no extra memory either way.
CHUNK_BYTES = 4 << 20
# Content-addressing granularity (v3): the unit of dedup and of delta
# transfer.  Smaller chunks shrink the delta for scattered updates but grow
# per-chunk metadata and per-file overhead; 1 MiB keeps the index ~0.01% of
# the payload while an optimizer-only step still collapses to a few chunks.
DELTA_CHUNK_BYTES = 1 << 20


class ChecksumError(RuntimeError):
    pass


# -- per-chunk compression frame (the dedup store's on-disk unit) -----------
#
# A chunk FILE may carry a 4-byte frame header in front of its payload:
#
#   [3B magic b'RCK'][1B codec]  codec 0 = raw, 1 = zlib, 2 = zstd
#
# Hashes, per-chunk CRCs and fingerprints are always over the UNCOMPRESSED
# content — the frame changes only what sits on disk, so dedup, the
# fingerprint pre-filter and the pre-dump pipeline are untouched, and two
# stores at different compression levels still agree on every chunk name.
# Frameless files (every chunk written before compression existed, and all
# writes at ``compress=0``) stay readable: ``unframe_chunk`` disambiguates
# by the known raw size, with the caller's CRC as the final arbiter for the
# pathological raw-bytes-that-look-framed case.

CHUNK_FRAME_MAGIC = b"RCK"
CHUNK_FRAME_LEN = 4
CODEC_RAW = 0
CODEC_ZLIB = 1
CODEC_ZSTD = 2

try:                                    # optional: not in every environment
    import zstandard as _zstd           # pragma: no cover - env-dependent
except ImportError:
    _zstd = None


def zstd_available() -> bool:
    return _zstd is not None


def preferred_codec() -> int:
    """zstd when the binding is importable, else stdlib zlib — compression
    must degrade, never become an install requirement."""
    return CODEC_ZSTD if _zstd is not None else CODEC_ZLIB


def frame_chunk(data, level: int, codec: Optional[int] = None) -> bytes:
    """Compress + frame one chunk payload for the dedup store.

    ``level`` is the policy's ``compress`` level (>= 1; level 0 means "no
    framing at all" and must be handled by the caller — existing stores stay
    byte-identical by default).  A chunk that compresses to no gain is
    framed with ``CODEC_RAW`` instead, so the reader never pays an inflate
    for incompressible float noise and ``cbytes`` stays honest (raw + 4)."""
    if level < 1:
        raise ValueError(f"frame_chunk wants level >= 1, got {level}")
    raw = bytes(data)
    codec = preferred_codec() if codec is None else codec
    if codec == CODEC_ZSTD and _zstd is not None:
        comp = _zstd.ZstdCompressor(level=level).compress(raw)
    elif codec in (CODEC_ZSTD, CODEC_ZLIB):
        codec = CODEC_ZLIB
        comp = zlib.compress(raw, min(level, 9))
    elif codec == CODEC_RAW:
        comp = raw
    else:
        raise ValueError(f"unknown chunk codec {codec}")
    if len(comp) >= len(raw):
        codec, comp = CODEC_RAW, raw
    return CHUNK_FRAME_MAGIC + bytes([codec]) + comp


def _inflate_chunk(codec: int, payload: bytes, raw_nbytes: int) -> bytes:
    if codec == CODEC_RAW:
        return payload
    if codec == CODEC_ZLIB:
        return zlib.decompress(payload)
    if codec == CODEC_ZSTD:
        if _zstd is None:
            raise ChecksumError(
                "chunk framed with zstd but no zstd binding is available")
        return _zstd.ZstdDecompressor().decompress(
            payload, max_output_size=raw_nbytes)
    raise ChecksumError(f"unknown chunk codec {codec}")


def unframe_chunk(blob: bytes, raw_nbytes: int,
                  crc32: Optional[int] = None) -> bytes:
    """Recover the raw chunk content from an on-disk chunk file.

    Speaks both generations: framed files (4-byte header) and legacy
    frameless files (payload only).  Disambiguation: a frameless chunk's
    file length equals its raw ``nbytes`` exactly, a framed one's almost
    never does — and in the one ambiguous corner (raw content that happens
    to start with the frame magic AND a framed file whose length equals the
    raw size) the caller-pinned ``crc32`` decides.  Raises ``ChecksumError``
    when no interpretation yields ``raw_nbytes`` verified bytes."""
    framed = (len(blob) >= CHUNK_FRAME_LEN
              and blob[:len(CHUNK_FRAME_MAGIC)] == CHUNK_FRAME_MAGIC)
    legacy_sized = len(blob) == raw_nbytes
    if framed:
        try:
            raw = _inflate_chunk(blob[3], blob[CHUNK_FRAME_LEN:], raw_nbytes)
        except (zlib.error, ValueError, ChecksumError):
            raw = None
        if (raw is not None and len(raw) == raw_nbytes
                and (crc32 is None or zlib.crc32(raw) == crc32)):
            return raw
        # framed parse failed (or mismatched the pinned CRC): raw content
        # starting with the magic bytes is still a legal legacy file
    if legacy_sized and (crc32 is None or zlib.crc32(blob) == crc32):
        return blob
    raise ChecksumError(
        f"chunk file unreadable as framed or raw ({len(blob)} bytes, "
        f"want {raw_nbytes} raw)")


# ---------------------------------------------------------------------------
# zero-copy leaf byte views
# ---------------------------------------------------------------------------

def as_byte_view(arr: np.ndarray) -> memoryview:
    """Flat uint8 ``memoryview`` over ``arr``'s payload without copying.

    Copies only if the array is non-contiguous (``ascontiguousarray``) — the
    device_get snapshot path always produces contiguous arrays, so the hot
    path is copy-free.  0-d arrays are promoted to shape (1,) views (their
    logical shape is recorded separately by the caller).
    """
    arr = np.ascontiguousarray(arr)
    return memoryview(arr.view(np.uint8).reshape(-1))


def leaf_checksum(arr: np.ndarray) -> int:
    """CRC32 of a leaf's raw bytes, computed from a zero-copy view.

    This is the single per-leaf CRC entry point for the save path: the
    streaming writer accepts the values it returns via ``crcs=`` and never
    recomputes them.
    """
    return zlib.crc32(as_byte_view(arr))


# ---------------------------------------------------------------------------
# v2: single-pass streaming writer
# ---------------------------------------------------------------------------

def write_shard_stream(fp: BinaryIO,
                       records: list[tuple[str, np.ndarray]],
                       meta: Optional[dict] = None,
                       *,
                       crcs: Optional[dict[str, int]] = None,
                       chunk_bytes: int = CHUNK_BYTES) -> dict:
    """Stream a v2 shard into ``fp`` in one pass; returns the footer dict.

    Each leaf is written directly from a ``memoryview`` — no per-leaf
    ``tobytes()`` copy and no whole-shard buffer.  If ``crcs`` maps a leaf
    path to a precomputed CRC32 it is trusted verbatim (the manager computes
    it once during the incremental diff); otherwise the CRC is folded in
    chunk-by-chunk as the bytes stream out, still a single pass.
    """
    fp.write(MAGIC2)
    offset = len(MAGIC2)
    tensors = []
    for name, arr in records:
        arr = np.asarray(arr)
        shape = list(arr.shape)          # before as_byte_view 0-d promotion
        view = as_byte_view(arr)
        nbytes = view.nbytes
        crc = None if crcs is None else crcs.get(name)
        if crc is None:
            crc = 0
            for start in range(0, nbytes, chunk_bytes):
                chunk = view[start:start + chunk_bytes]
                crc = zlib.crc32(chunk, crc)
                fp.write(chunk)
        else:
            for start in range(0, nbytes, chunk_bytes):
                fp.write(view[start:start + chunk_bytes])
        tensors.append({
            "path": name,
            "dtype": str(arr.dtype),
            "shape": shape,
            "offset": offset,            # ABSOLUTE file offset (v2)
            "nbytes": nbytes,
            "crc32": crc,
        })
        offset += nbytes
    footer = {"tensors": tensors, "meta": meta or {}, "format": 2}
    raw = json.dumps(footer).encode()
    fp.write(raw)
    fp.write(struct.pack("<Q", len(raw)))
    fp.write(MAGIC2)
    return footer


def write_shard_bytes_v2(records, meta=None, *, crcs=None) -> bytes:
    """v2 shard as one bytes object (tests/tools; the hot path streams)."""
    buf = io.BytesIO()
    write_shard_stream(buf, records, meta, crcs=crcs)
    return buf.getvalue()


# ---------------------------------------------------------------------------
# v3: content-addressed chunking (the delta-checkpoint plane)
# ---------------------------------------------------------------------------

def chunk_hash(view) -> str:
    """Content hash naming one chunk in the dedup store.  blake2b at 16
    bytes: keyless, stdlib, ~3x faster than sha256 on large buffers, and 128
    bits is far past birthday-collision range for any real checkpoint volume
    (integrity is separately guaranteed by CRCs pinned in the manifest)."""
    return hashlib.blake2b(view, digest_size=16).hexdigest()


# -- CRC32 combining (GF(2) matrix shift, zlib's crc32_combine) ------------
#
# crc32(A+B) == apply(OP(len(B)), crc32(A)) ^ crc32(B) where OP(n) is the
# linear operator advancing a CRC register past n zero bytes.  zlib composes
# OP from log2(n) squarings PER CALL (~20k Python ops here) — slower than
# just re-CRCing a small chunk.  The delta plane folds per-chunk CRCs into a
# leaf CRC over a handful of DISTINCT lengths (chunk_bytes plus one tail per
# leaf), so the composed operator is cached per length and each fold costs
# one 32x32 GF(2) apply (~32 int ops), making the leaf CRC free of any
# second byte traversal.

_CRC32_POLY = 0xEDB88320


def _gf2_times_vec(mat: tuple, vec: int) -> int:
    out = 0
    i = 0
    while vec:
        if vec & 1:
            out ^= mat[i]
        vec >>= 1
        i += 1
    return out


def _gf2_square(mat: tuple) -> tuple:
    return tuple(_gf2_times_vec(mat, mat[n]) for n in range(32))


@functools.lru_cache(maxsize=1024)
def _crc32_shift_operator(nbytes: int) -> tuple:
    """32x32 GF(2) matrix (columns as ints) advancing a CRC32 register past
    ``nbytes`` zero bytes.  Cached: chunked leaves fold over very few
    distinct lengths."""
    odd = (_CRC32_POLY,) + tuple(1 << (n - 1) for n in range(1, 32))  # 1 bit
    odd = _gf2_square(_gf2_square(odd))                               # 4 bits
    op = tuple(1 << n for n in range(32))                             # identity
    n = nbytes
    while n:
        odd = _gf2_square(odd)            # 8, 16, 32, ... zero bits
        if n & 1:
            op = tuple(_gf2_times_vec(odd, op[i]) for i in range(32))
        n >>= 1
    return op


def crc32_combine(crc1: int, crc2: int, len2: int) -> int:
    """``crc32(A+B)`` from ``crc32(A)``, ``crc32(B)`` and ``len(B)`` without
    touching any bytes (zlib's crc32_combine, with the shift operator cached
    per length)."""
    if len2 <= 0:
        return crc1
    return _gf2_times_vec(_crc32_shift_operator(len2), crc1) ^ crc2


def chunk_leaf(arr: np.ndarray, chunk_bytes: int = DELTA_CHUNK_BYTES):
    """Split one leaf into fixed-size content-addressed chunks.

    Returns ``(entries, views, leaf_crc32)``: per-chunk dicts
    ``{"hash","nbytes","crc32"}``, the matching zero-copy ``memoryview``s
    (aligned with ``entries``; valid while ``arr`` lives), and the whole-leaf
    CRC32 folded from the per-chunk CRCs via ``crc32_combine`` — so a delta
    save hashes, CRCs and diffs every leaf in ONE traversal of its bytes and
    the leaf CRC costs zero additional byte passes.
    """
    view = as_byte_view(np.asarray(arr))
    entries, views = [], []
    leaf_crc = 0
    for start in range(0, view.nbytes, chunk_bytes):
        part = view[start:start + chunk_bytes]
        crc = zlib.crc32(part)
        leaf_crc = crc32_combine(leaf_crc, crc, part.nbytes)
        entries.append({"hash": chunk_hash(part), "nbytes": part.nbytes,
                        "crc32": crc})
        views.append(part)
    return entries, views, leaf_crc


# -- per-chunk fingerprints (the dirty-chunk pre-filter) -------------------
#
# A 32-bit FNV-style mix per chunk, bit-identical across three impls: this
# vectorized numpy path (host bytes), kernels/ref.py::chunk_fingerprints
# (jnp oracle) and kernels/checksum.py::chunk_fingerprints_pallas (on-device,
# HBM bandwidth).  The fingerprint is a cheap PRE-FILTER in the CRIU
# soft-dirty sense: a chunk whose fingerprint matches the parent step's is
# treated as clean and skips blake2b; chunks it flags dirty are still named
# by their full content hash.  Correctness therefore never depends on the 32
# bits — a colliding dirty chunk (p ~ 2^-32 per chunk) is silently treated
# as clean, which is why fingerprint filtering is opt-in on the manager.

FP_PRIME = 16777619          # matches kernels PRIME (FNV-1 32-bit prime)


def fingerprint_chunks(data, chunk_bytes: int = DELTA_CHUNK_BYTES) -> np.ndarray:
    """uint32 fingerprint per fixed-size chunk of ``data`` (bytes-like or a
    byte view); the tail chunk is zero-padded so the value agrees with the
    device kernels on padded word streams.  Index mixing is chunk-LOCAL so a
    chunk's fingerprint is position-independent within the leaf."""
    if chunk_bytes < 4 or chunk_bytes % 4:
        raise ValueError(f"chunk_bytes must be a multiple of 4, got {chunk_bytes}")
    buf = np.frombuffer(data, dtype=np.uint8)
    n = buf.nbytes
    if n == 0:
        return np.zeros(0, np.uint32)
    nchunks = -(-n // chunk_bytes)
    if nchunks * chunk_bytes != n:
        padded = np.zeros(nchunks * chunk_bytes, np.uint8)
        padded[:n] = buf
        buf = padded
    words = buf.view("<u4").reshape(nchunks, chunk_bytes // 4)
    idx = np.arange(chunk_bytes // 4, dtype=np.uint32)
    mixed = (words ^ (idx * np.uint32(FP_PRIME))) * (idx | np.uint32(1))
    return np.bitwise_xor.reduce(mixed, axis=1) + mixed.sum(
        axis=1, dtype=np.uint32)


# -- parallel chunk hash/CRC engine ----------------------------------------

ENV_HASH_WORKERS = "REPRO_HASH_WORKERS"

# below this size the WorkPool handoff costs more than the digest itself
# (and neither blake2b nor crc32 releases the GIL for tiny buffers), so
# sub-threshold chunks are digested inline on the producer thread
INLINE_HASH_BYTES = 1 << 15


def auto_hash_workers(cap: Optional[int] = None) -> int:
    """Hash-engine pool sizing, mirroring restore_engine.auto_workers:
    ``REPRO_HASH_WORKERS`` wins outright when set to a positive integer;
    otherwise the CPU count (min 2, optionally capped).  A mangled override
    degrades to auto sizing with a logged warning — an operator typo must
    never kill a save (the parse contract lives in ``utils.env``)."""
    n = env_positive_int(ENV_HASH_WORKERS, logger=log)
    if n is not None:
        return n
    n = max(2, os.cpu_count() or 2)
    if cap:
        n = min(n, max(1, cap))
    return n


class ChunkHashEngine:
    """Multi-threaded chunk hash/CRC engine behind the ``chunk_leaf``
    contract.

    blake2b releases the GIL for updates past ~2 KB and zlib.crc32 past
    ~5 KB, so digesting many chunks on a small ``WorkPool`` (the same
    primitive the async writer and the promotion tee run on) scales with
    memory bandwidth instead of single-core hash speed.  Results are written
    into per-chunk slots, so entry order, hashes, per-chunk CRCs and the
    folded leaf CRC are byte-identical to the serial ``chunk_leaf`` path.

    The pool is created lazily on first use and only when ``workers > 1`` —
    a serial engine costs nothing beyond the function calls.
    """

    def __init__(self, workers: int = 0):
        self.workers = int(workers) if workers and int(workers) >= 1 \
            else auto_hash_workers()
        self._pool = None

    def _ensure_pool(self):
        if self.workers <= 1:
            return None
        if self._pool is None:
            from repro.checkpoint.async_writer import WorkPool
            self._pool = WorkPool(max_inflight=4 * self.workers,
                                  workers=self.workers, name="ckpt-hash")
        return self._pool

    @staticmethod
    def _digest(part) -> tuple[str, int]:
        return chunk_hash(part), zlib.crc32(part)

    def chunk_leaf(self, arr: np.ndarray,
                   chunk_bytes: int = DELTA_CHUNK_BYTES):
        """Parallel drop-in for module-level ``chunk_leaf`` — identical
        ``(entries, views, leaf_crc32)``."""
        out, _ = self.chunk_records([("", np.asarray(arr))], chunk_bytes)
        return out[""]

    def digest_views(self, views) -> list[tuple[str, int]]:
        """``(blake2b hash, crc32)`` per byte view, all in flight at once on
        the pool (sub-threshold views digested inline, same policy as
        ``chunk_records``).  The device-resident delta path uses this for
        the DIRTY chunks it gathered — it has no per-leaf arrays to hand
        to ``chunk_records``, just the fetched slices."""
        slots: list = [None] * len(views)
        pool = self._ensure_pool()
        if pool is None:
            for i, v in enumerate(views):
                slots[i] = self._digest(v)
            return slots

        def task(i, part):
            slots[i] = self._digest(part)
        for i, v in enumerate(views):
            if v.nbytes < INLINE_HASH_BYTES:
                slots[i] = self._digest(v)
            else:
                pool.submit(functools.partial(task, i, v))
        pool.wait()
        return slots

    def chunk_records(self, items, chunk_bytes: int = DELTA_CHUNK_BYTES, *,
                      known: Optional[dict] = None,
                      fps: Optional[dict] = None):
        """Hash/CRC every chunk of every leaf with ALL chunks in flight at
        once (one ``wait()`` at the end — no per-leaf barrier).

        ``items``: [(name, np.ndarray)].  ``known`` optionally maps
        ``name -> {chunk_index: entry}`` of already-trusted entries (the
        fingerprint pre-filter / pre-dump state); a known entry is reused
        verbatim — no blake2b, no crc — after its ``nbytes`` is checked
        against the live chunk layout.  ``fps`` optionally maps ``name`` to
        a per-chunk uint32 array stamped into the entries as ``"fp"``.

        Returns ``({name: (entries, views, leaf_crc)}, stats)`` with stats
        counting ``chunks_hashed`` vs ``chunks_known``.
        """
        known = known or {}
        fps = fps or {}
        plans = []
        for name, arr in items:
            view = as_byte_view(np.asarray(arr))
            parts = [view[s:s + chunk_bytes]
                     for s in range(0, view.nbytes, chunk_bytes)]
            slots: list = [None] * len(parts)
            kmap = known.get(name) or {}
            todo = []
            for i, part in enumerate(parts):
                e = kmap.get(i)
                if e is not None and e.get("nbytes") == part.nbytes:
                    slots[i] = (e["hash"], e["crc32"])
                else:
                    todo.append(i)
            plans.append((name, parts, slots, todo))

        pool = self._ensure_pool()
        if pool is None:
            for _, parts, slots, todo in plans:
                for i in todo:
                    slots[i] = self._digest(parts[i])
        else:
            # distinct list indices per task: no lock needed on the slots
            def task(slots, i, part):
                slots[i] = self._digest(part)
            for _, parts, slots, todo in plans:
                for i in todo:
                    if parts[i].nbytes < INLINE_HASH_BYTES:
                        slots[i] = self._digest(parts[i])
                    else:
                        pool.submit(functools.partial(task, slots, i,
                                                      parts[i]))
            pool.wait()

        out = {}
        hashed = reused = 0
        for name, parts, slots, todo in plans:
            fp = fps.get(name)
            entries = []
            leaf_crc = 0
            for i, (part, (h, crc)) in enumerate(zip(parts, slots)):
                e = {"hash": h, "nbytes": part.nbytes, "crc32": crc}
                if fp is not None and i < len(fp):
                    e["fp"] = int(fp[i])
                entries.append(e)
                leaf_crc = crc32_combine(leaf_crc, crc, part.nbytes)
            hashed += len(todo)
            reused += len(parts) - len(todo)
            out[name] = (entries, parts, leaf_crc)
        stats = {"chunks_hashed": hashed, "chunks_known": reused,
                 "hash_workers": self.workers if pool is not None else 1}
        return out, stats

    def close(self) -> None:
        if self._pool is not None:
            self._pool.close()
            self._pool = None


def write_chunk_index(fp: BinaryIO, tensors: list[dict],
                      meta: Optional[dict] = None, *,
                      chunk_bytes: int = DELTA_CHUNK_BYTES) -> dict:
    """Write a payload-free v3 chunk-index file: trailer-delimited JSON
    mapping leaves -> chunk lists.  ``tensors`` entries must carry
    ``path/dtype/shape/nbytes/crc32/chunks``.  Parses back through
    ``read_shard_header`` (``format == 3``) like any other shard."""
    index = {"tensors": tensors, "meta": meta or {}, "format": 3,
             "chunk_bytes": chunk_bytes}
    raw = json.dumps(index).encode()
    fp.write(MAGIC3)
    fp.write(raw)
    fp.write(struct.pack("<Q", len(raw)))
    fp.write(MAGIC3)
    return index


def write_chunk_index_bytes(tensors, meta=None, *,
                            chunk_bytes: int = DELTA_CHUNK_BYTES) -> bytes:
    buf = io.BytesIO()
    write_chunk_index(buf, tensors, meta, chunk_bytes=chunk_bytes)
    return buf.getvalue()


def assemble_leaf(t: dict, chunk_bytes_list: list[bytes], *,
                  verify: bool = True) -> np.ndarray:
    """Materialize one chunked tensor entry from its chunk payloads (in
    chunk-list order).  Verifies each chunk's CRC and the whole-leaf CRC, so
    the result is byte-identical to a full-shard restore or the read fails."""
    buf = np.empty(t["nbytes"], dtype=np.uint8)
    out = memoryview(buf)
    off = 0
    leaf_crc = 0
    for c, raw in zip(t["chunks"], chunk_bytes_list):
        if verify and zlib.crc32(raw) != c["crc32"]:
            raise ChecksumError(
                f"crc mismatch for chunk {c['hash']} of {t['path']}")
        out[off:off + c["nbytes"]] = raw
        leaf_crc = zlib.crc32(raw, leaf_crc)
        off += c["nbytes"]
    if off != t["nbytes"]:
        raise ChecksumError(f"chunk bytes {off}/{t['nbytes']} for {t['path']}")
    if verify and t.get("crc32") is not None and leaf_crc != t["crc32"]:
        raise ChecksumError(f"leaf crc mismatch for {t['path']}")
    return buf.view(np.dtype(t["dtype"])).reshape(t["shape"])


def read_chunked_leaves(header: dict, fetch_chunk, *,
                        paths: Optional[list[str]] = None,
                        verify: bool = True):
    """Materialize leaves of a v3 index given ``fetch_chunk(chunk_entry) ->
    bytes`` (the store/engine resolves a hash to whichever tier holds it).
    Returns ({path: np.ndarray}, meta) like ``read_shard_leaves``."""
    index = {t["path"]: t for t in header["tensors"]}
    want = list(index) if paths is None else paths
    missing = [p for p in want if p not in index]
    if missing:
        raise KeyError(f"leaves not in chunk index: {missing}")
    out = {}
    for p in want:
        t = index[p]
        out[p] = assemble_leaf(t, [fetch_chunk(c) for c in t["chunks"]],
                               verify=verify)
    return out, header["meta"]


# ---------------------------------------------------------------------------
# v1: legacy writer (kept verbatim so read-compat fixtures and the benchmark
# baseline exercise the true seed byte layout)
# ---------------------------------------------------------------------------

def write_shard_bytes(records: list[tuple[str, np.ndarray]],
                      meta: Optional[dict] = None) -> bytes:
    tensors = []
    blobs = []
    offset = 0
    for name, arr in records:
        arr = np.asarray(arr)
        shape = list(arr.shape)          # before ascontiguousarray (it is >=1-d)
        arr = np.ascontiguousarray(arr)
        raw = arr.tobytes()
        tensors.append({
            "path": name,
            "dtype": str(arr.dtype),
            "shape": shape,
            "offset": offset,
            "nbytes": len(raw),
            "crc32": zlib.crc32(raw),
        })
        blobs.append(raw)
        offset += len(raw)
    header = json.dumps({"tensors": tensors, "meta": meta or {}}).encode()
    buf = io.BytesIO()
    buf.write(MAGIC)
    buf.write(struct.pack("<I", len(header)))
    buf.write(header)
    for raw in blobs:
        buf.write(raw)
    return buf.getvalue()


# ---------------------------------------------------------------------------
# readers: ranged (header + per-leaf) and whole-buffer, both formats
# ---------------------------------------------------------------------------

ReadAt = Callable[[int, int], bytes]     # (offset, nbytes) -> bytes

# One tail read this large usually captures trailer + footer together, so a
# v2 header costs ONE ranged read instead of three (magic, trailer, footer).
# Per-op latency dominates header fetches on the shared tier and the peer
# fabric, so the restore planner's per-shard cost drops ~3x with this hint.
HEADER_TAIL_HINT = 4096


def read_shard_header(read_at: ReadAt, size: int, *,
                      tail_hint: int = HEADER_TAIL_HINT) -> dict:
    """Parse the tensor index of a shard using only ranged reads.

    ``read_at(offset, nbytes)`` is any positioned-read primitive (pread/mmap
    slice/HTTP range).  Returns the header dict with every tensor ``offset``
    normalized to an ABSOLUTE file offset regardless of format, so callers can
    ranged-read leaves uniformly.

    v2 fast path: one ``tail_hint``-byte read from the end of the file grabs
    the trailer and (almost always) the whole footer; only a footer larger
    than the hint costs a second read.  v1 keeps the magic-first probe.
    """
    if size >= 8 + TRAILER_LEN:
        tail_n = min(size, max(tail_hint, TRAILER_LEN))
        tail = bytes(read_at(size - tail_n, tail_n))
        if tail[-8:] in (MAGIC2, MAGIC3):
            try:
                (flen,) = struct.unpack("<Q", tail[-TRAILER_LEN:-8])
                if flen > size - 8 - TRAILER_LEN:
                    raise ValueError("bad checkpoint footer length")
                if flen + TRAILER_LEN <= tail_n:
                    raw = tail[tail_n - TRAILER_LEN - flen:
                               tail_n - TRAILER_LEN]
                else:
                    raw = bytes(read_at(size - TRAILER_LEN - flen, flen))
                return json.loads(raw.decode())
            except (ValueError, UnicodeDecodeError, struct.error):
                # a v1 shard whose last payload bytes collide with MAGIC2/3
                # must still parse — the leading magic below disambiguates
                # (and a genuinely damaged v2/v3 still errors there)
                pass
    magic = bytes(read_at(0, 8))
    if magic in (MAGIC2, MAGIC3):
        if size < 8 + TRAILER_LEN:
            raise ValueError("truncated checkpoint shard")
        raise ValueError("bad checkpoint shard trailer")
    if magic == MAGIC:
        (hlen,) = struct.unpack("<I", bytes(read_at(8, 4)))
        header = json.loads(bytes(read_at(12, hlen)).decode())
        base = 12 + hlen                 # v1 offsets are data-relative
        for t in header["tensors"]:
            t["offset"] += base
        header["format"] = 1
        return header
    raise ValueError("bad checkpoint shard magic")


def leaf_from_bytes(t: dict, raw, *, verify: bool = True) -> np.ndarray:
    """Materialize one tensor from its header entry + raw payload bytes."""
    if verify and zlib.crc32(raw) != t["crc32"]:
        raise ChecksumError(f"crc mismatch for tensor {t['path']}")
    return np.frombuffer(raw, dtype=np.dtype(t["dtype"])).reshape(t["shape"])


def select_leaves(header: dict, paths: Optional[list[str]]) -> list[dict]:
    """Header entries for the requested ``paths`` (all when ``None``), sorted
    by file offset.  Raises ``KeyError`` on a leaf the shard doesn't hold —
    a stale replica must fall back like any damaged one."""
    want = header["tensors"]
    if paths is None:
        return sorted(want, key=lambda t: t["offset"])
    index = {t["path"]: t for t in want}
    missing = [p for p in paths if p not in index]
    if missing:
        raise KeyError(f"leaves not in shard: {missing}")
    return sorted((index[p] for p in set(paths)), key=lambda t: t["offset"])


def coalesce_runs(want: list[dict], *,
                  max_run_bytes: Optional[int] = None) -> list[list[dict]]:
    """Group offset-sorted leaf entries into contiguous runs, each servable
    by ONE ranged read.  ``max_run_bytes`` additionally splits a run at leaf
    boundaries once it grows past the cap — how the parallel restore engine
    turns one large shard into several same-sized range tasks (a single
    oversized leaf still stays whole: CRC verification needs its full bytes).
    """
    runs: list[list[dict]] = []
    cur: list[dict] = []
    cur_bytes = 0
    for t in want:
        contiguous = cur and t["offset"] == cur[-1]["offset"] + cur[-1]["nbytes"]
        fits = max_run_bytes is None or not cur or cur_bytes + t["nbytes"] <= max_run_bytes
        if not (contiguous and fits):
            if cur:
                runs.append(cur)
            cur, cur_bytes = [], 0
        cur.append(t)
        cur_bytes += t["nbytes"]
    if cur:
        runs.append(cur)
    return runs


def read_run(read_at: ReadAt, run: list[dict], out: dict, *,
             verify: bool = True) -> int:
    """Fetch one coalesced run with a single ranged read and materialize its
    leaves into ``out`` (zero-copy: leaves alias the run buffer, read-only).
    Returns the number of bytes read."""
    start = run[0]["offset"]
    nbytes = run[-1]["offset"] + run[-1]["nbytes"] - start
    buf = memoryview(read_at(start, nbytes))
    for t in run:
        raw = buf[t["offset"] - start : t["offset"] - start + t["nbytes"]]
        out[t["path"]] = leaf_from_bytes(t, raw, verify=verify)
    return nbytes


def read_shard_leaves(read_at: ReadAt, size: int,
                      paths: Optional[list[str]] = None, *,
                      verify: bool = True,
                      header: Optional[dict] = None):
    """Ranged read of selected leaves.  Returns ({path: np.ndarray}, meta).

    ``paths=None`` reads every leaf.  Requested leaves that are adjacent in
    the file are fetched with one coalesced read.  Works on both formats
    (``read_shard_header`` normalizes offsets).
    """
    header = header or read_shard_header(read_at, size)
    if header.get("format") == 3:
        # a v3 index has no payload to range-read; its chunks resolve through
        # the store's chunk plane (read_chunked_leaves / restore_chunked)
        raise ValueError("v3 chunk index holds no payload; use the chunk plane")
    want = select_leaves(header, paths)
    out: dict = {}
    for run in coalesce_runs(want):
        read_run(read_at, run, out, verify=verify)
    return out, header["meta"]


def read_shard_bytes(data: bytes, *, verify: bool = True):
    """Whole-buffer parse (v1 or v2).  Returns ({path: np.ndarray}, meta)."""
    def read_at(off: int, n: int) -> bytes:
        if off + n > len(data):
            raise ValueError("truncated checkpoint shard")
        return data[off : off + n]
    return read_shard_leaves(read_at, len(data), None, verify=verify)


# ---------------------------------------------------------------------------
# pytree + file conveniences
# ---------------------------------------------------------------------------

def tree_to_records(tree) -> list[tuple[str, np.ndarray]]:
    out = []
    for name, leaf in flatten_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        out.append((name, arr))
    return out


def write_shard(path: Path, records, meta=None) -> dict:
    """Stream a v2 shard to ``path`` atomically (tmp + rename)."""
    path = Path(path)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "wb") as fp:
        footer = write_shard_stream(fp, records, meta)
        nbytes = fp.tell()
    tmp.rename(path)
    return {"nbytes": nbytes, "tensors": footer["tensors"]}


def read_shard(path: Path, *, verify: bool = True):
    return read_shard_bytes(Path(path).read_bytes(), verify=verify)


def restore_tree(template, named: dict[str, np.ndarray]):
    """Rebuild a pytree shaped like ``template`` from {path: array}."""
    return unflatten_like(template, named)
