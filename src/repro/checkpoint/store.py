"""Tiered checkpoint storage with k-replication.

Models the paper's Fig.-2 filesystem hierarchy: a container-image-cache-like
node-local tier (``ram`` / ``local``) vs a shared parallel filesystem
(``shared``).  Tiers carry simulated bandwidth/latency so benchmarks can
reproduce the paper's startup-time-vs-ranks effect on a single box; simulation
is off (factor 0) everywhere except the benchmarks.

Replication: a shard written at replication k lands in k distinct "node"
directories of the tier; reads fall back across replicas on checksum failure
(the paper: "redundantly storing checkpoint images").
"""
from __future__ import annotations

import dataclasses
import random
import shutil
import time
from pathlib import Path
from typing import Optional

from repro.checkpoint import serialization as SER


@dataclasses.dataclass
class TierSpec:
    name: str
    bandwidth_gbps: float      # simulated sequential bandwidth
    latency_s: float           # simulated per-op latency
    nodes: int = 1             # distinct failure domains within the tier


DEFAULT_TIERS = {
    "ram": TierSpec("ram", 40.0, 0.00005, nodes=1),
    "local": TierSpec("local", 3.0, 0.0005, nodes=1),
    "shared": TierSpec("shared", 1.0, 0.02, nodes=8),
}


class TieredStore:
    def __init__(self, root: Path, tiers: Optional[dict] = None,
                 sim_io_factor: float = 0.0):
        self.root = Path(root)
        self.tiers = tiers or dict(DEFAULT_TIERS)
        self.sim_io_factor = sim_io_factor

    # ------------------------------------------------------------------
    def _node_dirs(self, tier: str) -> list[Path]:
        spec = self.tiers[tier]
        return [self.root / tier / f"node{i}" for i in range(spec.nodes)]

    def _simulate(self, tier: str, nbytes: int) -> None:
        if not self.sim_io_factor:
            return
        spec = self.tiers[tier]
        t = spec.latency_s + nbytes / (spec.bandwidth_gbps * 1e9)
        time.sleep(t * self.sim_io_factor)

    # ------------------------------------------------------------------
    def put(self, tier: str, rel: str, data: bytes, *, replicas: int = 1) -> list[str]:
        nodes = self._node_dirs(tier)
        replicas = min(replicas, len(nodes))
        chosen = nodes[:replicas] if replicas == len(nodes) else random.sample(nodes, replicas)
        written = []
        for nd in chosen:
            p = nd / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            tmp = p.with_suffix(p.suffix + ".tmp")
            tmp.write_bytes(data)
            tmp.rename(p)
            self._simulate(tier, len(data))
            written.append(str(p.relative_to(self.root)))
        return written

    def get(self, tier: str, rel: str) -> bytes:
        """Read with replica fallback; raises FileNotFoundError if no replica."""
        last_err: Exception | None = None
        for nd in self._node_dirs(tier):
            p = nd / rel
            if not p.exists():
                continue
            data = p.read_bytes()
            self._simulate(tier, len(data))
            return data
        raise FileNotFoundError(f"{tier}:{rel}") from last_err

    def get_verified(self, tier: str, rel: str):
        """Read + parse a shard, falling back across replicas on crc failure."""
        errs = []
        for nd in self._node_dirs(tier):
            p = nd / rel
            if not p.exists():
                continue
            try:
                data = p.read_bytes()
                self._simulate(tier, len(data))
                return SER.read_shard_bytes(data, verify=True)
            except SER.ChecksumError as e:  # corrupted replica: try the next
                errs.append((str(p), str(e)))
                continue
        raise SER.ChecksumError(f"no intact replica for {tier}:{rel}: {errs}")

    def exists(self, tier: str, rel: str) -> bool:
        return any((nd / rel).exists() for nd in self._node_dirs(tier))

    def delete_prefix(self, tier: str, prefix: str) -> None:
        for nd in self._node_dirs(tier):
            p = nd / prefix
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)

    def delete_file(self, tier: str, rel: str) -> None:
        for nd in self._node_dirs(tier):
            p = nd / rel
            if p.exists():
                p.unlink()

    def list_prefix(self, tier: str, prefix: str) -> set[str]:
        out: set[str] = set()
        for nd in self._node_dirs(tier):
            p = nd / prefix
            if p.is_dir():
                for f in p.rglob("*"):
                    if f.is_file() and not f.name.endswith(".tmp"):
                        out.add(str(f.relative_to(nd)))
        return out
