"""Tiered checkpoint storage with k-replication.

Models the paper's Fig.-2 filesystem hierarchy: a container-image-cache-like
node-local tier (``ram`` / ``local``) vs a shared parallel filesystem
(``shared``).  Tiers carry simulated bandwidth/latency so benchmarks can
reproduce the paper's startup-time-vs-ranks effect on a single box; simulation
is off (factor 0) everywhere except the benchmarks.

Replication: a shard written at replication k lands in k distinct "node"
directories of the tier; reads fall back across replicas on checksum failure
or I/O error (the paper: "redundantly storing checkpoint images").  The
payload is serialized ONCE — the primary replica is written from the source
bytes/stream and the remaining k-1 replicas are fanned out with
``shutil.copyfile`` (kernel ``sendfile``/``copy_file_range`` on Linux), so
replica count multiplies disk traffic but not CPU serialization work.

Ranged access: ``get_range`` / ``read_shard_leaves`` serve sub-file reads via
positional ``pread``-style access, which is what lets the manager's
incremental restore pull single leaves out of multi-GB shards.  The ``_pread``
choke point keeps one open fd per replica file (``os.pread`` is positional and
thread-safe), so a task's coalesced reads — and the header/trailer/footer
triplet of every plan — reuse a descriptor instead of re-opening the shard
per range; every store-side mutation (rename-into-place, delete) invalidates
the cached descriptor so a replaced file is never read through a stale fd.

Peer tiers: ``add_peer`` registers another node's local root as an
addressable read-only tier (``peer:<node>``) carrying the ``peer``
``TierSpec`` — its own concurrency slots and simulated inter-node latency —
which is what lets the restore engine source ranges from a warm peer's
promoted cache instead of the shared parallel filesystem.

Chunk plane (v3 delta checkpoints): content-addressed chunk files live under
``<prefix>/chunks/<hash-prefix>/<hash>`` — one file per unique chunk,
whatever step(s) reference it.  ``put_chunk`` is the dedup write (a chunk
already present is never re-written), ``chunk_digests`` lists a tier's
inventory, and ``chunk_refcounts`` folds manifests into per-chunk reference
counts so GC reaps exactly the chunks no live manifest references (the CRIU
dirty-page idea applied to the store: a delta step writes only changed
chunks, and an unchanged chunk's single copy stays pinned by its refcount).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import queue
import random
import shutil
import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import BinaryIO, Callable, Iterable, Optional

from repro.checkpoint import io_backend as IOB
from repro.checkpoint import serialization as SER
from repro.utils.atomic import atomic_write_bytes

# tiers whose backing store is a real (cold/shared parallel) filesystem —
# the ones worth reading O_DIRECT when the kernel supports it there.  The
# hot node-local tiers (ram/local) WANT the page cache; bypassing it would
# only add alignment waste.
DIRECT_IO_TIERS = ("shared",)


class _FanoutSink:
    """Write-once tee: chunks handed to ``write`` are streamed to every
    replica file by a dedicated kernel-writer thread each.

    Chunks are enqueued by reference (zero-copy for ``memoryview``s whose
    backing buffers outlive the ``put_stream`` call, which holds the source
    arrays).  Bounded queues give backpressure so a slow replica cannot make
    the producer buffer the whole shard.
    """

    _CLOSE = object()

    def __init__(self, paths: list[Path], queue_depth: int = 4):
        self.nbytes = 0
        self._queues = [queue.Queue(maxsize=queue_depth) for _ in paths]
        self._errs: list[BaseException] = []
        self._threads = [
            threading.Thread(target=self._drain, args=(p, q), daemon=True,
                             name=f"ckpt-fanout-{i}")
            for i, (p, q) in enumerate(zip(paths, self._queues))
        ]
        for t in self._threads:
            t.start()

    def _drain(self, path: Path, q: queue.Queue) -> None:
        try:
            with open(path, "wb") as fp:
                while True:
                    chunk = q.get()
                    if chunk is self._CLOSE:
                        return
                    fp.write(chunk)
        except BaseException as e:  # noqa: BLE001 — re-raised on the producer
            self._errs.append(e)
            while q.get() is not self._CLOSE:   # unblock the producer
                pass

    def writable(self) -> bool:
        return True

    def write(self, chunk) -> int:
        if self._errs:
            raise self._errs[0]
        for q in self._queues:
            q.put(chunk)
        n = len(chunk) if not isinstance(chunk, memoryview) else chunk.nbytes
        self.nbytes += n
        return n

    def _join(self) -> None:
        for q in self._queues:
            q.put(self._CLOSE)
        for t in self._threads:
            t.join()

    def finish(self) -> None:
        self._join()
        if self._errs:
            raise self._errs[0]

    def abort(self) -> None:
        self._join()


class _FdEntry:
    """One cached read descriptor: refcounted so LRU eviction / invalidation
    never closes an fd another thread is mid-``pread`` on."""

    __slots__ = ("fd", "refs", "dead")

    def __init__(self, fd: int):
        self.fd = fd
        self.refs = 0
        self.dead = False


@dataclasses.dataclass
class TierSpec:
    name: str
    bandwidth_gbps: float      # simulated sequential bandwidth
    latency_s: float           # simulated per-op latency
    nodes: int = 1             # distinct failure domains within the tier
    concurrency: int = 0       # max in-flight restore reads (0 = unbounded)


DEFAULT_TIERS = {
    "ram": TierSpec("ram", 40.0, 0.00005, nodes=1, concurrency=16),
    "local": TierSpec("local", 3.0, 0.0005, nodes=1, concurrency=4),
    "shared": TierSpec("shared", 1.0, 0.02, nodes=8, concurrency=8),
    # template for peer tiers (add_peer): a warm peer's node-local cache read
    # over the interconnect — slower than our own local tier, but far lower
    # per-op latency than the contended shared parallel FS, and each peer
    # brings its OWN concurrency slots (bandwidth aggregates across k peers)
    "peer": TierSpec("peer", 2.5, 0.002, nodes=1, concurrency=4),
}

PEER_TIER_PREFIX = "peer:"


def is_peer_tier(tier: str) -> bool:
    return tier.startswith(PEER_TIER_PREFIX)


# -- content-addressed chunk plane (v3 delta checkpoints) -------------------

CHUNKS_DIRNAME = "chunks"


def chunk_rel(prefix: str, digest: str) -> str:
    """Store-relative path of one content-addressed chunk.  The two-hex-char
    fan-out directory keeps any single directory from holding the whole
    chunk population (the classic git-objects layout)."""
    return f"{prefix}/{CHUNKS_DIRNAME}/{digest[:2]}/{digest}"


def chunk_digest_of(rel: str) -> Optional[str]:
    """Inverse of ``chunk_rel``: the digest if ``rel`` is a chunk file path,
    else None."""
    parts = Path(rel).parts
    if len(parts) >= 3 and parts[-3] == CHUNKS_DIRNAME:
        return parts[-1]
    return None


def manifest_chunk_hashes(manifest: dict) -> set[str]:
    """Every chunk digest a manifest's leaves reference (empty for v1/v2
    file-based manifests)."""
    return {c["hash"] for e in manifest.get("leaves", ())
            for c in (e.get("chunks") or ())}


def chunk_refcounts(manifests: Iterable[dict]) -> dict[str, int]:
    """Fold manifests into per-chunk reference counts — the GC input: a
    chunk is live while its count is nonzero, reapable at exactly zero.
    Counted per MANIFEST (a chunk shared by two leaves of one step still
    counts once per step), so the count is 'how many committed steps pin
    this chunk'."""
    counts: dict[str, int] = {}
    for man in manifests:
        for h in manifest_chunk_hashes(man):
            counts[h] = counts.get(h, 0) + 1
    return counts

# tiers that live on a cluster node rather than the shared parallel FS —
# the set every per-node mount point must cover
NODE_LOCAL_TIERS = ("ram", "local")


def node_local_tier_roots(local_root) -> dict:
    """The ``tier_roots`` mapping that mounts every node-local tier under one
    per-node directory (the single definition train.py, the placement test
    job, and the benchmarks all share)."""
    return {t: Path(local_root) for t in NODE_LOCAL_TIERS}


class TieredStore:
    def __init__(self, root: Path, tiers: Optional[dict] = None,
                 sim_io_factor: float = 0.0,
                 rng: Optional[random.Random] = None,
                 seed: Optional[int] = None,
                 tier_roots: Optional[dict] = None):
        self.root = Path(root)
        self.tiers = tiers or dict(DEFAULT_TIERS)
        # tier_roots: per-tier root override — the multi-node cluster model:
        # every simulated cluster node shares the same ``shared`` tier root but
        # mounts ITS OWN ``local``/``ram`` roots (sched/slurmsim.py NodeSpec),
        # so a shared->local promotion warms exactly one node's cache.
        self.tier_roots = {t: Path(p) for t, p in (tier_roots or {}).items()}
        self.sim_io_factor = sim_io_factor
        # Replica placement is randomized; an injectable RNG (or just a seed)
        # makes placement deterministic for tests/CI.  Never the module-level
        # ``random`` — a seeded test elsewhere must not change our placement.
        self._rng = rng if rng is not None else random.Random(seed)
        self._sems: dict[str, threading.BoundedSemaphore] = {}
        self._sems_lock = threading.Lock()
        # peer tiers: tier name -> concrete replica dirs on the peer's root
        self._peer_dirs: dict[str, list[Path]] = {}
        # fd cache for positional reads (see _pread); bounded, refcounted
        self._fds: OrderedDict[Path, _FdEntry] = OrderedDict()
        self._fd_lock = threading.Lock()
        self._fd_cap = 64
        # batched-read plane: per-tier O_DIRECT alignment (None = buffered),
        # probed lazily on the first batch against that tier.  direct_io:
        # "auto" probes DIRECT_IO_TIERS, False disables, True probes every
        # tier (benchmarks A/B the modes explicitly).
        self.direct_io: object = "auto"
        self._direct_align: dict[str, Optional[int]] = {}
        self._direct_lock = threading.Lock()

    # ------------------------------------------------------------------
    def add_peer(self, name: str, root, *, via_tier: str = "local") -> str:
        """Register (or re-point) another node's local root as a read-only
        tier ``peer:<name>``.  ``via_tier`` is the tier the peer's promoted
        cache lives in under its root (the peer's ``promote_tier``).  The new
        tier carries the ``peer`` TierSpec — its own concurrency slots and
        simulated inter-node latency — so peer reads are costed and bounded
        independently of every other source."""
        tier = f"{PEER_TIER_PREFIX}{name}"
        template = self.tiers.get("peer", DEFAULT_TIERS["peer"])
        self.tiers[tier] = dataclasses.replace(template, name=tier)
        n = self.tiers[via_tier].nodes if via_tier in self.tiers else 1
        self._peer_dirs[tier] = [
            Path(root) / via_tier / f"node{i}" for i in range(n)]
        return tier

    def peer_tiers(self) -> list[str]:
        return sorted(self._peer_dirs)

    # ------------------------------------------------------------------
    def _node_dirs(self, tier: str) -> list[Path]:
        if tier in self._peer_dirs:
            return self._peer_dirs[tier]
        spec = self.tiers[tier]
        root = self.tier_roots.get(tier, self.root)
        return [root / tier / f"node{i}" for i in range(spec.nodes)]

    def _rel_of(self, p: Path) -> str:
        """Store-relative name of a replica file, whichever root it nests
        under (the main root or a tier_roots override)."""
        for root in (self.root, *self.tier_roots.values()):
            try:
                return str(p.relative_to(root))
            except ValueError:
                continue
        return str(p)

    def _simulate(self, tier: str, nbytes: int) -> None:
        if not self.sim_io_factor:
            return
        spec = self.tiers[tier]
        t = spec.latency_s + nbytes / (spec.bandwidth_gbps * 1e9)
        time.sleep(t * self.sim_io_factor)

    def _choose_nodes(self, tier: str, replicas: int) -> list[Path]:
        nodes = self._node_dirs(tier)
        replicas = min(replicas, len(nodes))
        return nodes[:replicas] if replicas == len(nodes) else self._rng.sample(nodes, replicas)

    def tier_slots(self, tier: str):
        """Context manager bounding in-flight reads against ``tier`` to the
        spec's ``concurrency`` (the restore engine acquires one slot per
        ranged read; unbounded tiers return a no-op)."""
        spec = self.tiers[tier]
        if not spec.concurrency:
            return contextlib.nullcontext()
        with self._sems_lock:
            sem = self._sems.get(tier)
            if sem is None:
                sem = self._sems[tier] = threading.BoundedSemaphore(spec.concurrency)
        return sem

    def _replicate(self, tier: str, primary: Path, rel: str,
                   others: list[Path], written: list[str]) -> None:
        """Fan the primary replica out with an OS-level copy (no re-serialize)."""
        nbytes = primary.stat().st_size
        for nd in others:
            p = nd / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            tmp = p.with_suffix(p.suffix + ".tmp")
            shutil.copyfile(primary, tmp)   # sendfile/copy_file_range path
            tmp.rename(p)
            self._fd_invalidate(p)
            self._simulate(tier, nbytes)
            written.append(self._rel_of(p))

    # ------------------------------------------------------------------
    def put(self, tier: str, rel: str, data: bytes, *, replicas: int = 1) -> list[str]:
        """Write ``data`` once, then copy-fan-out to the other replicas."""
        chosen = self._choose_nodes(tier, replicas)
        primary = chosen[0] / rel
        # unique-tmp atomic publish (utils.atomic): PROMOTED markers and
        # in-flight intent markers ride this path, and two writers racing
        # one marker must never interleave on a fixed <name>.tmp
        atomic_write_bytes(primary, data)
        self._fd_invalidate(primary)
        self._simulate(tier, len(data))
        written = [self._rel_of(primary)]
        self._replicate(tier, primary, rel, chosen[1:], written)
        return written

    def put_stream(self, tier: str, rel: str,
                   write_fn: Callable[[BinaryIO], object], *,
                   replicas: int = 1) -> list[str]:
        """Stream a payload once into all k replica files.

        ``write_fn(sink)`` is invoked exactly once — typically
        ``SER.write_shard_stream`` — so the payload is serialized a single
        time and never exists as a whole in memory.  Each chunk the writer
        emits is teed to one kernel-writer thread per replica; since both
        ``file.write`` and ``zlib.crc32`` release the GIL, the producer's CRC
        folding of chunk i+1 overlaps the disk writes of chunk i on every
        replica (the pipelined analogue of write-once + ``copyfile`` fan-out,
        minus the read-back).  Atomic per replica (tmp + rename-all at the
        end, so no torn replica is ever visible).
        """
        chosen = self._choose_nodes(tier, replicas)
        tmps, finals = [], []
        for nd in chosen:
            p = nd / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            tmps.append(p.with_suffix(p.suffix + ".tmp"))
            finals.append(p)
        sink = _FanoutSink(tmps)
        try:
            write_fn(sink)
            sink.finish()
        except BaseException:
            sink.abort()
            for t in tmps:
                t.unlink(missing_ok=True)
            raise
        for tmp, final in zip(tmps, finals):
            tmp.rename(final)
            self._fd_invalidate(final)
            self._simulate(tier, sink.nbytes)
        return [self._rel_of(p) for p in finals]

    # -- chunk plane ---------------------------------------------------
    def put_chunk(self, tier: str, prefix: str, digest: str, data, *,
                  replicas: int = 1, force: bool = False) -> bool:
        """Dedup write into the chunk plane: a chunk whose content-addressed
        file already exists on ``tier`` is NOT re-written (that is the whole
        point — identical chunks across steps/leaves cost one write ever).
        Returns True iff bytes were actually written.  ``data`` may be any
        buffer (the delta writer hands zero-copy memoryviews).

        ``force=True`` writes even when the file exists (idempotent: same
        hash, same bytes, atomic tmp+rename).  The delta saver uses it for
        chunks NOT pinned by the parent manifest: trusting bare existence
        there would race a concurrent gc reaping that very file after its
        last committed reference retired (content that oscillates back)."""
        rel = chunk_rel(prefix, digest)
        if not force and self.exists(tier, rel):
            return False
        self.put(tier, rel, data, replicas=replicas)
        return True

    def get_chunk(self, tier: str, prefix: str, digest: str) -> bytes:
        return self.get(tier, chunk_rel(prefix, digest))

    def chunk_digests(self, tier: str, prefix: str) -> set[str]:
        """Every chunk digest present on ``tier`` (union across replicas)."""
        out = set()
        for rel in self.list_prefix(tier, f"{prefix}/{CHUNKS_DIRNAME}"):
            d = chunk_digest_of(rel)
            if d is not None:
                out.add(d)
        return out

    # -- fd cache ------------------------------------------------------
    def _fd_acquire(self, path: Path) -> "_FdEntry":
        with self._fd_lock:
            ent = self._fds.get(path)
            if ent is not None:
                ent.refs += 1
                self._fds.move_to_end(path)
                return ent
        # open outside the lock: a slow/erroring open must not serialize
        # every other tier's reads behind it
        fd = os.open(path, os.O_RDONLY)
        ent = _FdEntry(fd)
        ent.refs = 1
        with self._fd_lock:
            if path in self._fds:           # raced: use ours once, then close
                ent.dead = True
                return ent
            # TOCTOU guard: the file may have been renamed-over or deleted
            # between the open above and here — its _fd_invalidate found
            # nothing to kill, so caching now would pin the dead inode.
            # Checked under the lock: any mutation AFTER this stat must wait
            # for the lock and will find (and kill) our entry.
            try:
                live = os.stat(path).st_ino == os.fstat(ent.fd).st_ino
            except OSError:
                live = False
            if not live:
                ent.dead = True             # replaced mid-open: use once only
                return ent
            self._fds[path] = ent
            while len(self._fds) > self._fd_cap:
                for p, e in self._fds.items():       # LRU with refs==0 only
                    if e.refs == 0:
                        e.dead = True
                        os.close(e.fd)
                        del self._fds[p]
                        break
                else:
                    break
            return ent

    def _fd_release(self, path: Path, ent: "_FdEntry") -> None:
        with self._fd_lock:
            ent.refs -= 1
            if ent.dead and ent.refs == 0:
                os.close(ent.fd)
                if self._fds.get(path) is ent:
                    del self._fds[path]

    def _fd_invalidate(self, path: Path) -> None:
        """Drop the cached descriptor for ``path`` — called by every mutation
        that replaces or removes a file, so no read ever goes through a stale
        fd to a renamed-over or deleted inode."""
        with self._fd_lock:
            ent = self._fds.pop(Path(path), None)
            if ent is not None:
                ent.dead = True
                if ent.refs == 0:
                    os.close(ent.fd)

    def _fd_invalidate_under(self, prefix: Path) -> None:
        prefix = Path(prefix)
        with self._fd_lock:
            doomed = [p for p in self._fds
                      if p == prefix or prefix in p.parents]
        for p in doomed:
            self._fd_invalidate(p)

    def close(self) -> None:
        """Close every cached read descriptor (reads after this just re-open).

        Idempotent and shutdown-safe: callable any number of times, from
        ``__del__``, and during interpreter teardown — when module globals
        (``os``) may already be None — without raising.  ``_OS_CLOSE`` is
        bound at class-definition time so the close syscall survives the
        ``os`` module being torn down first; a descriptor that fails to
        close (EBADF from a racing release) is skipped, not fatal."""
        lock = getattr(self, "_fd_lock", None)
        if lock is None:            # __init__ never completed
            return
        with lock:
            ents, self._fds = list(self._fds.values()), OrderedDict()
        for ent in ents:
            ent.dead = True
            if ent.refs == 0:
                try:
                    self._OS_CLOSE(ent.fd)
                except (OSError, TypeError):
                    pass            # already closed / teardown half-done

    _OS_CLOSE = staticmethod(os.close)

    def __del__(self):  # noqa: D105 — best-effort fd cleanup
        try:
            self.close()
        except Exception:  # noqa: BLE001 — interpreter may be tearing down
            pass

    # ------------------------------------------------------------------
    def _pread(self, path: Path, offset: int, nbytes: int) -> bytes:
        """Positional read — the single choke point for all ranged I/O (tests
        wrap/override it to count bytes actually fetched).  Reuses one cached
        fd per replica file across a task's coalesced reads (``os.pread`` is
        positional, so concurrent range tasks share the descriptor safely)."""
        if not hasattr(os, "pread"):            # non-POSIX fallback
            with open(path, "rb") as fp:
                fp.seek(offset)
                return fp.read(nbytes)
        path = Path(path)
        ent = self._fd_acquire(path)
        try:
            return os.pread(ent.fd, nbytes, offset)
        finally:
            self._fd_release(path, ent)

    def replica_paths(self, tier: str, rel: str) -> list[Path]:
        """Existing replica files for ``rel``, primary-placement order.  The
        restore engine plans against the first parseable one and falls back
        across the rest per ranged read."""
        return [nd / rel for nd in self._node_dirs(tier) if (nd / rel).exists()]

    def pread(self, tier: str, path: Path, offset: int, nbytes: int) -> bytes:
        """Public positional read against a known replica file, with the
        tier's simulated I/O cost applied.  Raises ``OSError`` on a short
        read so a truncated replica triggers fallback, never silent loss."""
        data = self._pread(path, offset, nbytes)
        if len(data) != nbytes:
            raise OSError(f"short read {len(data)}/{nbytes} in {path}")
        self._simulate(tier, nbytes)
        return data

    # -- batched submission plane --------------------------------------
    def _simulate_batch(self, tier: str, nbytes: int) -> None:
        """Simulated cost of ONE batched submission: a single per-op latency
        plus the bandwidth term over the whole payload.  This is the honest
        model of what batching buys — the queue-depth latency is paid once
        per submission instead of once per range — and it is exactly why the
        ``restore_engine_io`` bench shows batched >= per-range on the same
        plan under simulation."""
        self._simulate(tier, nbytes)

    def _pread_hooked(self) -> bool:
        """True when ``_pread`` is wrapped or overridden (fault injectors,
        byte-counting test stores).  The batched backend then degrades to
        per-range ``self._pread`` calls so every instrumented byte is still
        observed — ``_pread`` stays the single choke point for ranged I/O
        whichever submission path is in front of it."""
        return ("_pread" in self.__dict__
                or type(self)._pread is not TieredStore._pread)

    def _direct_alignment(self, tier: str, sample_path: Path) -> Optional[int]:
        """O_DIRECT alignment for ``tier``, probed once per tier against the
        directory of an actual replica file (the probe is a filesystem
        property; tier roots decide the filesystem)."""
        mode = self.direct_io
        if not mode or (mode == "auto" and tier not in DIRECT_IO_TIERS):
            return None
        with self._direct_lock:
            if tier in self._direct_align:
                return self._direct_align[tier]
        align = IOB.probe_direct_io(Path(sample_path).parent)
        with self._direct_lock:
            self._direct_align[tier] = align
        return align

    def pread_batch(self, tier: str, requests) -> list:
        """Drain one batch of ``(path, offset, nbytes)`` reads against known
        replica files of ``tier`` in a single submission (``os.preadv``
        vectored reads, O_DIRECT-aligned where the tier's filesystem allows
        it).  ``nbytes=None`` reads the whole file (the chunk plane's case:
        a compressed chunk's on-disk size differs from its raw size).

        Returns a list aligned with ``requests``: ``bytes`` on success, the
        ``Exception`` for a failed/short range (not raised — the caller owns
        per-range fallback down its source chain).  Like ``pread``, the
        caller is expected to hold the tier's concurrency slot; unlike
        ``pread``, the simulated I/O cost is applied ONCE for the batch.
        """
        reqs = []
        results: list = [None] * len(requests := list(requests))
        for i, (path, offset, nbytes) in enumerate(requests):
            if nbytes is None:
                try:
                    nbytes = os.stat(path).st_size - offset
                except OSError as e:
                    results[i] = e
                    continue
            reqs.append((i, Path(path), offset, nbytes))
        if self._pread_hooked():
            # instrumented store: route every range through the choke point
            for i, path, offset, nbytes in reqs:
                try:
                    data = self._pread(path, offset, nbytes)
                    if len(data) != nbytes:
                        raise OSError(
                            f"short read {len(data)}/{nbytes} in {path}")
                    results[i] = data
                except OSError as e:
                    results[i] = e
        elif reqs:
            align = self._direct_alignment(tier, reqs[0][1])

            def _open(p: Path):
                ent = self._fd_acquire(p)
                return (ent.fd, ent)

            def _close(p: Path, handle) -> None:
                self._fd_release(p, handle[1])

            got = IOB.read_ranges([(p, off, n) for _, p, off, n in reqs],
                                  direct_align=align,
                                  open_fd=None if align else _open,
                                  close_fd=None if align else _close)
            for (i, path, offset, nbytes), data in zip(reqs, got):
                if isinstance(data, Exception):
                    results[i] = data
                elif len(data) != nbytes:
                    results[i] = OSError(
                        f"short read {len(data)}/{nbytes} in {path}")
                else:
                    results[i] = data
        ok_bytes = sum(len(r) for r in results if isinstance(r, bytes))
        self._simulate_batch(tier, ok_bytes)
        return results

    def get_ranges(self, tier: str, requests) -> list[bytes]:
        """Batched ranged read by store-relative name: ``requests`` is a
        whole plan's worth of ``(rel, offset, nbytes)`` descriptors.  Ranges
        are resolved to replica files, coalesced per file, and drained in
        one submission under ONE tier-slot acquisition; any range the batch
        could not serve retries through the per-range replica-fallback path
        (``get_range``), so the result is complete or an exception — exactly
        the serial semantics, minus the per-range submission cost."""
        requests = list(requests)
        paths: list = [None] * len(requests)
        for i, (rel, _off, _n) in enumerate(requests):
            cands = self.replica_paths(tier, rel)
            if cands:
                paths[i] = cands[0]
        with self.tier_slots(tier):
            got = self.pread_batch(
                tier, [(p, off, n) for p, (_rel, off, n)
                       in zip(paths, requests) if p is not None])
        out: list = [None] * len(requests)
        it = iter(got)
        for i, p in enumerate(paths):
            if p is not None:
                out[i] = next(it)
        for i, (rel, off, n) in enumerate(requests):
            if not isinstance(out[i], bytes):
                # replica fallback per failed range (simulated cost applies
                # again there — failures pay the retry, successes don't)
                out[i] = self.get_range(tier, rel, off, n)
        return out

    def copy_file(self, src_tier: str, rel: str, dst_tier: str,
                  *, src_path: Optional[Path] = None) -> Path:
        """OS-copy one intact-looking replica of ``src_tier:rel`` into the
        primary node of ``dst_tier`` (tmp + rename, so no torn copy is ever
        visible).  This is the tier-promotion primitive — the caller verifies
        CRCs on the copy before publishing any marker that references it."""
        if src_path is None:
            candidates = self.replica_paths(src_tier, rel)
            if not candidates:
                raise FileNotFoundError(f"{src_tier}:{rel}")
            src_path = candidates[0]
        dst = self._node_dirs(dst_tier)[0] / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        tmp = dst.with_suffix(dst.suffix + ".tmp")
        shutil.copyfile(src_path, tmp)      # sendfile/copy_file_range path
        tmp.rename(dst)
        self._fd_invalidate(dst)
        self._simulate(dst_tier, dst.stat().st_size)
        return dst

    def get(self, tier: str, rel: str) -> bytes:
        """Read with replica fallback; tries the next replica on ``OSError``
        (torn node, evicted cache) and raises ``FileNotFoundError`` only when
        no replica could be read."""
        errs: list[tuple[str, str]] = []
        for nd in self._node_dirs(tier):
            p = nd / rel
            if not p.exists():
                continue
            try:
                data = p.read_bytes()
            except OSError as e:           # damaged replica: try the next
                errs.append((str(p), str(e)))
                continue
            self._simulate(tier, len(data))
            return data
        suffix = f" (replica errors: {errs})" if errs else ""
        raise FileNotFoundError(f"{tier}:{rel}{suffix}")

    def size(self, tier: str, rel: str) -> int:
        for nd in self._node_dirs(tier):
            p = nd / rel
            try:
                return p.stat().st_size
            except OSError:
                continue
        raise FileNotFoundError(f"{tier}:{rel}")

    def mtime(self, tier: str, rel: str) -> float:
        """Modification time of the first replica that has the file (the
        orphan sweep's last-line race guard: a chunk re-touched after the
        sweep started is a writer's, not an orphan)."""
        for nd in self._node_dirs(tier):
            p = nd / rel
            try:
                return p.stat().st_mtime
            except OSError:
                continue
        raise FileNotFoundError(f"{tier}:{rel}")

    def get_range(self, tier: str, rel: str, offset: int, nbytes: int) -> bytes:
        """Ranged read with replica fallback on ``OSError``/short read (a
        truncated replica must not surface as silently-shorter data)."""
        errs: list[tuple[str, str]] = []
        for nd in self._node_dirs(tier):
            p = nd / rel
            if not p.exists():
                continue
            try:
                data = self._pread(p, offset, nbytes)
            except OSError as e:
                errs.append((str(p), str(e)))
                continue
            if len(data) != nbytes:
                errs.append((str(p), f"short read {len(data)}/{nbytes}"))
                continue
            self._simulate(tier, len(data))
            return data
        suffix = f" (replica errors: {errs})" if errs else ""
        raise FileNotFoundError(f"{tier}:{rel}{suffix}")

    def get_verified(self, tier: str, rel: str):
        """Read + parse a whole shard, falling back across replicas on crc
        failure.  Prefer ``read_shard_leaves`` when only some leaves are
        needed — it reads strictly fewer bytes."""
        return self.read_shard_leaves(tier, rel, None)

    def read_shard_leaves(self, tier: str, rel: str,
                          paths: Optional[list[str]] = None, *,
                          expect_crcs: Optional[dict[str, int]] = None):
        """Leaf-granular shard read: ({path: np.ndarray}, meta).

        Fetches only the header/footer plus the byte ranges of the requested
        ``paths`` (all leaves when ``None``).  A corrupted or unreadable
        replica triggers fallback to the next one.  ``expect_crcs`` lets the
        caller pin per-leaf CRCs (e.g. from a manifest): a mismatch against
        the shard header is detected before any payload bytes are read.
        """
        errs = []
        for nd in self._node_dirs(tier):
            p = nd / rel
            if not p.exists():
                continue

            def read_at(off: int, n: int) -> bytes:
                # per-op simulated latency (same accounting as the parallel
                # engine's ``pread``, so serial-vs-parallel timings compare)
                data = self._pread(p, off, n)
                if len(data) != n:
                    raise SER.ChecksumError(f"short read in {p}")
                self._simulate(tier, n)
                return data

            try:
                header = SER.read_shard_header(read_at, p.stat().st_size)
                if expect_crcs:
                    by_path = {t["path"]: t for t in header["tensors"]}
                    for path, crc in expect_crcs.items():
                        t = by_path.get(path)
                        if t is not None and t["crc32"] != crc:
                            raise SER.ChecksumError(
                                f"manifest crc mismatch: {path} in {rel}")
                return SER.read_shard_leaves(
                    read_at, p.stat().st_size, paths, header=header)
            except (SER.ChecksumError, OSError, ValueError, KeyError) as e:
                # KeyError: a parseable-but-stale replica missing a requested
                # leaf must fall back like any other damaged replica
                errs.append((str(p), repr(e)))
                continue
        raise SER.ChecksumError(f"no intact replica for {tier}:{rel}: {errs}")

    def exists(self, tier: str, rel: str) -> bool:
        return any((nd / rel).exists() for nd in self._node_dirs(tier))

    def delete_prefix(self, tier: str, prefix: str) -> None:
        for nd in self._node_dirs(tier):
            p = nd / prefix
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)
                # invalidate AFTER the mutation (like put/copy_file): a read
                # racing the rmtree either misses the cache or gets an entry
                # this invalidation then kills — never a silently-pinned fd
                self._fd_invalidate_under(p)

    def delete_file(self, tier: str, rel: str) -> None:
        for nd in self._node_dirs(tier):
            p = nd / rel
            if p.exists():
                p.unlink()
                self._fd_invalidate(p)

    def list_prefix(self, tier: str, prefix: str) -> set[str]:
        out: set[str] = set()
        for nd in self._node_dirs(tier):
            p = nd / prefix
            if p.is_dir():
                for f in p.rglob("*"):
                    if f.is_file() and not f.name.endswith(".tmp"):
                        out.add(str(f.relative_to(nd)))
        return out
