"""Parallel multi-shard, multi-source restore engine (paper Fig. 2).

The paper's headline cost is restoring checkpoint images from the shared
parallel filesystem at scale; DMTCP's answer is parallel per-rank restore plus
peers cooperating on restart, and NERSC's is a node-local container-image
cache.  This module is the framework analogue of all three:
``CheckpointManager.restore`` hands the manifest's (file -> leaves) map to a
``ParallelRestorer``, which fans the reads out across a thread pool instead of
walking shards one at a time — and, via ``restore_multi``, plans every
coalesced run against an ordered SOURCE LIST (local promoted cache, warm
peers' caches over the interconnect, then the shared filesystem) instead of a
single tier.

Plan phase: every referenced shard's header (a few hundred bytes) is fetched
concurrently from the first source holding a parseable replica, manifest CRCs
are pinned against it, and the requested leaves are coalesced into contiguous
runs — one ranged read each.  Runs larger than ``split_bytes`` are split at
leaf boundaries so one multi-GB shard becomes several same-order tasks
instead of a single straggler.

Schedule phase: tasks are issued largest-first (LPT — the classic greedy
bound on makespan), so the big reads start immediately and the small ones
backfill the tail.  Per-tier concurrency comes from ``TierSpec.concurrency``
via ``TieredStore.tier_slots``: each in-flight read against a tier holds one
of that tier's slots, so a pool sized for the RAM tier cannot stampede the
shared parallel filesystem — and since every registered peer tier brings its
OWN slots, k warm peers aggregate to k times the per-peer read bandwidth.
With multiple warm peers the per-task source chains are rotated round-robin,
so the range load spreads evenly across the peer set.

Fault model: each range task retries down its source chain independently —
an ``OSError`` / short read / CRC mismatch on one source falls back to the
next (the next peer, then the shared tier), exactly like the serial reader's
replica fallback, but scoped to the failed range rather than the whole shard.
Manifest CRCs are pinned whatever the source, so a stale or corrupt peer can
cost a retry, never wrong bytes.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint import serialization as SER
from repro.checkpoint.store import is_peer_tier

DEFAULT_SPLIT_BYTES = 32 << 20      # target max payload bytes per range task

ENV_RESTORE_WORKERS = "REPRO_RESTORE_WORKERS"


def auto_workers(cap: Optional[int] = None) -> int:
    """Restore pool sizing.  ``REPRO_RESTORE_WORKERS`` wins outright when
    set; otherwise the CPU count, capped by ``cap`` — the restore tier's
    ``TierSpec.concurrency`` budget (summed across sources for multi-source
    restores), so the pool is sized by what the storage can actually absorb
    rather than a magic constant."""
    env = os.environ.get(ENV_RESTORE_WORKERS, "").strip()
    if env:
        try:
            return max(1, int(env))
        except ValueError:
            pass        # mangled override degrades to auto, never kills a restore
    n = max(2, os.cpu_count() or 2)
    if cap:
        n = min(n, max(1, cap))
    return n


@dataclasses.dataclass
class _ShardPlan:
    rel: str
    by_tier: dict                   # tier -> replica paths (plan-clean first)
    want: list[dict]                # offset-sorted header entries to fetch


@dataclasses.dataclass
class _RangeTask:
    rel: str
    sources: list[tuple[str, Path]]  # ordered (tier, path) fallback chain
    run: list[dict]                  # one contiguous run of header entries
    nbytes: int


@dataclasses.dataclass
class RestoreStats:
    workers: int
    files: int = 0
    tasks: int = 0
    bytes_read: int = 0             # payload bytes (headers excluded)
    replica_fallbacks: int = 0
    sources: list = dataclasses.field(default_factory=list)
    bytes_by_tier: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ParallelRestorer:
    """Fan manifest-referenced byte ranges out across a read pool.

    ``restore(tier, by_file)`` takes ``{shard_rel: [manifest leaf entries]}``
    and returns ``({leaf_path: np.ndarray}, RestoreStats)``;
    ``restore_multi(sources, by_file)`` does the same against an ordered
    source-tier list with per-range fallback down the chain.  Results are
    byte-identical to the serial ``TieredStore.read_shard_leaves`` loop — the
    engine only changes WHERE and WHEN each range is read, never what is
    verified.
    """

    def __init__(self, store, *, workers: int = 0,
                 split_bytes: int = DEFAULT_SPLIT_BYTES):
        self.store = store
        self.workers = workers          # 0 = auto-size per restore (tier-aware)
        self.split_bytes = split_bytes

    def _effective_workers(self, sources: list[str]) -> int:
        if self.workers > 0:
            return self.workers
        caps = [self.store.tiers[t].concurrency for t in sources
                if t in self.store.tiers]
        cap = None if (not caps or any(not c for c in caps)) else sum(caps)
        return auto_workers(cap)

    # -- plan ----------------------------------------------------------
    def _plan_shard(self, sources: list[str], rel: str, ents: list[dict],
                    shard_index: int = 0) -> _ShardPlan:
        """Parse one candidate's header, pin manifest CRCs against it, and
        keep every other candidate (all sources) as per-range fallbacks.
        Peer candidates are rotated by ``shard_index`` so header traffic —
        like range traffic — spreads across the warm peer set."""
        leaf_paths = [e["path"] for e in ents]
        expect = {e["path"]: e["crc32"] for e in ents
                  if e.get("crc32") is not None}
        by_tier = {t: self.store.replica_paths(t, rel) for t in sources}
        by_tier = {t: ps for t, ps in by_tier.items() if ps}
        candidates = [(t, p) for t in _ordered_tiers(sources, by_tier,
                                                     shard_index)
                      for p in by_tier[t]]
        errs: list[tuple[str, str, str]] = []
        for tier, p in candidates:
            try:
                # header reads hold tier slots like payload reads do — tier
                # concurrency is a property of the storage, not of the phase
                # (and it is what lets k peers aggregate during planning)
                with self.store.tier_slots(tier):
                    size = p.stat().st_size
                    header = SER.read_shard_header(
                        lambda off, n: self.store.pread(tier, p, off, n),
                        size)
                by_path = {t["path"]: t for t in header["tensors"]}
                for path, crc in expect.items():
                    t = by_path.get(path)
                    if t is not None and t["crc32"] != crc:
                        raise SER.ChecksumError(
                            f"manifest crc mismatch: {path} in {rel}")
                want = SER.select_leaves(header, leaf_paths)
                # plan-clean path first within its tier: range reads start on
                # a replica whose index is known parseable
                ps = by_tier[tier]
                by_tier[tier] = [p] + [q for q in ps if q != p]
                return _ShardPlan(rel=rel, by_tier=by_tier, want=want)
            except (SER.ChecksumError, OSError, ValueError, KeyError) as e:
                errs.append((tier, str(p), repr(e)))
        raise SER.ChecksumError(
            f"no intact replica for {'/'.join(sources)}:{rel}: {errs}")

    # -- execute -------------------------------------------------------
    def _exec_task(self, task: _RangeTask):
        """One ranged read with fallback down the (tier, path) source chain;
        returns the task's leaves plus (bytes_read, fallback_count, tier)."""
        errs: list[tuple[str, str, str]] = []
        for i, (tier, p) in enumerate(task.sources):
            out: dict[str, np.ndarray] = {}
            try:
                with self.store.tier_slots(tier):
                    nbytes = SER.read_run(
                        lambda off, n: self.store.pread(tier, p, off, n),
                        task.run, out)
                return out, nbytes, i, tier
            except (SER.ChecksumError, OSError, ValueError) as e:
                errs.append((tier, str(p), repr(e)))
        raise SER.ChecksumError(
            f"no intact replica for {task.rel}"
            f"@{task.run[0]['offset']}+{task.nbytes}: {errs}")

    # -- public --------------------------------------------------------
    def restore(self, tier: str, by_file: dict[str, list[dict]]):
        return self._run([tier], by_file)

    def restore_multi(self, sources: list[str],
                      by_file: dict[str, list[dict]]):
        """Multi-source restore: every range task gets a fallback chain built
        from ``sources`` in order, with warm peers rotated round-robin per
        task so k peers aggregate bandwidth instead of queueing on one."""
        return self._run(list(sources), by_file)

    def _run(self, sources: list[str], by_file: dict[str, list[dict]]):
        workers = self._effective_workers(sources)
        stats = RestoreStats(workers=workers, files=len(by_file),
                             sources=list(sources))
        if not by_file:
            return {}, stats
        named: dict[str, np.ndarray] = {}
        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="ckpt-restore") as pool:
            plans = list(pool.map(
                lambda item: self._plan_shard(sources, item[1][0], item[1][1],
                                              shard_index=item[0]),
                enumerate(by_file.items())))
            tasks = []
            j = 0
            for plan in plans:
                for run in SER.coalesce_runs(plan.want,
                                             max_run_bytes=self.split_bytes):
                    chain = [(t, p)
                             for t in _ordered_tiers(sources, plan.by_tier, j)
                             for p in plan.by_tier[t]]
                    tasks.append(_RangeTask(
                        rel=plan.rel, sources=chain, run=run,
                        nbytes=sum(t["nbytes"] for t in run)))
                    j += 1
            tasks.sort(key=lambda t: t.nbytes, reverse=True)   # LPT order
            stats.tasks = len(tasks)
            futures = [pool.submit(self._exec_task, t) for t in tasks]
            for fut in futures:
                out, nbytes, fallbacks, tier = fut.result()
                named.update(out)
                stats.bytes_read += nbytes
                stats.replica_fallbacks += fallbacks
                stats.bytes_by_tier[tier] = (
                    stats.bytes_by_tier.get(tier, 0) + nbytes)
        return named, stats


def _ordered_tiers(sources: list[str], by_tier: dict, index: int) -> list[str]:
    """Source order for one task: non-peer tiers keep their position, the
    peer subset is rotated by ``index`` (round-robin) so consecutive tasks
    start on different warm peers — that is the bandwidth aggregation."""
    avail = [t for t in sources if by_tier.get(t)]
    peers = [t for t in avail if is_peer_tier(t)]
    if len(peers) <= 1:
        return avail
    k = index % len(peers)
    rotated = iter(peers[k:] + peers[:k])
    return [next(rotated) if is_peer_tier(t) else t for t in avail]
