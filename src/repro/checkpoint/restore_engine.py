"""Parallel multi-shard restore engine (paper Fig. 2: restart latency).

The paper's headline cost is restoring checkpoint images from the shared
parallel filesystem at scale; DMTCP's answer is parallel per-rank restore and
NERSC's is a node-local container-image cache.  This module is the framework
analogue of the first half: ``CheckpointManager.restore`` hands the manifest's
(file -> leaves) map to a ``ParallelRestorer``, which fans the reads out
across a thread pool instead of walking shards one at a time.  (The second
half — teeing restored shards into the node-local tier — lives in
``CheckpointManager``'s promotion path; see manager.py.)

Plan phase: every referenced shard's header (a few hundred bytes) is fetched
concurrently, manifest CRCs are pinned against it, and the requested leaves
are coalesced into contiguous runs — one ranged read each.  Runs larger than
``split_bytes`` are split at leaf boundaries so one multi-GB shard becomes
several same-order tasks instead of a single straggler.

Schedule phase: tasks are issued largest-first (LPT — the classic greedy
bound on makespan), so the big reads start immediately and the small ones
backfill the tail.  Per-tier concurrency comes from ``TierSpec.concurrency``
via ``TieredStore.tier_slots``: a pool sized for the RAM tier cannot stampede
the shared parallel filesystem, because each in-flight read against a tier
holds one of that tier's slots.

Fault model: each range task retries across the replica set independently —
an ``OSError`` / short read / CRC mismatch on one replica falls back to the
next, exactly like the serial reader, but scoped to the failed range rather
than the whole shard.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from repro.checkpoint import serialization as SER

DEFAULT_SPLIT_BYTES = 32 << 20      # target max payload bytes per range task


def auto_workers() -> int:
    return max(2, min(8, os.cpu_count() or 2))


@dataclasses.dataclass
class _ShardPlan:
    rel: str
    paths: list[Path]               # replica candidates; paths[0] parsed clean
    want: list[dict]                # offset-sorted header entries to fetch


@dataclasses.dataclass
class _RangeTask:
    rel: str
    paths: list[Path]
    run: list[dict]                 # one contiguous run of header entries
    nbytes: int


@dataclasses.dataclass
class RestoreStats:
    workers: int
    files: int = 0
    tasks: int = 0
    bytes_read: int = 0             # payload bytes (headers excluded)
    replica_fallbacks: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ParallelRestorer:
    """Fan manifest-referenced byte ranges out across a read pool.

    ``restore(tier, by_file)`` takes ``{shard_rel: [manifest leaf entries]}``
    and returns ``({leaf_path: np.ndarray}, RestoreStats)``.  Results are
    byte-identical to the serial ``TieredStore.read_shard_leaves`` loop — the
    engine only changes WHEN each range is read, never what is verified.
    """

    def __init__(self, store, *, workers: int = 0,
                 split_bytes: int = DEFAULT_SPLIT_BYTES):
        self.store = store
        self.workers = workers if workers > 0 else auto_workers()
        self.split_bytes = split_bytes

    # -- plan ----------------------------------------------------------
    def _plan_shard(self, tier: str, rel: str, ents: list[dict]) -> _ShardPlan:
        """Parse one replica's header, pin manifest CRCs against it, and keep
        the other replicas as per-range fallbacks."""
        leaf_paths = [e["path"] for e in ents]
        expect = {e["path"]: e["crc32"] for e in ents
                  if e.get("crc32") is not None}
        candidates = self.store.replica_paths(tier, rel)
        errs: list[tuple[str, str]] = []
        for i, p in enumerate(candidates):
            try:
                size = p.stat().st_size
                header = SER.read_shard_header(
                    lambda off, n: self.store.pread(tier, p, off, n), size)
                by_path = {t["path"]: t for t in header["tensors"]}
                for path, crc in expect.items():
                    t = by_path.get(path)
                    if t is not None and t["crc32"] != crc:
                        raise SER.ChecksumError(
                            f"manifest crc mismatch: {path} in {rel}")
                want = SER.select_leaves(header, leaf_paths)
                paths = [p] + candidates[:i] + candidates[i + 1:]
                return _ShardPlan(rel=rel, paths=paths, want=want)
            except (SER.ChecksumError, OSError, ValueError, KeyError) as e:
                errs.append((str(p), repr(e)))
        raise SER.ChecksumError(f"no intact replica for {tier}:{rel}: {errs}")

    # -- execute -------------------------------------------------------
    def _exec_task(self, tier: str, task: _RangeTask):
        """One ranged read with per-replica fallback; returns the task's
        leaves plus (bytes_read, fallback_count)."""
        errs: list[tuple[str, str]] = []
        for i, p in enumerate(task.paths):
            out: dict[str, np.ndarray] = {}
            try:
                with self.store.tier_slots(tier):
                    nbytes = SER.read_run(
                        lambda off, n: self.store.pread(tier, p, off, n),
                        task.run, out)
                return out, nbytes, i
            except (SER.ChecksumError, OSError, ValueError) as e:
                errs.append((str(p), repr(e)))
        raise SER.ChecksumError(
            f"no intact replica for {task.rel}"
            f"@{task.run[0]['offset']}+{task.nbytes}: {errs}")

    # -- public --------------------------------------------------------
    def restore(self, tier: str, by_file: dict[str, list[dict]]):
        stats = RestoreStats(workers=self.workers, files=len(by_file))
        if not by_file:
            return {}, stats
        named: dict[str, np.ndarray] = {}
        with ThreadPoolExecutor(max_workers=self.workers,
                                thread_name_prefix="ckpt-restore") as pool:
            plans = list(pool.map(
                lambda item: self._plan_shard(tier, item[0], item[1]),
                by_file.items()))
            tasks = [
                _RangeTask(rel=plan.rel, paths=plan.paths, run=run,
                           nbytes=sum(t["nbytes"] for t in run))
                for plan in plans
                for run in SER.coalesce_runs(plan.want,
                                             max_run_bytes=self.split_bytes)
            ]
            tasks.sort(key=lambda t: t.nbytes, reverse=True)   # LPT order
            stats.tasks = len(tasks)
            futures = [pool.submit(self._exec_task, tier, t) for t in tasks]
            for fut in futures:
                out, nbytes, fallbacks = fut.result()
                named.update(out)
                stats.bytes_read += nbytes
                stats.replica_fallbacks += fallbacks
        return named, stats
