"""Parallel multi-shard, multi-source restore engine (paper Fig. 2).

The paper's headline cost is restoring checkpoint images from the shared
parallel filesystem at scale; DMTCP's answer is parallel per-rank restore plus
peers cooperating on restart, and NERSC's is a node-local container-image
cache.  This module is the framework analogue of all three:
``CheckpointManager.restore`` hands the manifest's (file -> leaves) map to a
``ParallelRestorer``, which fans the reads out across a thread pool instead of
walking shards one at a time — and, via ``restore_multi``, plans every
coalesced run against an ordered SOURCE LIST (local promoted cache, warm
peers' caches over the interconnect, then the shared filesystem) instead of a
single tier.

Plan phase: every referenced shard's header (a few hundred bytes) is fetched
concurrently from the first source holding a parseable replica, manifest CRCs
are pinned against it, and the requested leaves are coalesced into contiguous
runs — one ranged read each.  Runs larger than ``split_bytes`` are split at
leaf boundaries so one multi-GB shard becomes several same-order tasks
instead of a single straggler.

Schedule phase: tasks are issued largest-first (LPT — the classic greedy
bound on makespan), so the big reads start immediately and the small ones
backfill the tail.  Per-tier concurrency comes from ``TierSpec.concurrency``
via ``TieredStore.tier_slots``: each in-flight read against a tier holds one
of that tier's slots, so a pool sized for the RAM tier cannot stampede the
shared parallel filesystem — and since every registered peer tier brings its
OWN slots, k warm peers aggregate to k times the per-peer read bandwidth.
With multiple warm peers the per-task source chains are rotated round-robin,
so the range load spreads evenly across the peer set.

Fault model: each range task retries down its source chain independently —
an ``OSError`` / short read / CRC mismatch on one source falls back to the
next (the next peer, then the shared tier), exactly like the serial reader's
replica fallback, but scoped to the failed range rather than the whole shard.
Manifest CRCs are pinned whatever the source, so a stale or corrupt peer can
cost a retry, never wrong bytes.

Chunk plane (``restore_chunked``): content-addressed (v3) leaves resolve
per CHUNK instead of per byte range — every chunk independently walks an
ordered source list that starts with the node's own (possibly stale)
promoted cache, so a delta restore reads only the chunks the node is
actually missing from remote tiers.  Same fault model, same CRC pinning,
plus a whole-leaf CRC after assembly.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import zlib
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint import serialization as SER
from repro.checkpoint.store import chunk_rel, is_peer_tier
from repro.utils.env import env_positive_int

DEFAULT_SPLIT_BYTES = 32 << 20      # target max payload bytes per range task

ENV_RESTORE_WORKERS = "REPRO_RESTORE_WORKERS"
ENV_IO_BATCH = "REPRO_IO_BATCH"
# ranges per batched submission: enough to amortize the per-submission
# latency across a plan's small ranges, small enough that one failed batch
# retries cheaply.  1 disables batching (the per-range legacy path, kept as
# the benchmark baseline).
DEFAULT_IO_BATCH = 16

log = logging.getLogger(__name__)


def auto_workers(cap: Optional[int] = None) -> int:
    """Restore pool sizing.  ``REPRO_RESTORE_WORKERS`` wins outright when
    set to a positive integer; otherwise the CPU count, capped by ``cap`` —
    the restore tier's ``TierSpec.concurrency`` budget (summed across sources
    for multi-source restores), so the pool is sized by what the storage can
    actually absorb rather than a magic constant.

    A mangled override (non-integer, zero, negative) degrades to auto sizing
    with a logged warning — an operator typo in a job script must never turn
    into a ``ValueError`` at restore time, which is exactly when the job can
    least afford to die (the parse contract lives in ``utils.env``)."""
    n = env_positive_int(ENV_RESTORE_WORKERS, logger=log)
    if n is not None:
        return n
    n = max(2, os.cpu_count() or 2)
    if cap:
        n = min(n, max(1, cap))
    return n


def auto_io_batch() -> int:
    """Ranges per batched submission.  ``REPRO_IO_BATCH`` wins when set to a
    positive integer; a mangled value degrades to the default with a logged
    warning — the same contract as the two worker knobs."""
    n = env_positive_int(ENV_IO_BATCH, logger=log)
    return n if n is not None else DEFAULT_IO_BATCH


@dataclasses.dataclass
class _ShardPlan:
    rel: str
    by_tier: dict                   # tier -> replica paths (plan-clean first)
    want: list[dict]                # offset-sorted header entries to fetch


@dataclasses.dataclass
class _RangeTask:
    rel: str
    sources: list[tuple[str, Path]]  # ordered (tier, path) fallback chain
    runs: list[list[dict]]           # contiguous runs, one submission all-up
    nbytes: int


@dataclasses.dataclass
class _ChunkWork:
    """One unique chunk to fetch (dedup'd: the same content hash wanted by
    several leaves — or several positions of one leaf — is read ONCE)."""
    digest: str
    nbytes: int
    crc32: Optional[int]
    users: list                     # (leaf_path, byte offset) placements
    by_tier: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RestoreStats:
    workers: int
    files: int = 0
    tasks: int = 0
    bytes_read: int = 0             # payload bytes (headers excluded)
    replica_fallbacks: int = 0
    sources: list = dataclasses.field(default_factory=list)
    bytes_by_tier: dict = dataclasses.field(default_factory=dict)
    chunks: int = 0                 # unique chunks fetched (chunked restores)
    chunk_refs: int = 0             # chunk references before dedup

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ParallelRestorer:
    """Fan manifest-referenced byte ranges out across a read pool.

    ``restore(tier, by_file)`` takes ``{shard_rel: [manifest leaf entries]}``
    and returns ``({leaf_path: np.ndarray}, RestoreStats)``;
    ``restore_multi(sources, by_file)`` does the same against an ordered
    source-tier list with per-range fallback down the chain.  Results are
    byte-identical to the serial ``TieredStore.read_shard_leaves`` loop — the
    engine only changes WHERE and WHEN each range is read, never what is
    verified.
    """

    def __init__(self, store, *, workers: int = 0,
                 split_bytes: int = DEFAULT_SPLIT_BYTES,
                 io_batch: int = 0):
        self.store = store
        self.workers = workers          # 0 = auto-size per restore (tier-aware)
        self.split_bytes = split_bytes
        # ranges per submission: 0 = $REPRO_IO_BATCH / default, 1 = the
        # per-range path (one pread per run — the pre-batching engine and
        # the benchmark baseline), N = up to N ranges per pread_batch
        self.io_batch = io_batch if io_batch > 0 else auto_io_batch()

    def _effective_workers(self, sources: list[str]) -> int:
        if self.workers > 0:
            return self.workers
        caps = [self.store.tiers[t].concurrency for t in sources
                if t in self.store.tiers]
        cap = None if (not caps or any(not c for c in caps)) else sum(caps)
        return auto_workers(cap)

    # -- plan ----------------------------------------------------------
    def _plan_shard(self, sources: list[str], rel: str, ents: list[dict],
                    shard_index: int = 0) -> _ShardPlan:
        """Parse one candidate's header, pin manifest CRCs against it, and
        keep every other candidate (all sources) as per-range fallbacks.
        Peer candidates are rotated by ``shard_index`` so header traffic —
        like range traffic — spreads across the warm peer set."""
        leaf_paths = [e["path"] for e in ents]
        expect = {e["path"]: e["crc32"] for e in ents
                  if e.get("crc32") is not None}
        by_tier = {t: self.store.replica_paths(t, rel) for t in sources}
        by_tier = {t: ps for t, ps in by_tier.items() if ps}
        candidates = [(t, p) for t in _ordered_tiers(sources, by_tier,
                                                     shard_index)
                      for p in by_tier[t]]
        errs: list[tuple[str, str, str]] = []
        for tier, p in candidates:
            try:
                # header reads hold tier slots like payload reads do — tier
                # concurrency is a property of the storage, not of the phase
                # (and it is what lets k peers aggregate during planning)
                with self.store.tier_slots(tier):
                    size = p.stat().st_size
                    header = SER.read_shard_header(
                        lambda off, n: self.store.pread(tier, p, off, n),
                        size)
                by_path = {t["path"]: t for t in header["tensors"]}
                for path, crc in expect.items():
                    t = by_path.get(path)
                    if t is not None and t["crc32"] != crc:
                        raise SER.ChecksumError(
                            f"manifest crc mismatch: {path} in {rel}")
                want = SER.select_leaves(header, leaf_paths)
                # plan-clean path first within its tier: range reads start on
                # a replica whose index is known parseable
                ps = by_tier[tier]
                by_tier[tier] = [p] + [q for q in ps if q != p]
                return _ShardPlan(rel=rel, by_tier=by_tier, want=want)
            except (SER.ChecksumError, OSError, ValueError, KeyError) as e:
                errs.append((tier, str(p), repr(e)))
        raise SER.ChecksumError(
            f"no intact replica for {'/'.join(sources)}:{rel}: {errs}")

    # -- execute -------------------------------------------------------
    @staticmethod
    def _run_span(run: list[dict]) -> tuple[int, int]:
        start = run[0]["offset"]
        return start, run[-1]["offset"] + run[-1]["nbytes"] - start

    def _exec_task(self, task: _RangeTask):
        """One submission with fallback down the (tier, path) source chain;
        returns the task's leaves plus (bytes_read, fallback_count, tier).

        A multi-run task is drained as ONE batched submission
        (``pread_batch``: vectored/direct reads, one slot, one simulated
        latency); a single-run task — and every task when ``io_batch == 1``
        — keeps the per-range ``pread``, byte-identical either way.  Any
        failed range fails the source: the whole task falls back to the
        next (tier, path), exactly the pre-batching semantics."""
        errs: list[tuple[str, str, str]] = []
        for i, (tier, p) in enumerate(task.sources):
            out: dict[str, np.ndarray] = {}
            try:
                with self.store.tier_slots(tier):
                    if len(task.runs) > 1:
                        spans = [self._run_span(r) for r in task.runs]
                        got = self.store.pread_batch(
                            tier, [(p, s, n) for s, n in spans])
                        nbytes = 0
                        for run, (start, _n), blob in zip(task.runs, spans,
                                                          got):
                            if isinstance(blob, Exception):
                                raise blob
                            nbytes += SER.read_run(
                                lambda off, n, b=blob, s=start:
                                    b[off - s:off - s + n],
                                run, out)
                    else:
                        nbytes = SER.read_run(
                            lambda off, n: self.store.pread(tier, p, off, n),
                            task.runs[0], out)
                return out, nbytes, i, tier
            except (SER.ChecksumError, OSError, ValueError) as e:
                errs.append((tier, str(p), repr(e)))
        raise SER.ChecksumError(
            f"no intact replica for {task.rel}"
            f"@{task.runs[0][0]['offset']}+{task.nbytes}: {errs}")

    # -- public --------------------------------------------------------
    def restore(self, tier: str, by_file: dict[str, list[dict]]):
        return self._run([tier], by_file)

    def restore_multi(self, sources: list[str],
                      by_file: dict[str, list[dict]]):
        """Multi-source restore: every range task gets a fallback chain built
        from ``sources`` in order, with warm peers rotated round-robin per
        task so k peers aggregate bandwidth instead of queueing on one."""
        return self._run(list(sources), by_file)

    def restore_chunked(self, sources: list[str], leaves: list[dict], *,
                        prefix: str, tee=None):
        """Restore content-addressed (v3) leaves against an ordered source
        list.  Returns ``({leaf_path: np.ndarray}, RestoreStats)``.

        Every chunk is resolved INDEPENDENTLY down the source list — which is
        what makes delta restores cheap: a requeued node whose stale local
        cache still holds 95% of the chunks reads those locally and fetches
        only the missing delta chunks from peers (or the shared tier).
        Duplicate sources and duplicate chunk references are dedup'd; chunks
        are batched into ~``split_bytes`` tasks grouped by their primary
        source, issued largest-first, with peers rotated round-robin per task.
        Per-chunk CRCs AND the whole-leaf CRC are pinned from the manifest,
        so the result is byte-identical to a full-shard restore or it fails.

        ``tee(rel, data, src_tier)``, if given, is invoked once per unique
        chunk AFTER its CRC verified, from the worker threads (callers
        bring their own synchronization).  The serving-fleet follower uses
        it to park remotely-fetched delta chunks in its node-local tier —
        the write-behind that makes replica-to-replica propagation possible
        without ever touching the node's promotion marker.
        """
        srcs = list(dict.fromkeys(sources))         # dedup, order-preserving
        workers = self._effective_workers(srcs)
        stats = RestoreStats(workers=workers, files=len(leaves),
                             sources=srcs)
        buffers: dict[str, np.ndarray] = {}
        works: dict[str, _ChunkWork] = {}
        for e in leaves:
            nbytes = sum(c["nbytes"] for c in e["chunks"])
            buffers[e["path"]] = np.empty(nbytes, dtype=np.uint8)
            off = 0
            for c in e["chunks"]:
                w = works.get(c["hash"])
                if w is None:
                    w = works[c["hash"]] = _ChunkWork(
                        digest=c["hash"], nbytes=c["nbytes"],
                        crc32=c.get("crc32"), users=[])
                w.users.append((e["path"], off))
                off += c["nbytes"]
                stats.chunk_refs += 1
        stats.chunks = len(works)
        if not works:
            return self._finish_chunked(leaves, buffers, stats)

        def locate(w: _ChunkWork) -> _ChunkWork:
            rel = chunk_rel(prefix, w.digest)
            w.by_tier = {t: ps for t in srcs
                         if (ps := self.store.replica_paths(t, rel))}
            return w

        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="ckpt-restore") as pool:
            ordered = list(pool.map(locate,
                                    (works[d] for d in sorted(works))))
            # batch by primary source so one task streams from one tier;
            # cap at split_bytes so a large delta still fans out
            groups: dict[str, list[_ChunkWork]] = {}
            for w in ordered:
                first = next((t for t in srcs if w.by_tier.get(t)), "")
                groups.setdefault(first, []).append(w)
            tasks: list[list[_ChunkWork]] = []
            for _, ws in sorted(groups.items()):
                cur: list[_ChunkWork] = []
                cur_bytes = 0
                for w in ws:
                    if cur and cur_bytes + w.nbytes > self.split_bytes:
                        tasks.append(cur)
                        cur, cur_bytes = [], 0
                    cur.append(w)
                    cur_bytes += w.nbytes
                if cur:
                    tasks.append(cur)
            tasks.sort(key=lambda ws: sum(w.nbytes for w in ws),
                       reverse=True)                    # LPT order
            stats.tasks = len(tasks)
            futures = [pool.submit(self._exec_chunk_task, srcs, j, ws,
                                   buffers, prefix, tee)
                       for j, ws in enumerate(tasks)]
            for fut in futures:
                by_tier, fallbacks = fut.result()
                stats.replica_fallbacks += fallbacks
                for tier, n in by_tier.items():
                    stats.bytes_read += n
                    stats.bytes_by_tier[tier] = (
                        stats.bytes_by_tier.get(tier, 0) + n)
        return self._finish_chunked(leaves, buffers, stats)

    def _chunk_done(self, w: _ChunkWork, blob: bytes, raw: bytes, tier: str,
                    by_tier: dict, buffers: dict, prefix: str, tee) -> None:
        """Account + scatter one verified chunk.  ``blob`` is the on-disk
        file (possibly compression-framed), ``raw`` the verified content;
        byte attribution and the tee both use the FILE bytes, so
        ``bytes_by_tier`` reports what actually moved over each tier and a
        follower cache parks the same framed file the source tier holds."""
        by_tier[tier] = by_tier.get(tier, 0) + len(blob)
        if tee is not None:
            tee(chunk_rel(prefix, w.digest), blob, tier)
        for leaf_path, off in w.users:
            memoryview(buffers[leaf_path])[off:off + w.nbytes] = raw

    def _exec_chunk_task(self, srcs: list[str], index: int,
                         ws: list[_ChunkWork], buffers: dict,
                         prefix: str = "", tee=None):
        """Fetch one batch of chunks and scatter the verified bytes into the
        leaf buffers (disjoint regions, so no locking).

        With ``io_batch > 1`` the task's chunks are grouped by their
        first-choice source tier and each group drains as ONE batched
        submission (whole chunk files — a compressed chunk's on-disk size
        differs from its raw size, so the backend stats each file).  Any
        chunk the batch could not serve — and every chunk at
        ``io_batch == 1`` — retries independently down its own (tier, path)
        chain, exactly the pre-batching fault model.  Chunk files are
        unframed (``SER.unframe_chunk``) with the manifest CRC as arbiter,
        so compressed and legacy frameless chunks verify identically."""
        by_tier: dict[str, int] = {}
        fallbacks = 0
        pending: list[_ChunkWork] = list(ws)
        if self.io_batch > 1:
            groups: dict[str, list[tuple[_ChunkWork, Path]]] = {}
            unplaced: list[_ChunkWork] = []
            for w in ws:
                chain = [(t, p)
                         for t in _ordered_tiers(srcs, w.by_tier, index)
                         for p in w.by_tier[t]]
                if chain:
                    groups.setdefault(chain[0][0], []).append((w, chain[0][1]))
                else:
                    unplaced.append(w)
            pending = unplaced
            for tier, members in sorted(groups.items()):
                with self.store.tier_slots(tier):
                    got = self.store.pread_batch(
                        tier, [(p, 0, None) for _, p in members])
                for (w, _p), blob in zip(members, got):
                    raw = None
                    if isinstance(blob, bytes):
                        try:
                            raw = SER.unframe_chunk(blob, w.nbytes,
                                                    crc32=w.crc32)
                        except SER.ChecksumError:
                            raw = None
                    if raw is None:
                        pending.append(w)   # per-chunk fallback below
                    else:
                        self._chunk_done(w, blob, raw, tier, by_tier,
                                         buffers, prefix, tee)
        for w in pending:
            errs: list[tuple[str, str, str]] = []
            chain = [(t, p) for t in _ordered_tiers(srcs, w.by_tier, index)
                     for p in w.by_tier[t]]
            for i, (tier, p) in enumerate(chain):
                try:
                    with self.store.tier_slots(tier):
                        blob = self.store.pread(tier, p, 0,
                                                os.stat(p).st_size)
                    raw = SER.unframe_chunk(blob, w.nbytes, crc32=w.crc32)
                    break
                except (SER.ChecksumError, OSError, ValueError) as e:
                    errs.append((tier, str(p), repr(e)))
            else:
                raise SER.ChecksumError(
                    f"no intact source for chunk {w.digest}: {errs}")
            fallbacks += i
            self._chunk_done(w, blob, raw, tier, by_tier, buffers, prefix,
                             tee)
        return by_tier, fallbacks

    @staticmethod
    def _finish_chunked(leaves: list[dict], buffers: dict,
                        stats: RestoreStats):
        """Whole-leaf CRC check + dtype/shape materialization (zero-copy
        views over the assembled buffers)."""
        named: dict[str, np.ndarray] = {}
        for e in leaves:
            buf = buffers[e["path"]]
            if e.get("crc32") is not None and zlib.crc32(buf) != e["crc32"]:
                raise SER.ChecksumError(
                    f"leaf crc mismatch for {e['path']} after chunk assembly")
            named[e["path"]] = buf.view(
                np.dtype(e["dtype"])).reshape(e["shape"])
        return named, stats

    def _run(self, sources: list[str], by_file: dict[str, list[dict]]):
        workers = self._effective_workers(sources)
        stats = RestoreStats(workers=workers, files=len(by_file),
                             sources=list(sources))
        if not by_file:
            return {}, stats
        named: dict[str, np.ndarray] = {}
        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="ckpt-restore") as pool:
            plans = list(pool.map(
                lambda item: self._plan_shard(sources, item[1][0], item[1][1],
                                              shard_index=item[0]),
                enumerate(by_file.items())))
            tasks = []
            j = 0
            for plan in plans:
                runs = SER.coalesce_runs(plan.want,
                                         max_run_bytes=self.split_bytes)
                # pack runs into one submission each, up to io_batch ranges
                # and split_bytes total — small scattered leaves share one
                # vectored read, a split_bytes-sized run stays its own task
                # so LPT granularity (and the straggler bound) is unchanged
                packs: list[list[list[dict]]] = []
                cur: list[list[dict]] = []
                cur_bytes = 0
                for run in runs:
                    rb = sum(t["nbytes"] for t in run)
                    if cur and (len(cur) >= self.io_batch
                                or cur_bytes + rb > self.split_bytes):
                        packs.append(cur)
                        cur, cur_bytes = [], 0
                    cur.append(run)
                    cur_bytes += rb
                if cur:
                    packs.append(cur)
                for pack in packs:
                    chain = [(t, p)
                             for t in _ordered_tiers(sources, plan.by_tier, j)
                             for p in plan.by_tier[t]]
                    tasks.append(_RangeTask(
                        rel=plan.rel, sources=chain, runs=pack,
                        nbytes=sum(t["nbytes"] for r in pack for t in r)))
                    j += 1
            tasks.sort(key=lambda t: t.nbytes, reverse=True)   # LPT order
            stats.tasks = len(tasks)
            futures = [pool.submit(self._exec_task, t) for t in tasks]
            for fut in futures:
                out, nbytes, fallbacks, tier = fut.result()
                named.update(out)
                stats.bytes_read += nbytes
                stats.replica_fallbacks += fallbacks
                stats.bytes_by_tier[tier] = (
                    stats.bytes_by_tier.get(tier, 0) + nbytes)
        return named, stats


def _ordered_tiers(sources: list[str], by_tier: dict, index: int) -> list[str]:
    """Source order for one task: non-peer tiers keep their position, the
    peer subset is rotated by ``index`` (round-robin) so consecutive tasks
    start on different warm peers — that is the bandwidth aggregation."""
    avail = [t for t in sources if by_tier.get(t)]
    peers = [t for t in avail if is_peer_tier(t)]
    if len(peers) <= 1:
        return avail
    k = index % len(peers)
    rotated = iter(peers[k:] + peers[:k])
    return [next(rotated) if is_peer_tier(t) else t for t in avail]
