"""Background checkpoint worker pools (beyond-paper optimization).

``WorkPool`` is the shared primitive: a small pool of daemon threads with a
bounded in-flight count — ``submit`` blocks once the bound is hit, which is
the backpressure knob for everything the checkpoint plane runs off the
training thread.  Three users:

* ``AsyncWriter`` (save path): the paper's DMTCP checkpoint is synchronous —
  user threads quiesce for the whole image write (the CPU dips in its
  Fig. 4).  Here the quiesce only lasts for the device->host snapshot
  (double buffer); serialization + store writes run on the pool overlapped
  with training.  Every pending write pins a full host snapshot via its
  closure, so the in-flight bound is a memory bound.
* tier promotion (restore path): ``CheckpointManager`` tees restored shard
  bytes into the node-local tier write-behind on a ``WorkPool`` so the
  restore returns as soon as the state is materialized — the copy into the
  container-image-cache-like tier never blocks the restart.
* chunk hashing (delta save path): ``serialization.ChunkHashEngine`` fans
  every leaf's blake2b/CRC chunk digests across a pool — both primitives
  release the GIL on multi-KB buffers, so the hash pass scales with memory
  bandwidth instead of single-core hash speed.  The pre-dump (``precommit``)
  phase additionally runs whole hash+pre-write passes as single pool tasks,
  overlapped with the next training step.

``wait()`` drains the queue — called before a requeue/exit so the last image
is durable, and by the two-phase coordinator barrier before WRITTEN is sent.
"""
from __future__ import annotations

import os
import queue
import threading
import traceback
from typing import Callable, Optional


class WorkPool:
    """Bounded-in-flight daemon thread pool.

    ``max_inflight`` bounds TOTAL unfinished tasks (queued + executing);
    ``submit`` blocks when the producer outpaces the consumers.  The first
    task exception is re-raised on the producer thread at the next
    ``submit``/``wait`` (tasks after a failure still run — each task must be
    independently meaningful, which checkpoint writes and promotions are).
    """

    def __init__(self, max_inflight: int = 3, workers: int = 1,
                 name: str = "ckpt-pool"):
        self._max_inflight = max(1, max_inflight)
        workers = min(max(1, workers), self._max_inflight)
        self.workers = workers          # resolved size, for bench run_meta
        self._q: queue.Queue = queue.Queue()   # _inflight gate does the bounding
        self._err: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._inflight = 0
        self._done = threading.Condition()
        self._closed = False
        self._threads = [
            threading.Thread(target=self._run, daemon=True, name=f"{name}-{i}")
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()

    def _run(self):
        while True:
            fn = self._q.get()
            if fn is None:
                return
            try:
                fn()
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                with self._lock:
                    self._err = e
                traceback.print_exc()
            finally:
                with self._done:
                    self._inflight -= 1
                    self._done.notify_all()

    def submit(self, fn: Callable[[], None]) -> None:
        if self._closed:
            raise RuntimeError(f"{type(self).__name__} is closed")
        self.raise_if_failed()
        with self._done:
            self._done.wait_for(lambda: self._inflight < self._max_inflight)
            self._inflight += 1
        self._q.put(fn)

    def try_submit(self, fn: Callable[[], None]) -> bool:
        """Non-blocking submit: False when the in-flight bound is reached.
        For best-effort work (tier promotion) that must never apply
        backpressure to the caller — dropping is the correct behavior for an
        opportunistic cache."""
        if self._closed:
            raise RuntimeError(f"{type(self).__name__} is closed")
        self.raise_if_failed()
        with self._done:
            if self._inflight >= self._max_inflight:
                return False
            self._inflight += 1
        self._q.put(fn)
        return True

    def wait(self, timeout: Optional[float] = None) -> None:
        with self._done:
            self._done.wait_for(lambda: self._inflight == 0, timeout=timeout)
        self.raise_if_failed()

    def raise_if_failed(self) -> None:
        with self._lock:
            if self._err is not None:
                err, self._err = self._err, None
                raise RuntimeError("background checkpoint task failed") from err

    def close(self) -> None:
        """Drain, stop the threads, then surface any task failure.  The
        thread teardown runs even when a task failed — a raising ``close``
        must not leak pool threads or leave the pool half-open."""
        if self._closed:
            return
        try:
            self.wait()
        finally:
            self._closed = True
            for _ in self._threads:
                self._q.put(None)
            for t in self._threads:
                t.join(timeout=5)


class AsyncWriter(WorkPool):
    """Save-path pool: the default in-flight bound matches the seed's memory
    budget (2 queued + 1 executing host snapshots)."""

    def __init__(self, max_inflight: int = 3, workers: Optional[int] = None):
        if workers is None:
            workers = max(2, min(4, (os.cpu_count() or 2) // 2))
        super().__init__(max_inflight=max_inflight, workers=workers,
                         name="ckpt-writer")
