"""Background checkpoint writer pool (beyond-paper optimization).

The paper's DMTCP checkpoint is synchronous: user threads are quiesced for the
whole image write (the CPU dips in its Fig. 4).  Here the quiesce only lasts
for the device->host snapshot (double buffer); serialization + store writes
run on a small pool of daemon threads overlapped with training.  A pool (not a
single thread) lets independent saves — shards of consecutive steps, or the
several worker shards a single process hosts in tests/simulation — stream
concurrently: the CRC folding of one shard overlaps the kernel writes of
another (within one shard the same overlap comes from the store's fan-out
sink threads).
``wait()`` drains the queue — called before a requeue/exit so the last image
is durable, and by the two-phase coordinator barrier before WRITTEN is sent.
"""
from __future__ import annotations

import os
import queue
import threading
import traceback
from typing import Callable, Optional


class AsyncWriter:
    def __init__(self, max_inflight: int = 3, workers: Optional[int] = None):
        # ``max_inflight`` bounds TOTAL unfinished tasks (queued + executing).
        # Every pending checkpoint write pins a full host snapshot via its
        # closure, so this is the memory backpressure knob — the default
        # matches the seed's bound (2 queued + 1 executing); ``submit`` blocks
        # when the training loop outpaces the store.
        if workers is None:
            workers = max(2, min(4, (os.cpu_count() or 2) // 2))
        self._max_inflight = max(1, max_inflight)
        workers = min(workers, self._max_inflight)
        self._q: queue.Queue = queue.Queue()   # _inflight gate does the bounding
        self._err: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._inflight = 0
        self._done = threading.Condition()
        self._closed = False
        self._threads = [
            threading.Thread(target=self._run, daemon=True, name=f"ckpt-writer-{i}")
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()

    def _run(self):
        while True:
            fn = self._q.get()
            if fn is None:
                return
            try:
                fn()
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                with self._lock:
                    self._err = e
                traceback.print_exc()
            finally:
                with self._done:
                    self._inflight -= 1
                    self._done.notify_all()

    def submit(self, fn: Callable[[], None]) -> None:
        if self._closed:
            raise RuntimeError("AsyncWriter is closed")
        self.raise_if_failed()
        with self._done:
            self._done.wait_for(lambda: self._inflight < self._max_inflight)
            self._inflight += 1
        self._q.put(fn)

    def wait(self, timeout: Optional[float] = None) -> None:
        with self._done:
            self._done.wait_for(lambda: self._inflight == 0, timeout=timeout)
        self.raise_if_failed()

    def raise_if_failed(self) -> None:
        with self._lock:
            if self._err is not None:
                err, self._err = self._err, None
                raise RuntimeError("async checkpoint write failed") from err

    def close(self) -> None:
        if self._closed:
            return
        self.wait()
        self._closed = True
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join(timeout=5)
