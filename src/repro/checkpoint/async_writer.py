"""Background checkpoint writer (beyond-paper optimization).

The paper's DMTCP checkpoint is synchronous: user threads are quiesced for the
whole image write (the CPU dips in its Fig. 4).  Here the quiesce only lasts for
the device->host snapshot (double buffer); the serialization + store write run
on a daemon thread overlapped with training.  ``wait()`` drains the queue —
called before a requeue/exit so the last image is durable, and by the two-phase
coordinator barrier before WRITTEN is sent.
"""
from __future__ import annotations

import queue
import threading
import traceback
from typing import Callable, Optional


class AsyncWriter:
    def __init__(self, max_inflight: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max_inflight)
        self._err: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._inflight = 0
        self._done = threading.Condition()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            fn = item
            try:
                fn()
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                with self._lock:
                    self._err = e
                traceback.print_exc()
            finally:
                with self._done:
                    self._inflight -= 1
                    self._done.notify_all()

    def submit(self, fn: Callable[[], None]) -> None:
        self.raise_if_failed()
        with self._done:
            self._inflight += 1
        self._q.put(fn)

    def wait(self, timeout: Optional[float] = None) -> None:
        with self._done:
            self._done.wait_for(lambda: self._inflight == 0, timeout=timeout)
        self.raise_if_failed()

    def raise_if_failed(self) -> None:
        with self._lock:
            if self._err is not None:
                err, self._err = self._err, None
                raise RuntimeError("async checkpoint write failed") from err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=5)
