"""Batched ranged-read backend: vectored submission, direct I/O, capability
probes.

The restore engine plans whole checkpoints as lists of ``(path, offset,
nbytes)`` descriptors; this module drains such a batch with as few syscalls
and as little kernel-side copying as the host allows:

* **preadv** — per-file descriptor groups are submitted as ONE vectored
  positional read (``os.preadv``), so a plan's coalesced runs against one
  shard cost one syscall instead of one per range.
* **O_DIRECT** — for tiers backed by a real (cold/shared) filesystem the
  reader can bypass the page cache: offsets/lengths are aligned down/up to
  the probed alignment and the destination buffers are page-aligned
  ``mmap`` allocations, as O_DIRECT requires.  Fixed-size chunks mean the
  alignment waste is a few hundred bytes per range, not a re-read.  The
  probe is per-directory and cached: filesystems that reject O_DIRECT
  (older tmpfs, some overlayfs) degrade to buffered reads, never error.
* **io_uring** — probed, not required: when a liburing shared object is
  present AND ``REPRO_IO_URING=1`` opts in, the submission loop could ride
  a real ring; this container has no liburing, so the probe reports
  unavailable and the preadv path serves.  The probe exists so the backend
  choice is a measured capability, not a build flag.
* **thread fallback** — hosts without ``os.preadv`` (non-POSIX) drain the
  batch with per-range ``pread``-style reads; same results, more syscalls.

Results are positional: ``read_ranges`` returns one ``bytes`` per request,
with failures returned as ``Exception`` instances (not raised), so a caller
holding a multi-source fallback chain can retry exactly the ranges that
failed instead of resubmitting the batch.
"""
from __future__ import annotations

import ctypes.util
import dataclasses
import logging
import mmap
import os
import threading
from pathlib import Path
from typing import Optional

log = logging.getLogger(__name__)

ENV_IO_URING = "REPRO_IO_URING"

HAVE_PREADV = hasattr(os, "preadv")
# Linux caps a single readv/preadv submission at IOV_MAX iovecs
IOV_MAX = 1024
_PAGE = mmap.PAGESIZE


@dataclasses.dataclass(frozen=True)
class IOCapabilities:
    """What the probed filesystem/host actually supports."""
    preadv: bool
    direct_io: bool
    alignment: int          # O_DIRECT offset/length/buffer alignment (bytes)
    io_uring: bool


def io_uring_available() -> bool:
    """True only when a liburing shared object is loadable AND the operator
    opted in via ``REPRO_IO_URING=1``.  Opt-in because the binding is the
    least-traveled path; the preadv backend is the default everywhere."""
    if os.environ.get(ENV_IO_URING, "").strip() != "1":
        return False
    return ctypes.util.find_library("uring") is not None


# -- O_DIRECT probe ---------------------------------------------------------

_DIRECT_CACHE: dict[str, Optional[int]] = {}
_DIRECT_LOCK = threading.Lock()


def probe_direct_io(directory) -> Optional[int]:
    """O_DIRECT alignment for files under ``directory``, or ``None`` when
    the filesystem rejects direct I/O (tmpfs on older kernels, overlayfs).

    Probed once per directory with a scratch file and cached — the probe is
    a filesystem property, not a file property.  The returned alignment is
    the logical block size when discoverable, else one page (always a legal
    O_DIRECT alignment on Linux)."""
    if not hasattr(os, "O_DIRECT") or not HAVE_PREADV:
        return None
    key = str(Path(directory))
    with _DIRECT_LOCK:
        if key in _DIRECT_CACHE:
            return _DIRECT_CACHE[key]
    align: Optional[int] = None
    probe = Path(directory) / f".directio_probe.{os.getpid()}"
    try:
        with open(probe, "wb") as f:
            f.write(b"\0" * _PAGE)
        fd = os.open(probe, os.O_RDONLY | os.O_DIRECT)
        try:
            buf = mmap.mmap(-1, _PAGE)
            try:
                if os.preadv(fd, [buf], 0) == _PAGE:
                    try:
                        align = os.statvfs(probe).f_bsize or _PAGE
                    except OSError:
                        align = _PAGE
                    align = max(512, min(int(align), _PAGE * 16))
            finally:
                buf.close()
        finally:
            os.close(fd)
    except OSError:
        align = None
    finally:
        try:
            probe.unlink()
        except OSError:
            pass
    with _DIRECT_LOCK:
        _DIRECT_CACHE[key] = align
    return align


def reset_direct_io_cache() -> None:
    """Test hook: forget probe results (e.g. after monkeypatching os.open)."""
    with _DIRECT_LOCK:
        _DIRECT_CACHE.clear()


def capabilities(directory) -> IOCapabilities:
    align = probe_direct_io(directory)
    return IOCapabilities(preadv=HAVE_PREADV,
                          direct_io=align is not None,
                          alignment=align or 0,
                          io_uring=io_uring_available())


# -- batched submission -----------------------------------------------------

def _group_by_file(requests):
    """Coalesce a batch per file, preserving request order inside each group.
    Returns ``[(path, [(orig_index, offset, nbytes)...])...]``."""
    groups: dict[str, list] = {}
    paths: dict[str, Path] = {}
    for i, (path, offset, nbytes) in enumerate(requests):
        key = str(path)
        paths.setdefault(key, Path(path))
        groups.setdefault(key, []).append((i, offset, nbytes))
    return [(paths[k], v) for k, v in groups.items()]


def _drain_preadv(fd: int, reqs: list, results: list) -> None:
    """One (or a few, IOV_MAX-capped) vectored submissions for all ranges of
    one file.  Short reads surface as OSError in that range's slot only."""
    for start in range(0, len(reqs), IOV_MAX):
        window = reqs[start:start + IOV_MAX]
        bufs = [bytearray(n) for _, _, n in window]
        # one submission per contiguous offset run; ranges at arbitrary
        # offsets each need their own preadv position, so split the window
        # wherever the file offset jumps
        j = 0
        while j < len(window):
            k = j
            pos = window[j][1]
            end = pos
            while (k < len(window) and window[k][1] == end):
                end += window[k][2]
                k += 1
            got = os.preadv(fd, bufs[j:k], pos)
            want = end - pos
            if got != want:
                # a short vectored read torn across ranges: mark each range
                # in this submission by how many of its bytes arrived
                seen = got
                for idx in range(j, k):
                    i, _, n = window[idx]
                    if seen >= n:
                        results[i] = bytes(bufs[idx])
                        seen -= n
                    else:
                        results[i] = OSError(
                            f"short read {max(seen, 0)}/{n} at "
                            f"offset {window[idx][1]}")
                        seen = 0
            else:
                for idx in range(j, k):
                    results[window[idx][0]] = bytes(bufs[idx])
            j = k


def _drain_direct(path: Path, reqs: list, results: list,
                  align: int) -> None:
    """O_DIRECT drain for one file: offsets aligned down, lengths aligned
    up, destination buffers page-aligned (anonymous mmap satisfies any
    sub-page alignment).  Reads past EOF are clamped by the kernel; the
    caller's short-read check stays with the caller."""
    fd = os.open(path, os.O_RDONLY | os.O_DIRECT)
    try:
        size = os.fstat(fd).st_size
        for i, offset, nbytes in reqs:
            lo = (offset // align) * align
            hi = -(-(offset + nbytes) // align) * align
            span = hi - lo
            # mmap length must be page-rounded; the extra tail is unused
            buf = mmap.mmap(-1, -(-span // _PAGE) * _PAGE)
            try:
                view = memoryview(buf)[:span]
                got = os.preadv(fd, [view], lo)
                # bytes of the REQUESTED range that actually arrived: the
                # aligned read starts at lo, so the first (offset - lo)
                # bytes are alignment padding, and EOF clamps the tail
                avail = max(0, min(got, size - lo) - (offset - lo))
                take = min(nbytes, avail)
                results[i] = bytes(view[offset - lo:offset - lo + take])
                del view
            finally:
                buf.close()
    finally:
        os.close(fd)


def _drain_seek_read(path: Path, reqs: list, results: list) -> None:
    """Portable fallback: one buffered handle, seek+read per range."""
    with open(path, "rb") as fp:
        for i, offset, nbytes in reqs:
            fp.seek(offset)
            results[i] = fp.read(nbytes)


def read_ranges(requests, *, direct_align: Optional[int] = None,
                open_fd=None, close_fd=None):
    """Drain one batch of ``(path, offset, nbytes)`` requests.

    Returns a list aligned with ``requests``: ``bytes`` per success (short
    reads included — length checking is the caller's contract, matching
    ``TieredStore._pread``), or the ``Exception`` per failed range.

    ``direct_align``: when set, files are read O_DIRECT at that alignment
    (the caller probed it for this batch's tier root); an O_DIRECT open
    failing mid-batch degrades to buffered for that file.  ``open_fd`` /
    ``close_fd``: optional hooks to source buffered descriptors from a
    cache (the store lends its refcounted fd cache) instead of open/close
    per file."""
    requests = list(requests)
    results: list = [None] * len(requests)
    for path, reqs in _group_by_file(requests):
        try:
            if direct_align:
                try:
                    _drain_direct(path, reqs, results, direct_align)
                    continue
                except OSError as e:
                    log.debug("O_DIRECT read of %s failed (%s); "
                              "falling back to buffered", path, e)
            if HAVE_PREADV:
                if open_fd is not None:
                    handle = open_fd(path)
                    try:
                        _drain_preadv(handle[0], reqs, results)
                    finally:
                        if close_fd is not None:
                            close_fd(path, handle)
                else:
                    fd = os.open(path, os.O_RDONLY)
                    try:
                        _drain_preadv(fd, reqs, results)
                    finally:
                        os.close(fd)
            else:                       # pragma: no cover - non-POSIX hosts
                _drain_seek_read(path, reqs, results)
        except OSError as e:
            for i, _, _ in reqs:
                if results[i] is None:
                    results[i] = e
    return results
