"""Calibrated tier profiles: measured bandwidth/latency instead of guesses.

``DEFAULT_TIERS`` carries hand-written numbers (40 GB/s ram, 1 GB/s shared
parallel FS) that shape everything downstream — ``_simulate`` sleep times in
benchmarks, ``tier_slots`` concurrency budgets, and through those the
restore pool sizing (``auto_workers`` caps at the summed concurrency of the
source tiers).  On a real host those guesses are wrong in both directions:
tmpfs reads run at memory speed, an NFS-backed "shared" root may be 50x
slower than the guess.  ``calibrate_tiers`` replaces the guesswork with a
short measurement against each tier's actual backing directory:

* **sequential bandwidth** — one scratch file written, then read back start
  to finish; the read side is timed (write speed is not what restore cares
  about).
* **random-read latency + bandwidth** — N positional reads at seeded-random
  offsets; the per-op time in excess of the pure transfer time is the
  latency estimate.
* **concurrency** — the bandwidth-delay product: how many in-flight ranged
  reads it takes to cover the measured latency at the measured bandwidth
  (clamped to a sane [2, 32] band).  That is exactly the number
  ``tier_slots`` should admit and ``auto_workers`` should cap at.

Results are cached as one atomic JSON file (``tier_profile.json`` under the
store root, via ``repro.utils.atomic``) so a fleet of restore processes pays
the probe once per node, not once per process; ``max_age_s`` bounds staleness
and ``force=True`` re-measures.  Measurements deliberately bypass
``TieredStore`` — calibration reads the real filesystem, never the simulated
costs it exists to replace.

Peer tiers (``peer:<node>``) are never probed: their roots belong to another
node and a calibration write there would be a cross-node side effect.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Optional

from repro.checkpoint import io_backend as IOB
from repro.checkpoint.store import is_peer_tier
from repro.utils.atomic import atomic_write_json

CALIB_FILENAME = "tier_profile.json"
CALIB_VERSION = 1
DEFAULT_MAX_AGE_S = 24 * 3600.0

# probe sizing: big enough that per-syscall overhead does not dominate the
# sequential number, small enough that calibrating a slow shared FS stays
# well under a second of I/O
PROBE_FILE_BYTES = 8 << 20
PROBE_RANGE_BYTES = 256 << 10
PROBE_RANGES = 32

_MIN_CONC, _MAX_CONC = 2, 32


def _bdp_concurrency(bandwidth_gbps: float, latency_s: float,
                     range_bytes: int = PROBE_RANGE_BYTES) -> int:
    """In-flight ranged reads needed to keep the pipe full: the classic
    bandwidth-delay product, in units of one typical restore range."""
    per_range_s = range_bytes / max(bandwidth_gbps * 1e9, 1.0)
    need = (latency_s + per_range_s) / max(per_range_s, 1e-9)
    return max(_MIN_CONC, min(_MAX_CONC, round(need)))


def _measure_root(directory: Path, *, file_bytes: int = PROBE_FILE_BYTES,
                  range_bytes: int = PROBE_RANGE_BYTES,
                  ranges: int = PROBE_RANGES) -> dict:
    """Measure one backing directory.  Returns the raw numbers; interpreting
    them into a TierSpec is ``calibrate_tiers``'s job."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    scratch = directory / f".tier_probe.{os.getpid()}"
    # incompressible-ish payload: a repeated urandom page, so a filesystem
    # with transparent compression cannot flatter the read numbers much
    # while the probe stays cheap to generate
    page = os.urandom(min(file_bytes, 1 << 20))
    reps = -(-file_bytes // len(page))
    try:
        with open(scratch, "wb") as fp:
            for _ in range(reps):
                fp.write(page)
            fp.flush()
            os.fsync(fp.fileno())
        size = scratch.stat().st_size

        fd = os.open(scratch, os.O_RDONLY)
        try:
            t0 = time.perf_counter()
            pos = 0
            while pos < size:
                got = os.pread(fd, 4 << 20, pos)
                if not got:
                    break
                pos += len(got)
            seq_s = max(time.perf_counter() - t0, 1e-9)

            # seeded offsets: the probe is deterministic for a given file
            # size, so two processes racing the cache measure the same plan
            step = max((size - range_bytes) // max(ranges, 1), 1)
            offsets = [(i * step * 2654435761) % max(size - range_bytes, 1)
                       for i in range(ranges)]
            t0 = time.perf_counter()
            for off in offsets:
                os.pread(fd, range_bytes, off)
            rand_s = max(time.perf_counter() - t0, 1e-9)
        finally:
            os.close(fd)
    finally:
        try:
            scratch.unlink()
        except OSError:
            pass

    seq_gbps = size / seq_s / 1e9
    rand_gbps = (range_bytes * ranges) / rand_s / 1e9
    # per-op time not explained by pure transfer at sequential speed is the
    # access latency; floor at 1us so a fully-cached tmpfs never yields zero
    per_op = rand_s / max(ranges, 1)
    xfer = range_bytes / max(seq_gbps * 1e9, 1.0)
    latency_s = max(per_op - xfer, 1e-6)
    return {
        "seq_gbps": round(seq_gbps, 4),
        "rand_gbps": round(rand_gbps, 4),
        "latency_s": round(latency_s, 7),
        "file_bytes": size,
        "range_bytes": range_bytes,
        "ranges": ranges,
        "direct_align": IOB.probe_direct_io(directory),
    }


def _load_cached(path: Path, max_age_s: float) -> Optional[dict]:
    try:
        profile = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    if profile.get("version") != CALIB_VERSION:
        return None
    if time.time() - float(profile.get("t", 0)) > max_age_s:
        return None
    if not isinstance(profile.get("roots"), dict):
        return None
    return profile


def apply_profile(store, profile: dict) -> dict:
    """Overwrite the store's TierSpec numbers with a profile's measurements.
    Returns ``{tier: TierSpec}`` of the specs actually replaced.  Tiers whose
    root was not measured (peers, unknown roots) keep their current spec."""
    applied = {}
    for tier, spec in list(store.tiers.items()):
        if is_peer_tier(tier):
            continue
        root = str(store.tier_roots.get(tier, store.root))
        m = profile["roots"].get(root)
        if not m:
            continue
        new = dataclasses.replace(
            spec,
            bandwidth_gbps=max(float(m["seq_gbps"]), 1e-3),
            latency_s=float(m["latency_s"]),
            concurrency=_bdp_concurrency(float(m["seq_gbps"]),
                                         float(m["latency_s"])))
        store.tiers[tier] = new
        applied[tier] = new
    # concurrency semaphores are created lazily per tier and cached; drop
    # them so the calibrated budgets take effect for the next restore
    with store._sems_lock:
        store._sems.clear()
    return applied


def calibrate_tiers(store, *, path=None, max_age_s: float = DEFAULT_MAX_AGE_S,
                    force: bool = False,
                    file_bytes: int = PROBE_FILE_BYTES,
                    range_bytes: int = PROBE_RANGE_BYTES,
                    ranges: int = PROBE_RANGES) -> dict:
    """Measure (or load the cached measurement of) every tier root and apply
    the results onto ``store.tiers``.  Returns the profile dict.

    One measurement per UNIQUE backing directory: tiers sharing a root (ram
    and local both mounted on one node-local disk) share one probe and get
    the same numbers, which is the truth — they ARE the same device."""
    path = Path(path) if path is not None else Path(store.root) / CALIB_FILENAME
    profile = None if force else _load_cached(path, max_age_s)
    roots = {}
    for tier in store.tiers:
        if is_peer_tier(tier):
            continue
        roots.setdefault(str(store.tier_roots.get(tier, store.root)), tier)
    if profile is None or set(profile["roots"]) != set(roots):
        measured = {root: _measure_root(Path(root), file_bytes=file_bytes,
                                        range_bytes=range_bytes,
                                        ranges=ranges)
                    for root in roots}
        profile = {"version": CALIB_VERSION, "t": time.time(),
                   "roots": measured}
        try:
            atomic_write_json(path, profile)
        except OSError:
            pass            # cache is an optimization; the numbers still apply
    apply_profile(store, profile)
    return profile
