"""CheckpointPolicy — the typed policy surface of ``CheckpointManager``.

Six PRs grew ``CheckpointManager.__init__`` into keyword soup: storage
placement (``tier``/``replicas``/``prefix``), write pipeline (``mode``/
``shard_format``), the delta/chunk plane (``delta``/``chunk_bytes``/
``rebase_every``/``fingerprint``/``hash_workers``), retention
(``keep_last``), restore sizing (``restore_workers``) and cache promotion
(``promote``/``promote_tier``).  Those are POLICY — how checkpoints are
written, kept and restored — as opposed to the manager's IDENTITY kwargs
(``worker_id``/``num_workers``/``node``/``peer_roots``/``registry``), which
say who this manager is inside the cluster.

This dataclass is the policy half, validated once at construction so an
invalid combination fails where it is written, not mid-save on a pool
thread.  ``CheckpointManager(store, CheckpointPolicy(...))`` is the
supported construction; the old flat kwargs still work through a
deprecation shim (see ``CheckpointManager.__init__``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

PROMOTE_POLICIES = ("off", "on_restore", "eager")

# fixed-size chunking default lives in serialization.DELTA_CHUNK_BYTES;
# ``chunk_bytes=None`` means "use that default", resolved by the manager so
# the policy stays a pure value object with no import cycle
_MODES = ("sync", "async")
_SHARD_FORMATS = (1, 2)


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """How checkpoints are written, retained, promoted and restored.

    Field groups (the old ``CheckpointManager.__init__`` keyword soup,
    now typed and validated together):

    * placement: ``tier``, ``replicas``, ``prefix``
    * write pipeline: ``mode`` ("sync"/"async"), ``shard_format``,
      ``incremental``
    * delta/chunk plane: ``delta``, ``chunk_bytes``, ``rebase_every``,
      ``fingerprint``, ``hash_workers`` (pre-dump rides on these — see
      ``CheckpointManager.precommit``), ``compress`` (per-chunk frame
      level: 0 = frameless raw, >=1 = zstd/zlib at that level; hashes stay
      over uncompressed content so dedup and fingerprints are unaffected)
    * retention: ``keep_last``
    * restore: ``restore_workers`` (0 = auto, 1 = serial), ``io_batch``
      (ranges per batched read submission: 0 = $REPRO_IO_BATCH / default,
      1 = per-range reads)
    * promotion: ``promote`` ("off"/"on_restore"/"eager"), ``promote_tier``
    """

    # -- placement ------------------------------------------------------
    tier: str = "shared"
    replicas: int = 2
    prefix: str = "ckpt"
    # -- write pipeline -------------------------------------------------
    mode: str = "sync"
    shard_format: int = 2          # 1 = legacy writer (compat tests)
    incremental: bool = False
    # -- delta / chunk plane --------------------------------------------
    delta: bool = False
    chunk_bytes: Optional[int] = None      # None -> DELTA_CHUNK_BYTES
    rebase_every: int = 8
    fingerprint: bool = False
    device_fp: bool = False        # dirty detection on device (delta only)
    hash_workers: int = 0
    compress: int = 0              # per-chunk frame level; 0 = frameless raw
    # -- retention ------------------------------------------------------
    keep_last: int = 3
    # -- restore --------------------------------------------------------
    restore_workers: int = 0
    io_batch: int = 0              # ranges per submission; 0 = env/default
    # -- promotion ------------------------------------------------------
    promote: str = "off"
    promote_tier: str = "local"

    def __post_init__(self) -> None:
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {self.mode!r}")
        if self.shard_format not in _SHARD_FORMATS:
            raise ValueError(
                f"shard_format must be one of {_SHARD_FORMATS}, "
                f"got {self.shard_format!r}")
        if self.promote not in PROMOTE_POLICIES:
            raise ValueError(
                f"promote must be one of {PROMOTE_POLICIES}, "
                f"got {self.promote!r}")
        # delta (v3 chunk plane) and incremental (v1/v2 leaf reuse) are two
        # answers to the same question; combining them would mix chunked and
        # file-based leaves inside one manifest for no gain
        if self.delta and self.incremental:
            raise ValueError("delta and incremental are exclusive")
        if self.rebase_every < 1:
            raise ValueError(
                f"rebase_every must be >= 1, got {self.rebase_every}")
        # the promote tier is a CACHE whose invalidation deletes files —
        # pointing it at the primary tier would let a stale-cache cleanup
        # destroy the committed checkpoints themselves
        if self.promote != "off" and self.promote_tier == self.tier:
            raise ValueError(
                "promote_tier must differ from the primary checkpoint tier")
        # fingerprints (fingerprint=True and every precommit) view a chunk
        # as a padded <u4 word stream, so an unaligned chunk size must fail
        # HERE — not mid-save, and not on a pre-dump pool thread where the
        # ValueError would only surface at the next wait()
        if (self.delta and self.chunk_bytes is not None
                and (self.chunk_bytes < 4 or self.chunk_bytes % 4)):
            raise ValueError(
                "delta chunk_bytes must be a positive multiple of 4 "
                f"(fingerprint word stream), got {self.chunk_bytes}")
        # device_fp runs the fingerprint kernel on live device residents and
        # gathers only fp-dirty chunks host-side — it IS a delta-plane mode
        if self.device_fp and not self.delta:
            raise ValueError("device_fp requires delta mode")
        # the Pallas fingerprint kernel folds its XOR reduction with a
        # reshape-halving tree, so the per-chunk word count must be a power
        # of two; fail at construction, not inside a jitted save
        if (self.device_fp and self.chunk_bytes is not None
                and (self.chunk_bytes // 4) & (self.chunk_bytes // 4 - 1)):
            raise ValueError(
                "device_fp chunk_bytes must be 4 * a power of two "
                f"(Pallas fold), got {self.chunk_bytes}")
        # 22 is zstd's max standard level; zlib callers are clamped to 9 at
        # frame time.  compress only shapes the chunk plane's on-disk frame,
        # so it is legal (and a no-op) without delta — but a negative level
        # is always a typo.
        if not 0 <= self.compress <= 22:
            raise ValueError(
                f"compress must be in [0, 22], got {self.compress}")
        if self.io_batch < 0:
            raise ValueError(
                f"io_batch must be >= 0 (0 = auto), got {self.io_batch}")

    # field-name set for the __init__ shim (and the shim-equivalence test)
    @classmethod
    def field_names(cls) -> tuple[str, ...]:
        return tuple(f.name for f in dataclasses.fields(cls))
