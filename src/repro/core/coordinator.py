"""Checkpoint coordinator — the framework's ``dmtcp_coordinator``.

A TCP server coordinating checkpoint rounds across worker checkpoint threads
with a two-phase barrier:

  phase 1 (quiesce):  CKPT_REQ -> all workers; wait for READY from every live
                      worker (each worker is parked at a step boundary).
  phase 2 (write):    workers snapshot + write their shards; wait for WRITTEN.
  commit:             verify parts, write MANIFEST.json atomically, broadcast
                      COMMIT.  Any FAILED / disconnect / straggler timeout
                      instead broadcasts ABORT — no manifest, the previous
                      checkpoint stays authoritative.

Straggler mitigation: a worker that misses ``straggler_timeout`` in either
phase fails the round (and is dropped if its socket died); the job-level
requeue logic decides whether to retry with the survivors (elastic restart).

Like DMTCP, multiple independent coordinators can run (one per job) — they are
plain instances bound to distinct ports.  Periodic checkpointing (`interval_s`)
matches ``dmtcp_coordinator -i``.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Callable, Optional

from repro.core import protocol as P


class _WorkerConn:
    def __init__(self, sock: socket.socket, addr):
        self.sock = sock
        self.addr = addr
        self.worker_id: Optional[int] = None
        self.alive = True
        self.lock = threading.Lock()

    def send(self, message: dict) -> bool:
        with self.lock:
            if not self.alive:
                return False
            try:
                P.send_msg(self.sock, message)
                return True
            except OSError:
                self.alive = False
                return False


class CoordinatorError(RuntimeError):
    pass


class CheckpointCoordinator:
    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 expected_workers: int = 1,
                 straggler_timeout: float = 120.0,
                 interval_s: Optional[float] = None,
                 commit_fn: Optional[Callable[[int, int], dict]] = None,
                 log: Callable[[str], None] = lambda s: None):
        """``commit_fn(step, num_workers)`` writes the manifest (usually
        ``CheckpointManager.commit``); called only when all workers WROTE."""
        self.expected_workers = expected_workers
        self.straggler_timeout = straggler_timeout
        self.interval_s = interval_s
        self.commit_fn = commit_fn
        self.log = log
        self._conns: dict[int, _WorkerConn] = {}
        self._conns_lock = threading.Lock()
        self._round_lock = threading.Lock()
        self._round_cv = threading.Condition(self._round_lock)
        self._round_id = 0
        self._acks: dict[str, set[int]] = {}
        self._failed: set[int] = set()
        self._written_meta: dict[int, dict] = {}
        self._stop = threading.Event()
        self._history: list[dict] = []

        self._srv = socket.create_server((host, port))
        self.host, self.port = self._srv.getsockname()
        self._accept_thread = threading.Thread(target=self._accept_loop, daemon=True)
        self._accept_thread.start()
        self._interval_thread = None
        if interval_s:
            self._interval_thread = threading.Thread(
                target=self._interval_loop, daemon=True)
            self._interval_thread.start()

    # ------------------------------------------------------------------
    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                self._srv.settimeout(0.2)
                sock, addr = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            conn = _WorkerConn(P.configure(sock), addr)
            threading.Thread(target=self._serve_conn, args=(conn,), daemon=True).start()

    def _serve_conn(self, conn: _WorkerConn):
        try:
            while not self._stop.is_set():
                m = P.recv_msg(conn.sock, timeout=1.0)
                if m is None:
                    break
                self._handle(conn, m)
        except socket.timeout:
            if not self._stop.is_set():
                # keep listening; timeouts are normal between messages
                return self._serve_conn(conn)
        except OSError:
            pass
        finally:
            conn.alive = False
            if conn.worker_id is not None:
                self.log(f"worker {conn.worker_id} disconnected")
                with self._round_cv:
                    self._failed.add(conn.worker_id)
                    self._round_cv.notify_all()

    def _handle(self, conn: _WorkerConn, m: dict):
        kind = m.get("type")
        if kind == P.INTRO:
            conn.worker_id = int(m["worker_id"])
            with self._conns_lock:
                self._conns[conn.worker_id] = conn
            self.log(f"worker {conn.worker_id} connected")
            return
        wid = conn.worker_id
        if wid is None:
            return
        if kind in (P.READY, P.WRITTEN, P.FAILED):
            with self._round_cv:
                if m.get("round") == self._round_id:
                    if kind == P.FAILED:
                        self._failed.add(wid)
                    else:
                        self._acks.setdefault(kind, set()).add(wid)
                        if kind == P.WRITTEN:
                            self._written_meta[wid] = m.get("meta", {})
                self._round_cv.notify_all()
        elif kind == P.BYE:
            conn.alive = False

    # ------------------------------------------------------------------
    def connected_workers(self) -> list[int]:
        with self._conns_lock:
            return sorted(w for w, c in self._conns.items() if c.alive)

    def wait_for_workers(self, n: Optional[int] = None, timeout: float = 60.0) -> None:
        n = n or self.expected_workers
        t0 = time.time()
        while len(self.connected_workers()) < n:
            if time.time() - t0 > timeout:
                raise CoordinatorError(
                    f"only {len(self.connected_workers())}/{n} workers connected")
            time.sleep(0.02)

    def _broadcast(self, message: dict, workers: list[int]) -> None:
        with self._conns_lock:
            for w in workers:
                c = self._conns.get(w)
                if c:
                    c.send(message)

    def _await_acks(self, kind: str, workers: set[int], timeout: float) -> bool:
        deadline = time.time() + timeout
        with self._round_cv:
            while True:
                got = self._acks.get(kind, set())
                if self._failed & workers:
                    return False
                if workers <= got:
                    return True
                remaining = deadline - time.time()
                if remaining <= 0:
                    self.log(f"straggler timeout waiting for {kind}: "
                             f"missing {sorted(workers - got)}")
                    return False
                self._round_cv.wait(timeout=min(remaining, 0.5))

    # ------------------------------------------------------------------
    def trigger_checkpoint(self, step: int, *, reason: str = "interval") -> dict:
        """Run one full two-phase checkpoint round.  Returns a result record."""
        with self._round_lock:
            self._round_id += 1
            rid = self._round_id
            self._acks = {}
            self._failed = set()
            self._written_meta = {}
        workers = set(self.connected_workers())
        # the checkpoint LABEL is coordinator-assigned: the caller's step when
        # known, else the round id (interval triggers).  Workers write their
        # shards under this label regardless of their local step counters, so
        # the round forms one consistent named cut.
        label = step if step >= 0 else rid
        rec = {"round": rid, "step": label, "reason": reason,
               "workers": sorted(workers), "t_start": time.time()}
        if not workers:
            rec.update(ok=False, error="no workers")
            self._history.append(rec)
            return rec
        self._broadcast(P.msg(P.CKPT_REQ, round=rid, step=label, reason=reason),
                        sorted(workers))
        if not self._await_acks(P.READY, workers, self.straggler_timeout):
            self._abort(rid, workers, rec, "quiesce barrier failed")
            return rec
        rec["t_quiesced"] = time.time()
        if not self._await_acks(P.WRITTEN, workers, self.straggler_timeout):
            self._abort(rid, workers, rec, "write barrier failed")
            return rec
        rec["t_written"] = time.time()
        try:
            manifest = (self.commit_fn(label, num_workers=len(workers))
                        if self.commit_fn else {"step": label})
        except Exception as e:  # noqa: BLE001
            self._abort(rid, workers, rec, f"commit failed: {e}")
            return rec
        self._broadcast(P.msg(P.COMMIT, round=rid, step=step), sorted(workers))
        rec.update(ok=True, t_commit=time.time(),
                   manifest_step=manifest.get("step"),
                   written_meta=self._written_meta)
        self._history.append(rec)
        self.log(f"checkpoint round {rid} (step {step}) committed")
        return rec

    def _abort(self, rid, workers, rec, why):
        self._broadcast(P.msg(P.ABORT, round=rid, reason=why), sorted(workers))
        rec.update(ok=False, error=why, t_abort=time.time())
        self._history.append(rec)
        self.log(f"checkpoint round {rid} ABORTED: {why}")

    def request_exit(self, reason: str = "preemption") -> None:
        """Ask every worker to checkpoint-and-exit (paper: SIGTERM propagation)."""
        self._broadcast(P.msg(P.EXIT_REQ, reason=reason), self.connected_workers())

    # ------------------------------------------------------------------
    def _interval_loop(self):
        last = time.time()
        while not self._stop.wait(0.2):
            if time.time() - last >= self.interval_s and self.connected_workers():
                self.trigger_checkpoint(step=-1, reason="interval")
                last = time.time()

    @property
    def history(self) -> list[dict]:
        return list(self._history)

    def close(self) -> None:
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass
