"""Signal trapping — the paper's ``func_trap`` / Slurm ``--signal`` handling.

Slurm sends SIGTERM (or a user-chosen USR1) ahead of the walltime limit; the
paper's script traps it, checkpoints, and requeues.  ``SignalTrap`` installs
handlers that only set flags — the training loop reads them at step boundaries
(async-signal-safe by construction: no jax calls in handler context).
"""
from __future__ import annotations

import signal
import threading
from typing import Iterable, Optional


class SignalTrap:
    def __init__(self, signals: Iterable[int] = (signal.SIGTERM, signal.SIGUSR1)):
        self.signals = tuple(signals)
        self._event = threading.Event()
        self.received: Optional[int] = None
        self._prev: dict[int, object] = {}

    def __enter__(self) -> "SignalTrap":
        for s in self.signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc) -> None:
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        self._prev.clear()

    def _handler(self, signum, frame) -> None:
        self.received = signum
        self._event.set()

    @property
    def triggered(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def reset(self) -> None:
        self._event.clear()
        self.received = None
