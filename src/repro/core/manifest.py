"""Run manifest — the "container image" of a training run.

The paper embeds DMTCP inside the container image so the restored process sees
identical libraries and env vars.  We cannot freeze a Python environment from
inside it, but we can capture and *verify* it: a manifest of library versions,
relevant env vars, and the config hash is written with every checkpoint; on
restore a mismatch is surfaced (warn or refuse), catching the
restored-into-a-different-image failure mode the containers prevent.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import sys
from dataclasses import asdict, is_dataclass
from typing import Optional

_ENV_KEYS = ("XLA_FLAGS", "JAX_PLATFORMS", "JAX_ENABLE_X64", "LD_LIBRARY_PATH")


def config_hash(cfg) -> str:
    d = asdict(cfg) if is_dataclass(cfg) else dict(cfg)
    return hashlib.sha256(json.dumps(d, sort_keys=True, default=str).encode()).hexdigest()[:16]


def capture_manifest(cfg=None, extra: Optional[dict] = None) -> dict:
    import jax
    import numpy as np

    man = {
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "backend": jax.default_backend(),
        "env": {k: os.environ.get(k, "") for k in _ENV_KEYS},
    }
    if cfg is not None:
        man["config_hash"] = config_hash(cfg)
        man["config_name"] = getattr(cfg, "name", "?")
    if extra:
        man.update(extra)
    return man


class ManifestMismatch(RuntimeError):
    pass


def verify_manifest(saved: dict, *, cfg=None, strict: bool = False,
                    log=print) -> list[str]:
    """Compare the saved manifest with the current environment.

    Returns the list of mismatches; raises in strict mode."""
    current = capture_manifest(cfg)
    problems = []
    for key in ("python", "jax", "numpy", "backend"):
        if key in saved and saved[key] != current[key]:
            problems.append(f"{key}: saved={saved[key]} current={current[key]}")
    if cfg is not None and saved.get("config_hash") not in (None, current["config_hash"]):
        problems.append("config_hash mismatch — model/config changed since checkpoint")
    for p in problems:
        log(f"[manifest] {p}")
    if problems and strict:
        raise ManifestMismatch("; ".join(problems))
    return problems
