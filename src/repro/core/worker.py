"""Worker-side checkpoint client — the framework's in-process "CKPT thread".

A daemon thread holds the coordinator socket (paper Fig. 1).  It cannot
interrupt XLA mid-step (DESIGN.md §2: instruction-level -> iteration-level
quiescence), so it raises flags that the training loop polls at step
boundaries via ``service()``:

    client = CkptClient(host, port, worker_id, save_fn=...)
    while training:
        state = train_step(state, batch)
        client.service(step, lambda: snapshot(state))   # quiesce point

``service`` handles a pending CKPT_REQ: sends READY (phase-1 barrier), runs the
save function, sends WRITTEN, then blocks for COMMIT/ABORT.  ``exit_requested``
becomes True on EXIT_REQ (coordinator-propagated preemption).
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Callable, Optional

from repro.core import protocol as P


class CkptClient:
    def __init__(self, host: str, port: int, worker_id: int, *,
                 connect_timeout: float = 30.0,
                 log: Callable[[str], None] = lambda s: None):
        self.worker_id = worker_id
        self.log = log
        self._sock = P.configure(
            socket.create_connection((host, port), timeout=connect_timeout))
        P.send_msg(self._sock, P.msg(P.INTRO, worker_id=worker_id))
        self._lock = threading.Lock()
        self._pending_req: Optional[dict] = None
        self._outcome: Optional[dict] = None
        self._cv = threading.Condition(self._lock)
        self.exit_requested = False
        self.exit_reason: Optional[str] = None
        self._closed = False
        self._rx = threading.Thread(target=self._recv_loop, daemon=True)
        self._rx.start()

    # ------------------------------------------------------------------
    def _recv_loop(self):
        while not self._closed:
            try:
                m = P.recv_msg(self._sock, timeout=0.5)
            except socket.timeout:
                continue
            except OSError:
                return
            if m is None:
                return
            kind = m.get("type")
            with self._cv:
                if kind == P.CKPT_REQ:
                    self._pending_req = m
                elif kind in (P.COMMIT, P.ABORT):
                    self._outcome = m
                elif kind == P.EXIT_REQ:
                    self.exit_requested = True
                    self.exit_reason = m.get("reason")
                self._cv.notify_all()

    def _send(self, m: dict):
        try:
            P.send_msg(self._sock, m)
        except OSError as e:
            raise CoordinatorLost(str(e)) from e

    # ------------------------------------------------------------------
    def checkpoint_pending(self) -> bool:
        with self._lock:
            return self._pending_req is not None

    def service(self, step: int, save_fn: Callable[[], dict],
                *, commit_timeout: float = 300.0) -> Optional[dict]:
        """Call at every step boundary.  Runs a checkpoint round if requested.

        ``save_fn(label)`` must perform this worker's snapshot+write under the
        coordinator-assigned checkpoint ``label`` and return the worker-part
        metadata.  Returns the round outcome (COMMIT/ABORT dict) or None if no
        round was pending.
        """
        with self._lock:
            req = self._pending_req
            self._pending_req = None
            self._outcome = None
        if req is None:
            return None
        rid = req["round"]
        label = req.get("step", step)   # coordinator-assigned checkpoint label
        self._send(P.msg(P.READY, round=rid, worker_id=self.worker_id, step=step))
        try:
            meta = save_fn(label) or {}
            self._send(P.msg(P.WRITTEN, round=rid, worker_id=self.worker_id,
                             meta={k: v for k, v in meta.items()
                                   if isinstance(v, (int, float, str, bool))}))
        except Exception as e:  # noqa: BLE001
            self.log(f"worker {self.worker_id} save failed: {e}")
            self._send(P.msg(P.FAILED, round=rid, worker_id=self.worker_id,
                             error=str(e)))
            raise
        deadline = time.time() + commit_timeout
        with self._cv:
            while self._outcome is None or self._outcome.get("round") != rid:
                remaining = deadline - time.time()
                if remaining <= 0:
                    raise CoordinatorLost("no COMMIT/ABORT from coordinator")
                self._cv.wait(timeout=min(remaining, 0.5))
            return self._outcome

    def close(self):
        self._closed = True
        try:
            P.send_msg(self._sock, P.msg(P.BYE, worker_id=self.worker_id))
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


class CoordinatorLost(RuntimeError):
    pass


class InlineCoordinator:
    """Single-process stand-in: same service() contract, no sockets.

    Used by quickstart/simple jobs where coordinator and worker share the
    process (DMTCP equally works single-node); triggers come from interval /
    signal / walltime sources via ``request()``.
    """

    def __init__(self, commit_fn=None):
        self._pending: Optional[dict] = None
        self.commit_fn = commit_fn
        self.exit_requested = False
        self.exit_reason: Optional[str] = None
        self.history: list[dict] = []

    def request(self, reason: str = "manual"):
        self._pending = {"reason": reason}

    def request_exit(self, reason: str):
        self.exit_requested = True
        self.exit_reason = reason

    def checkpoint_pending(self) -> bool:
        return self._pending is not None

    def service(self, step: int, save_fn, **_) -> Optional[dict]:
        req, self._pending = self._pending, None
        if req is None:
            return None
        t0 = time.time()
        save_fn(step)
        manifest = self.commit_fn(step, num_workers=1) if self.commit_fn else {}
        rec = {"type": P.COMMIT, "step": step, "reason": req["reason"],
               "duration_s": time.time() - t0,
               "manifest_step": manifest.get("step")}
        self.history.append(rec)
        return rec

    def close(self):
        pass
