"""Topology virtualization — elastic (MxN) restart.

DMTCP virtualizes PIDs/fds so a restarted process keeps working on a different
node.  The framework analogue: checkpoints never record mesh coordinates — a
leaf is (path, global shape, dtype) and sharding is *re-derived* from the
logical-axis rules against whatever mesh the restarted job has.  A checkpoint
taken on (16,16) restores onto (2,16,16), (8,8), or one CPU device unchanged.

``place_tree`` is the single entry point: host pytree -> device pytree laid out
for the current mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.parallel.mesh_rules import Rules

tree_map = jax.tree_util.tree_map


def place_tree(host_tree, axes_tree, rules: Optional[Rules]):
    """device_put every leaf with the sharding derived from its logical axes.

    ``rules=None`` places on the default device (single-device restore)."""
    if rules is None:
        return tree_map(jax.device_put, host_tree)

    flat_h, treedef = jax.tree_util.tree_flatten(host_tree)
    flat_a = treedef.flatten_up_to(axes_tree)
    out = []
    for arr, axes in zip(flat_h, flat_a):
        arr = np.asarray(arr)
        sh = rules.sharding(axes, arr.shape)
        out.append(jax.device_put(arr, sh))
    return jax.tree_util.tree_unflatten(treedef, out)


def fetch_tree(device_tree):
    """Device pytree -> host (numpy) pytree; works for any sharding because
    jax gathers fully-addressable arrays transparently."""
    return tree_map(lambda x: np.asarray(jax.device_get(x)), device_tree)
