"""Walltime tracking + requeue decision — the paper's automated C/R strategy.

The paper's batch script tracks consumed vs remaining walltime (via Slurm
``--comment``), checkpoints shortly before the limit, and ``scontrol requeue``s
itself with the remaining time.  ``WalltimeTracker`` is the framework version;
``RequeueFile`` persists the accounting across requeues (our analogue of the
updated job comment).
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Optional

from repro.utils.atomic import atomic_write_json


class WalltimeTracker:
    def __init__(self, limit_s: float, margin_s: float = 30.0,
                 total_budget_s: Optional[float] = None,
                 consumed_s: float = 0.0):
        """``limit_s``: this allocation's walltime.  ``margin_s``: checkpoint
        this long before the limit.  ``total_budget_s``: the whole-computation
        budget across requeues (paper: "desired duration")."""
        self.t0 = time.monotonic()
        self.limit_s = limit_s
        self.margin_s = margin_s
        self.total_budget_s = total_budget_s
        self.prior_consumed_s = consumed_s

    @property
    def elapsed_s(self) -> float:
        return time.monotonic() - self.t0

    @property
    def total_consumed_s(self) -> float:
        return self.prior_consumed_s + self.elapsed_s

    @property
    def remaining_s(self) -> float:
        return self.limit_s - self.elapsed_s

    def near_limit(self) -> bool:
        return self.remaining_s <= self.margin_s

    def budget_exhausted(self) -> bool:
        return (self.total_budget_s is not None
                and self.total_consumed_s >= self.total_budget_s)

    def human(self) -> str:
        e = int(self.elapsed_s)
        return f"{e // 3600:02d}:{(e % 3600) // 60:02d}:{e % 60:02d}"


def detect_node() -> Optional[str]:
    """Node identity under a scheduler: slurmsim sets ``SLURMSIM_NODE``, real
    Slurm sets ``SLURMD_NODENAME``."""
    return os.environ.get("SLURMSIM_NODE") or os.environ.get("SLURMD_NODENAME")


class RequeueFile:
    """Persistent per-job accounting (requeue count, consumed time, last
    step, node placements).  The recorded ``node`` is the placement hint the
    restore-aware scheduler (sched/placement.py) round-trips: the node that
    wrote the last checkpoint is the one whose caches are worth preferring.
    """

    def __init__(self, path: Path):
        self.path = Path(path)

    def load(self) -> dict:
        if self.path.exists():
            return json.loads(self.path.read_text())
        return {"requeues": 0, "consumed_s": 0.0, "last_step": -1,
                "node": None, "placements": [], "peer_roots": {}}

    def save(self, tracker: WalltimeTracker, last_step: int, *,
             reason: str = "", node: Optional[str] = None,
             peers: Optional[dict] = None) -> dict:
        rec = self.load()
        rec["requeues"] += 1
        rec["consumed_s"] = tracker.total_consumed_s
        rec["last_step"] = int(last_step)
        rec["last_reason"] = reason
        rec["pid"] = os.getpid()
        node = node if node is not None else detect_node()
        if node is not None:
            # never clobber the last known placement hint with None — a
            # scheduler-less attempt still wants the previous node preferred
            rec["node"] = node
            rec.setdefault("placements", []).append(node)
        if peers is not None:
            # the warm-peer roots this attempt knew about: a scheduler-less
            # restart can still source its restore from them (peer fabric)
            rec["peer_roots"] = {str(k): str(v) for k, v in peers.items()}
        # unique-tmp atomic publish: two attempts racing a requeue record
        # (a dying process and its replacement) must never interleave
        # write/rename on one fixed tmp path
        atomic_write_json(self.path, rec)
        return rec
