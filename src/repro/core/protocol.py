"""Wire protocol between the checkpoint coordinator and worker checkpoint threads.

Mirrors DMTCP's coordinator <-> checkpoint-thread socket messages (paper Fig. 1):
length-prefixed JSON over TCP.

  worker -> coordinator:  INTRO, READY, WRITTEN, FAILED, HEARTBEAT, BYE
  coordinator -> worker:  CKPT_REQ, COMMIT, ABORT, EXIT_REQ, PING
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Optional

# message types
INTRO = "INTRO"
CKPT_REQ = "CKPT_REQ"
READY = "READY"
WRITTEN = "WRITTEN"
COMMIT = "COMMIT"
ABORT = "ABORT"
FAILED = "FAILED"
HEARTBEAT = "HEARTBEAT"
EXIT_REQ = "EXIT_REQ"
BYE = "BYE"
PING = "PING"

_LEN = struct.Struct("<I")
MAX_MSG = 64 * 1024 * 1024


def configure(sock: socket.socket) -> socket.socket:
    """Small control messages: disable Nagle or every barrier pays ~40ms."""
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return sock


def send_msg(sock: socket.socket, msg: dict) -> None:
    data = json.dumps(msg).encode()
    sock.sendall(_LEN.pack(len(data)) + data)


def recv_msg(sock: socket.socket, timeout: Optional[float] = None) -> Optional[dict]:
    """Returns None on clean EOF; raises socket.timeout on timeout."""
    sock.settimeout(timeout)
    hdr = _recv_exact(sock, _LEN.size)
    if hdr is None:
        return None
    (n,) = _LEN.unpack(hdr)
    if n > MAX_MSG:
        raise ValueError(f"oversized message: {n}")
    body = _recv_exact(sock, n)
    if body is None:
        return None
    return json.loads(body.decode())


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def msg(kind: str, **kw) -> dict:
    kw["type"] = kind
    return kw
