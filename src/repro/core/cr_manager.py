"""CRManager — glues the C/R core into a training loop (paper Fig. 3 workflow).

One object owns: the checkpoint manager (storage), the coordinator client (or
inline coordinator), the signal trap, and the walltime tracker.  The training
loop touches three methods:

    state, data_state, start_step = crm.restore_or_init(init_fn)
    for step in range(start_step, total):
        state = train_step(state, batch)
        action = crm.step_boundary(step, state_snapshot_fn, data_state_fn)
        if action == "exit":           # preempted / walltime -> checkpointed
            crm.request_requeue(step); break

Exit paths mirror the paper: trapped SIGTERM/USR1, coordinator EXIT_REQ,
walltime margin — each forces a final checkpoint round, records the requeue
file, and returns "exit".  Periodic checkpoints happen every
``interval_steps`` or via a coordinator interval trigger.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.checkpoint.manager import CheckpointManager
from repro.core.manifest import capture_manifest, verify_manifest
from repro.core.requeue import RequeueFile, WalltimeTracker, detect_node
from repro.core.signals import SignalTrap
from repro.core.virtualization import fetch_tree, place_tree
from repro.core.worker import InlineCoordinator


class CRManager:
    def __init__(self, ckpt: CheckpointManager, *,
                 client=None,
                 signal_trap: Optional[SignalTrap] = None,
                 walltime: Optional[WalltimeTracker] = None,
                 requeue_file: Optional[RequeueFile] = None,
                 interval_steps: Optional[int] = None,
                 predump: bool = False, predump_lead: int = 1,
                 cfg=None, rules=None, node: Optional[str] = None,
                 peers: Optional[dict] = None,
                 log: Callable[[str], None] = print):
        self.ckpt = ckpt
        # predump=True (delta mode only): ``predump_lead`` steps before each
        # interval checkpoint, snapshot + hand the hash/fingerprint/pre-write
        # work to the manager's background pool (CheckpointManager.precommit)
        # so the interval save pays only for bytes dirtied in the last
        # ``predump_lead`` steps — CRIU's pre-dump, at the training loop level
        self.predump = predump
        self.predump_lead = predump_lead
        # which cluster node this attempt runs on — recorded into the requeue
        # file so the scheduler can round-trip the placement hint
        self.node = node if node is not None else detect_node()
        # the warm-peer roots this attempt was handed (scheduler hint) —
        # recorded into the requeue file so a scheduler-less restart can
        # still source its restore through the peer fabric
        self.peers = peers
        self.client = client or InlineCoordinator(commit_fn=ckpt.commit)
        self.signal_trap = signal_trap
        self.walltime = walltime
        self.requeue_file = requeue_file
        self.interval_steps = interval_steps
        self.cfg = cfg
        self.rules = rules
        self.log = log
        self.events: list[dict] = []
        self._restored_meta: Optional[dict] = None

    # ------------------------------------------------------------------
    def restore_or_init(self, init_fn, templates: dict, axes: Optional[dict] = None):
        """templates: {"state": host-template pytree}.  Returns
        (device_state, manifest_meta|None, start_step)."""
        try:
            host_state, manifest = self.ckpt.restore(templates["state"])
        except FileNotFoundError:
            state = init_fn()
            self.log("[cr] no checkpoint found — cold start")
            return state, None, 0
        stats = getattr(self.ckpt, "last_restore_stats", None)
        if stats:
            src = "promoted " + stats["tier"] if stats.get("promoted") else stats["tier"]
            if stats.get("peer"):
                src = "peers " + ",".join(stats.get("peer_tiers") or [])
            self.log(f"[cr] restore engine: tier={src} mode={stats['mode']} "
                     f"workers={stats.get('workers')} "
                     f"tasks={stats.get('tasks', stats.get('files'))}")
        meta = manifest.get("meta", {})
        if meta.get("run_manifest"):
            verify_manifest(meta["run_manifest"], cfg=self.cfg, log=self.log)
        state = place_tree(host_state, axes["state"] if axes else None,
                           self.rules) if axes else place_tree(host_state, None, None)
        start_step = int(meta.get("next_step", manifest["step"] + 1))
        self._restored_meta = meta
        self.log(f"[cr] restored checkpoint step={manifest['step']} "
                 f"-> resuming at {start_step}")
        return state, meta, start_step

    # ------------------------------------------------------------------
    def _save_fn(self, step: int, state_fn, extra_meta: dict):
        def save(label=None):
            state = state_fn()
            # device_fp: the manager fingerprints LIVE device leaves and
            # gathers only dirty chunks itself — a full fetch here would
            # pay the D2H bill the mode exists to avoid
            host = (state if getattr(self.ckpt, "device_fp", False)
                    else fetch_tree(state))  # quiesce point: device -> host
            meta = dict(extra_meta)
            meta["next_step"] = step + 1
            meta["run_manifest"] = capture_manifest(self.cfg)
            return self.ckpt.save(label if label is not None else step,
                                  host, extra_meta=meta)
        return save

    def checkpoint_now(self, step: int, state_fn, *, reason: str = "manual",
                       extra_meta: Optional[dict] = None) -> Optional[dict]:
        if isinstance(self.client, InlineCoordinator):
            self.client.request(reason)
        outcome = self.client.service(
            step, self._save_fn(step, state_fn, extra_meta or {}))
        if outcome:
            self.events.append({"step": step, "reason": reason, **outcome})
        return outcome

    # ------------------------------------------------------------------
    def exit_reason(self) -> Optional[str]:
        if self.signal_trap is not None and self.signal_trap.triggered:
            return f"signal:{self.signal_trap.received}"
        if getattr(self.client, "exit_requested", False):
            return f"coordinator:{self.client.exit_reason}"
        if self.walltime is not None and self.walltime.near_limit():
            return "walltime"
        return None

    def step_boundary(self, step: int, state_fn, *,
                      extra_meta: Optional[dict] = None) -> str:
        """Returns 'exit' | 'checkpointed' | 'continue'."""
        reason = self.exit_reason()
        if reason is not None:
            self.log(f"[cr] exit condition at step {step}: {reason}")
            self.checkpoint_now(step, state_fn, reason=reason,
                                extra_meta=extra_meta)
            return "exit"
        if self.client.checkpoint_pending():
            self.client.service(step, self._save_fn(step, state_fn,
                                                    extra_meta or {}))
            return "checkpointed"
        if self.interval_steps and step > 0 and step % self.interval_steps == 0:
            self.checkpoint_now(step, state_fn, reason="interval",
                                extra_meta=extra_meta)
            return "checkpointed"
        if (self.predump and self.interval_steps
                and getattr(self.ckpt, "delta", False)):
            from repro.train.step import predump_boundary
            if predump_boundary(step, self.interval_steps, self.predump_lead):
                state = state_fn()
                host = (state if getattr(self.ckpt, "device_fp", False)
                        else fetch_tree(state))  # quiesce: device -> host only
                info = self.ckpt.precommit(step, host)
                self.events.append({"step": step, "reason": "predump",
                                    **info})
        return "continue"

    # ------------------------------------------------------------------
    def request_requeue(self, step: int, reason: str = "") -> None:
        if self.requeue_file is not None and self.walltime is not None:
            rec = self.requeue_file.save(self.walltime, step, reason=reason,
                                         node=self.node, peers=self.peers)
            self.log(f"[cr] requeue recorded: {rec}")

    def close(self) -> None:
        try:
            self.ckpt.close()
        finally:
            self.client.close()   # BYE must go out even if a write failed
