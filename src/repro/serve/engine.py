"""Serving step factories + a batched generation engine.

``make_decode_step`` lowers one-new-token-with-cache (the assigned decode_32k /
long_500k cells); ``make_prefill_step`` lowers the full-prompt pass.  The
``Engine`` drives batched generation on real devices and exposes its cache as
checkpointable state — the paper's "pause, migrate, resume" applies to serving
too (examples/serve_migration.py snapshots a half-generated batch and resumes it
elsewhere).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import use_shard_resolver
from repro.parallel.context import use_mesh_context
from repro.parallel.mesh_rules import Rules
from repro.serve.weight_sync import ParamHandle

tree_map = jax.tree_util.tree_map


def _tree_shardings(rules, sds, axes):
    flat_s, tdef = jax.tree_util.tree_flatten(sds)
    flat_a = tdef.flatten_up_to(axes)
    return jax.tree_util.tree_unflatten(
        tdef, [rules.sharding(a, s.shape) for s, a in zip(flat_s, flat_a)])


def make_decode_step(cfg: ModelConfig, mesh, *, batch: int, max_seq: int,
                     rules: Optional[Rules] = None, impl: Optional[str] = None,
                     donate: bool = True):
    rules = rules or Rules(mesh)
    resolver = rules.activation_resolver()
    sds, axes = M.cache_specs(cfg, batch, max_seq)
    cache_sh = _tree_shardings(rules, sds, axes)
    param_sh = _tree_shardings(
        rules, M.abstract_params(cfg), M.param_logical_axes(cfg))

    def step(params, cache, tokens):
        with use_shard_resolver(resolver), use_mesh_context(mesh, rules):
            logits, cache = M.decode_step(params, cfg, tokens, cache, impl=impl)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return cache, next_tok, logits

    tok_shape = (batch, cfg.num_codebooks) if cfg.num_codebooks else (batch,)
    tok_sh = rules.sharding(("batch",) + (None,) * (len(tok_shape) - 1), tok_shape)
    jitted = jax.jit(
        step,
        in_shardings=(param_sh, cache_sh, tok_sh),
        out_shardings=(cache_sh, tok_sh, None),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, param_sh, cache_sh, tok_sh


def make_prefill_step(cfg: ModelConfig, mesh, *, batch: int, seq_len: int,
                      max_seq: Optional[int] = None, rules: Optional[Rules] = None,
                      impl: Optional[str] = None, moe_groups: Optional[int] = None):
    rules = rules or Rules(mesh)
    resolver = rules.activation_resolver()
    max_seq = max_seq or seq_len
    if moe_groups is None:
        moe_groups = rules.axis_group_size("batch")

    def step(params, batch_in):
        with use_shard_resolver(resolver), use_mesh_context(mesh, rules):
            return M.prefill(params, cfg, batch_in, max_seq, impl=impl,
                             moe_groups=moe_groups)

    param_sh = _tree_shardings(
        rules, M.abstract_params(cfg), M.param_logical_axes(cfg))
    sds, axes = M.cache_specs(cfg, batch, max_seq)
    cache_sh = _tree_shardings(rules, sds, axes)
    jitted = jax.jit(step, in_shardings=(param_sh, None),
                     out_shardings=(None, cache_sh))
    return jitted, param_sh, cache_sh


class Engine:
    """Minimal batched serving engine with checkpointable generation state."""

    def __init__(self, cfg: ModelConfig, mesh, params, *, batch: int,
                 max_seq: int, impl: Optional[str] = None, sync_client=None):
        self.cfg = cfg
        self.mesh = mesh
        # optional WeightSyncClient: wires the staleness gate into the
        # serving loop as ADMISSION CONTROL (admit() below) instead of a
        # mid-batch failure
        self.sync_client = sync_client
        # swap-safe weights: the engine serves ``param_handle.current`` and
        # commits a staged update (weight_sync's double buffer) only at
        # generation boundaries — a decode loop can never see a torn tree.
        # Passing a ParamHandle shares it with a WeightSyncClient; passing a
        # bare tree keeps the old single-tree behavior.
        self.param_handle = (params if isinstance(params, ParamHandle)
                             else ParamHandle(params))
        self.batch = batch
        self.max_seq = max_seq
        self.decode, *_ = make_decode_step(
            cfg, mesh, batch=batch, max_seq=max_seq, impl=impl, donate=True)
        self.prefill_fn, *_ = make_prefill_step(
            cfg, mesh, batch=batch, seq_len=max_seq, max_seq=max_seq, impl=impl)
        self.cache = None
        self.last_tokens = None

    @property
    def params(self):
        """The tree decode is currently serving (read-only view)."""
        return self.param_handle.current

    def maybe_swap(self) -> bool:
        """Generation-boundary swap point: adopt a staged weight update, if
        any.  Called automatically at the entry of ``prefill``/``generate``;
        exposed so a serving loop can also swap between batches."""
        return self.param_handle.commit_pending()

    def admit(self) -> bool:
        """Admission gate for NEW generations: False while the attached
        ``WeightSyncClient`` is draining (replica too stale to take new
        work — finish in-flight generations, catch up, re-admit).  Always
        True without a sync client.  The serving loop calls this BEFORE
        ``prefill``; ``generate`` on already-admitted work never gates, so
        a draining replica finishes what it started."""
        return self.sync_client is None or self.sync_client.admit()

    def prefill(self, prompts: dict):
        self.maybe_swap()
        logits, cache = self.prefill_fn(self.param_handle.current, prompts)
        self.cache = cache
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if self.cfg.num_codebooks and nxt.ndim == 1:
            nxt = jnp.broadcast_to(nxt[:, None], (nxt.shape[0], self.cfg.num_codebooks))
        self.last_tokens = nxt
        return nxt

    def generate(self, n: int, on_token=None):
        self.maybe_swap()
        # captured ONCE: a weight push staged mid-loop (e.g. from an
        # on_token callback or a sync thread) waits for the next boundary —
        # all n tokens of this call come from one coherent tree
        params = self.param_handle.current
        out = []
        for _ in range(n):
            self.cache, self.last_tokens, _ = self.decode(
                params, self.cache, self.last_tokens)
            out.append(np.asarray(self.last_tokens))
            if on_token is not None:
                on_token(out[-1])
        return np.stack(out, axis=1)

    # --- C/R surface ---------------------------------------------------------
    def snapshot(self) -> dict:
        return {"cache": self.cache, "last_tokens": self.last_tokens}

    def restore(self, snap: dict) -> None:
        self.cache = snap["cache"]
        self.last_tokens = snap["last_tokens"]
