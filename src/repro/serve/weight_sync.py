"""Serving-fleet weight distribution on the checkpoint chunk fabric.

The paper's C/R machinery moves TRAINING state between jobs; a production
inference fleet needs the same bytes moved the other way — a fine-tune/RLHF
trainer commits step N+1 as a delta checkpoint, and every serving replica
must converge to it without dropping requests.  This module is that
consumer:

* ``ParamHandle`` double-buffers the parameter tree: decode always reads one
  coherent tree, a newer one is STAGED off to the side, and the swap is a
  pointer flip at a generation boundary — the only request-visible cost.
* ``WeightSyncClient`` subscribes to the ``CacheRegistry`` push plane
  (``announce_push``/``latest_push``), fetches a newer step through the
  unified ``CheckpointManager.restore`` as a READ-ONLY follower
  (``promote=False`` — never invalidates or promotes cache markers some
  other replica on the node may be serving from), stages it, and publishes
  per-replica sync state (step, lag, bytes by tier, swap stall) back
  through the registry.

Because the fetch rides the chunk plane's own-cache -> exact-peer ->
stale-peer -> shared resolution, a warm-but-stale replica pulls only the
chunks the new step changed — fleet-wide shared-tier traffic is ~delta
size, not N x full model size (see benchmarks/bench_weight_push.py).

Deliberately jax-free: trees are whatever the caller serves (the engine
passes device arrays through ``to_native``), so the unit tests drive the
whole protocol on numpy.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class StaleReplicaError(RuntimeError):
    """A replica exceeded its staleness bound and could not close the gap."""


class ParamHandle:
    """Double-buffered parameter tree.

    ``current`` is what decode reads; ``stage()`` parks a newer tree without
    touching it; ``commit_pending()`` flips the pointer.  The flip is the
    ONLY mutation ``current`` ever sees, so a generation loop that captures
    ``current`` once can never observe a torn update — the swap lands at
    the next capture point (the engine calls ``commit_pending()`` exactly
    at generation boundaries).
    """

    def __init__(self, tree, step: Optional[int] = None):
        self._lock = threading.Lock()
        self._current = tree
        self._step = step
        self._pending: Optional[tuple] = None      # (tree, step)
        self.swap_count = 0
        self.last_swap_s = 0.0                     # request-visible stall

    @property
    def current(self):
        with self._lock:
            return self._current

    @property
    def step(self) -> Optional[int]:
        with self._lock:
            return self._step

    @property
    def pending_step(self) -> Optional[int]:
        with self._lock:
            return self._pending[1] if self._pending is not None else None

    @property
    def newest_step(self) -> Optional[int]:
        """The step this handle has BYTES for (staged counts — it is one
        pointer flip away), which is what staleness is measured against."""
        with self._lock:
            return self._pending[1] if self._pending is not None else self._step

    def stage(self, tree, step: Optional[int]) -> None:
        """Park a newer tree; a later stage before the swap supersedes it
        (the fleet converges to the NEWEST push, intermediate ones are
        skippable by design — bounded staleness, not a replay log)."""
        with self._lock:
            self._pending = (tree, step)

    def commit_pending(self) -> bool:
        """Flip to the staged tree, if any.  Returns True when a swap
        happened.  ``last_swap_s`` times exactly this flip — the fetch that
        produced the staged tree ran off the request path."""
        with self._lock:
            if self._pending is None:
                return False
            t0 = time.perf_counter()
            self._current, self._step = self._pending
            self._pending = None
            self.swap_count += 1
            self.last_swap_s = time.perf_counter() - t0
            return True


class WeightSyncClient:
    """One serving replica's subscription to the weight-push plane.

    ``manager`` is a READ-ONLY follower ``CheckpointManager`` (typically
    ``promote="off"``; every restore here passes ``promote=False`` anyway);
    ``handle`` is the engine's ``ParamHandle``; ``template`` a same-shape
    host tree for ``restore``.  ``sources`` pins the fetch plan
    (``"auto"`` plans own-cache -> peers -> shared).  ``to_native``
    converts the restored host tree into whatever the engine serves
    (device placement) BEFORE it is staged, so the boundary swap stays a
    pointer flip.
    """

    def __init__(self, manager, handle: ParamHandle, template, *,
                 registry=None, replica: Optional[str] = None,
                 max_lag_steps: Optional[int] = None, sources="auto",
                 to_native: Optional[Callable] = None):
        self.manager = manager
        self.handle = handle
        self.template = template
        self.registry = registry if registry is not None else manager.registry
        self.replica = replica or manager.node or "replica"
        self.max_lag_steps = max_lag_steps
        self.sources = sources
        self.to_native = to_native
        self.history: list[dict] = []          # one record per applied sync

    # -- push-plane polling --------------------------------------------
    def published_step(self) -> Optional[int]:
        """Newest step the publisher advertised.  One tiny registry read
        per poll; falls back to listing committed manifests only when no
        announcement exists (cold registry / out-of-band publisher)."""
        if self.registry is not None:
            ann = self.registry.latest_push()
            if ann is not None:
                return ann["step"]
        steps = self.manager.steps()
        return steps[-1] if steps else None

    def lag(self) -> Optional[int]:
        """Published step minus the newest step this replica has bytes for
        (staged-but-unswapped counts; None when either side is unknown)."""
        target = self.published_step()
        have = self.handle.newest_step
        if target is None or have is None:
            return None
        return max(0, target - have)

    # -- sync ----------------------------------------------------------
    def sync_once(self) -> Optional[dict]:
        """Poll; if a newer step is published, fetch its delta and stage it.
        Returns the sync record (also appended to ``history``) or None when
        already current.  The fetch never blocks decode — the engine keeps
        serving ``handle.current`` until its next boundary swap."""
        target = self.published_step()
        have = self.handle.newest_step
        if target is None or (have is not None and target <= have):
            self._publish_status(phase="serving")
            return None
        self._publish_status(phase="fetching", target_step=target)
        t0 = time.perf_counter()
        try:
            tree, manifest = self.manager.restore(
                self.template, target, sources=self.sources, promote=False)
        except FileNotFoundError:
            # announced but not (yet) visible — a paused or failed publisher
            # mid-push.  Keep serving the current weights; ensure_fresh()'s
            # staleness bound decides when that stops being acceptable.
            self._publish_status(phase="serving")
            return None
        fetch_s = time.perf_counter() - t0
        if self.to_native is not None:
            tree = self.to_native(tree)
        self.handle.stage(tree, target)
        stats = self.manager.last_restore_stats or {}
        rec = {
            "step": target,
            "from_step": have,
            "fetch_s": fetch_s,
            "bytes_read": stats.get("bytes_read", 0),
            "bytes_by_tier": dict(stats.get("bytes_by_tier") or {}),
            "chunks": stats.get("chunks", 0),
            "delta": stats.get("delta", False),
            "manifest_version": manifest.get("manifest_version", 1),
        }
        self.history.append(rec)
        self._publish_status(phase="staged", target_step=target, stats=rec)
        return rec

    def ensure_fresh(self) -> int:
        """Staleness gate for the serving loop: when the bound is exceeded,
        sync and force a swap AT THIS BOUNDARY before another request is
        decoded; raise ``StaleReplicaError`` only if even that cannot close
        the gap (torn fabric — serving stale beyond the bound is worse than
        failing the replica out of rotation).  Returns the lag after the
        gate.  With no bound configured this never blocks or raises."""
        lag = self.lag()
        if (self.max_lag_steps is None or lag is None
                or lag <= self.max_lag_steps):
            return lag or 0
        self.sync_once()
        self.handle.commit_pending()
        lag = self.lag() or 0
        if lag > self.max_lag_steps:
            self._publish_status(phase="stalled")
            raise StaleReplicaError(
                f"replica {self.replica} is {lag} steps behind the "
                f"published weights (bound {self.max_lag_steps})")
        return lag

    # -- registry status ------------------------------------------------
    def _publish_status(self, *, phase: str,
                        target_step: Optional[int] = None,
                        stats: Optional[dict] = None) -> None:
        if self.registry is None:
            return
        try:
            self.registry.publish_replica(
                self.replica, step=self.handle.step,
                target_step=target_step, phase=phase, stats=stats)
        except OSError:
            pass        # advisory, like every registry write: an unwritable
            #             inventory must never take the replica down

    # -- follower loop (launch/serve.py --follow) ----------------------
    def follow(self, *, poll_s: float = 0.5,
               stop: Optional[threading.Event] = None,
               on_sync: Optional[Callable[[dict], None]] = None,
               max_polls: Optional[int] = None) -> int:
        """Poll/fetch/stage until ``stop`` is set (or ``max_polls`` polls
        ran).  Swaps are still the ENGINE's business at its generation
        boundaries; this loop only keeps the staged side fresh.  Returns
        the number of syncs applied."""
        n = polls = 0
        while not (stop is not None and stop.is_set()):
            rec = self.sync_once()
            if rec is not None:
                n += 1
                if on_sync is not None:
                    on_sync(rec)
            polls += 1
            if max_polls is not None and polls >= max_polls:
                break
            if stop is not None:
                stop.wait(poll_s)
            else:
                time.sleep(poll_s)
        return n
