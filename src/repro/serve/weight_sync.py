"""Serving-fleet weight distribution on the checkpoint chunk fabric.

The paper's C/R machinery moves TRAINING state between jobs; a production
inference fleet needs the same bytes moved the other way — a fine-tune/RLHF
trainer commits step N+1 as a delta checkpoint, and every serving replica
must converge to it without dropping requests.  This module is that
consumer:

* ``ParamHandle`` double-buffers the parameter tree: decode always reads one
  coherent tree, a newer one is STAGED off to the side, and the swap is a
  pointer flip at a generation boundary — the only request-visible cost.
* ``WeightSyncClient`` subscribes to the ``CacheRegistry`` push plane
  (``announce_push``/``latest_push``), fetches a newer step through the
  unified ``CheckpointManager.restore`` as a READ-ONLY follower
  (``promote=False`` — never invalidates or promotes cache markers some
  other replica on the node may be serving from), stages it, and publishes
  per-replica sync state (step, lag, bytes by tier, swap stall) back
  through the registry.

Because the fetch rides the chunk plane's own-cache -> exact-peer ->
stale-peer -> shared resolution, a warm-but-stale replica pulls only the
chunks the new step changed — fleet-wide shared-tier traffic is ~delta
size, not N x full model size (see benchmarks/bench_weight_push.py).

Deliberately jax-free: trees are whatever the caller serves (the engine
passes device arrays through ``to_native``), so the unit tests drive the
whole protocol on numpy.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional


class StaleReplicaError(RuntimeError):
    """A replica exceeded its staleness bound and could not close the gap."""


class ParamHandle:
    """Double-buffered parameter tree.

    ``current`` is what decode reads; ``stage()`` parks a newer tree without
    touching it; ``commit_pending()`` flips the pointer.  The flip is the
    ONLY mutation ``current`` ever sees, so a generation loop that captures
    ``current`` once can never observe a torn update — the swap lands at
    the next capture point (the engine calls ``commit_pending()`` exactly
    at generation boundaries).
    """

    def __init__(self, tree, step: Optional[int] = None):
        self._lock = threading.Lock()
        self._current = tree
        self._step = step
        self._pending: Optional[tuple] = None      # (tree, step)
        self.swap_count = 0
        self.last_swap_s = 0.0                     # request-visible stall

    @property
    def current(self):
        with self._lock:
            return self._current

    @property
    def step(self) -> Optional[int]:
        with self._lock:
            return self._step

    @property
    def pending_step(self) -> Optional[int]:
        with self._lock:
            return self._pending[1] if self._pending is not None else None

    @property
    def newest_step(self) -> Optional[int]:
        """The step this handle has BYTES for (staged counts — it is one
        pointer flip away), which is what staleness is measured against."""
        with self._lock:
            return self._pending[1] if self._pending is not None else self._step

    def stage(self, tree, step: Optional[int]) -> None:
        """Park a newer tree; a later stage before the swap supersedes it
        (the fleet converges to the NEWEST push, intermediate ones are
        skippable by design — bounded staleness, not a replay log)."""
        with self._lock:
            self._pending = (tree, step)

    def commit_pending(self) -> bool:
        """Flip to the staged tree, if any.  Returns True when a swap
        happened.  ``last_swap_s`` times exactly this flip — the fetch that
        produced the staged tree ran off the request path."""
        with self._lock:
            if self._pending is None:
                return False
            t0 = time.perf_counter()
            self._current, self._step = self._pending
            self._pending = None
            self.swap_count += 1
            self.last_swap_s = time.perf_counter() - t0
            return True


class WeightSyncClient:
    """One serving replica's subscription to the weight-push plane.

    ``manager`` is a READ-ONLY follower ``CheckpointManager`` (typically
    ``promote="off"``; every restore here passes ``promote=False`` anyway);
    ``handle`` is the engine's ``ParamHandle``; ``template`` a same-shape
    host tree for ``restore``.  ``sources`` pins the fetch plan
    (``"auto"`` plans own-cache -> peers -> shared).  ``to_native``
    converts the restored host tree into whatever the engine serves
    (device placement) BEFORE it is staged, so the boundary swap stays a
    pointer flip.

    ``on_stale`` picks what the staleness gate does when even a forced
    sync cannot close the gap: ``"drain"`` (default) flips the replica
    into a DRAINING phase — in-flight generations finish, ``admit()``
    refuses new ones, the registry shows ``draining`` — and re-admits it
    once it catches up; ``"raise"`` keeps the PR-7 behavior of failing the
    replica out of rotation with ``StaleReplicaError``.

    ``pipeline_uploads=True`` moves ``to_native`` + ``stage`` onto a
    single background upload thread, so the (device-upload-heavy) native
    conversion of push N overlaps the FETCH of push N+1; the in-flight
    step still counts as "have" for lag, and ``wait_uploads()`` (called by
    the gate before a forced swap) drains the pipeline and re-raises any
    upload failure at the boundary that needs the bytes.

    ``advertise=True`` (default) passes ``follower_cache=True`` into the
    manager's restore: fetched delta chunks are parked in the node-local
    tier and the synced step is advertised as a registry follower-cache
    entry, so the NEXT replica pulls the delta from this one instead of
    the shared tier (see ``CacheRegistry.publish_follower``).

    Thread-safe: one ``RLock`` serializes the poll -> fetch -> stage path,
    so a background ``follow()`` thread and a boundary ``ensure_fresh()``/
    ``admit()`` call can never double-fetch one step, tear ``history``, or
    interleave their status publishes.
    """

    def __init__(self, manager, handle: ParamHandle, template, *,
                 registry=None, replica: Optional[str] = None,
                 max_lag_steps: Optional[int] = None, sources="auto",
                 to_native: Optional[Callable] = None,
                 on_stale: str = "drain", pipeline_uploads: bool = False,
                 advertise: bool = True):
        if on_stale not in ("drain", "raise"):
            raise ValueError("on_stale must be 'drain' or 'raise'")
        self.manager = manager
        self.handle = handle
        self.template = template
        self.registry = registry if registry is not None else manager.registry
        self.replica = replica or manager.node or "replica"
        self.max_lag_steps = max_lag_steps
        self.sources = sources
        self.to_native = to_native
        self.on_stale = on_stale
        self.pipeline_uploads = pipeline_uploads
        self.advertise = advertise
        self.history: list[dict] = []          # one record per applied sync
        self.drain_count = 0                   # times the replica drained
        self.readmit_count = 0                 # times it re-admitted after
        self._sync_lock = threading.RLock()
        self._draining = False
        self._upload_pool: Optional[ThreadPoolExecutor] = None
        self._upload_futures: list[Future] = []
        self._inflight_step: Optional[int] = None

    @property
    def draining(self) -> bool:
        """True while the replica is refusing new admissions (over its
        staleness bound, waiting to catch up)."""
        with self._sync_lock:
            return self._draining

    # -- push-plane polling --------------------------------------------
    def published_step(self) -> Optional[int]:
        """Newest step the publisher advertised.  One tiny registry read
        per poll; falls back to listing committed manifests only when no
        announcement exists (cold registry / out-of-band publisher)."""
        if self.registry is not None:
            ann = self.registry.latest_push()
            if ann is not None:
                return ann["step"]
        steps = self.manager.steps()
        return steps[-1] if steps else None

    def _newest_have(self) -> Optional[int]:
        """Newest step this replica has bytes for: staged counts (one flip
        away) and so does a step whose upload is still IN FLIGHT on the
        pipeline thread — the fetch is done, the bytes exist, only the
        native conversion lags."""
        have = self.handle.newest_step
        infl = self._inflight_step
        if infl is not None and (have is None or infl > have):
            return infl
        return have

    def lag(self) -> Optional[int]:
        """Published step minus the newest step this replica has bytes for
        (staged-but-unswapped and in-flight-upload count; None when either
        side is unknown)."""
        target = self.published_step()
        with self._sync_lock:
            have = self._newest_have()
        if target is None or have is None:
            return None
        return max(0, target - have)

    # -- sync ----------------------------------------------------------
    def sync_once(self) -> Optional[dict]:
        """Poll; if a newer step is published, fetch its delta and stage it
        (directly, or via the upload pipeline).  Returns the sync record
        (also appended to ``history``) or None when already current.  The
        fetch never blocks decode — the engine keeps serving
        ``handle.current`` until its next boundary swap."""
        with self._sync_lock:
            target = self.published_step()
            have = self._newest_have()
            if target is None or (have is not None and target <= have):
                self._publish_status(phase="serving")
                return None
            self._publish_status(phase="fetching", target_step=target)
            t0 = time.perf_counter()
            try:
                tree, manifest = self.manager.restore(
                    self.template, target, sources=self.sources,
                    promote=False, follower_cache=self.advertise)
            except FileNotFoundError:
                # announced but not (yet) visible — a paused or failed
                # publisher mid-push.  Keep serving the current weights;
                # the staleness gate decides when that stops being OK.
                self._publish_status(phase="serving")
                return None
            fetch_s = time.perf_counter() - t0
            stats = self.manager.last_restore_stats or {}
            rec = {
                "step": target,
                "from_step": have,
                "fetch_s": fetch_s,
                "bytes_read": stats.get("bytes_read", 0),
                "bytes_by_tier": dict(stats.get("bytes_by_tier") or {}),
                "chunks": stats.get("chunks", 0),
                "delta": stats.get("delta", False),
                "follower_advertised": stats.get("follower_advertised",
                                                 False),
                "pipelined": bool(self.pipeline_uploads),
                "manifest_version": manifest.get("manifest_version", 1),
            }
            self.history.append(rec)
            if self.pipeline_uploads:
                # overlap to_native of THIS push with the fetch of the
                # next: the single-worker pool keeps stages ordered, and
                # _inflight_step keeps lag()/dedup honest meanwhile
                self._inflight_step = target
                self._upload_futures.append(
                    self._upload_executor().submit(
                        self._upload, tree, target, rec))
            else:
                if self.to_native is not None:
                    tree = self.to_native(tree)
                self.handle.stage(tree, target)
                self._publish_status(phase="staged", target_step=target,
                                     stats=rec)
            return rec

    # -- pipelined device upload ----------------------------------------
    def _upload_executor(self) -> ThreadPoolExecutor:
        if self._upload_pool is None:
            self._upload_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="weight-upload")
        return self._upload_pool

    def _upload(self, tree, step: int, rec: dict) -> None:
        # runs on the upload thread; deliberately lock-free (wait_uploads
        # blocks on this future WHILE holding the sync lock)
        if self.to_native is not None:
            tree = self.to_native(tree)
        self.handle.stage(tree, step)
        self._publish_status(phase="staged", target_step=step, stats=rec)

    def wait_uploads(self) -> None:
        """Drain the upload pipeline.  The first failed upload re-raises
        HERE — at the boundary that needs the bytes — not on a background
        thread; after a failure the in-flight step no longer counts as
        "have", so the next sync re-fetches it."""
        with self._sync_lock:
            futs, self._upload_futures = self._upload_futures, []
            self._inflight_step = None
        for f in futs:
            f.result()

    def close(self) -> None:
        """Drain and shut down the upload pipeline (no-op when unused)."""
        try:
            self.wait_uploads()
        finally:
            pool, self._upload_pool = self._upload_pool, None
            if pool is not None:
                pool.shutdown(wait=True)

    # -- staleness gate / draining admission control ---------------------
    def _readmit(self) -> None:
        if self._draining:
            self._draining = False
            self.readmit_count += 1
            self._publish_status(phase="serving")

    def _gate(self) -> int:
        """Shared staleness gate (callers hold ``_sync_lock``): when the
        bound is exceeded, sync, drain the upload pipeline and force a swap
        AT THIS BOUNDARY; if even that cannot close the gap, either enter
        the draining phase (``on_stale="drain"``) or raise
        ``StaleReplicaError`` (``on_stale="raise"``).  Returns the lag
        after the gate and clears draining whenever the replica is back
        within its bound."""
        lag = self.lag()
        if (self.max_lag_steps is None or lag is None
                or lag <= self.max_lag_steps):
            self._readmit()
            return lag or 0
        self.sync_once()
        self.wait_uploads()
        self.handle.commit_pending()
        lag = self.lag() or 0
        if lag <= self.max_lag_steps:
            self._readmit()
            return lag
        if self.on_stale == "raise":
            self._publish_status(phase="stalled")
            raise StaleReplicaError(
                f"replica {self.replica} is {lag} steps behind the "
                f"published weights (bound {self.max_lag_steps})")
        if not self._draining:
            self._draining = True
            self.drain_count += 1
        self._publish_status(phase="draining")
        return lag

    def ensure_fresh(self) -> int:
        """Staleness gate for the serving loop: when the bound is exceeded,
        sync and force a swap AT THIS BOUNDARY before another request is
        decoded.  If even that cannot close the gap the replica DRAINS
        (default) — check ``draining`` / use ``admit()`` — or, with
        ``on_stale="raise"``, fails out of rotation with
        ``StaleReplicaError``.  Returns the lag after the gate.  With no
        bound configured this never blocks, drains, or raises."""
        with self._sync_lock:
            return self._gate()

    def admit(self) -> bool:
        """Admission control for the serving loop: True when the replica
        may take a NEW generation at this boundary.  Runs the staleness
        gate first, so a recovered replica re-admits on the same call that
        observes it caught up; a draining replica keeps finishing in-flight
        work (the engine only asks ``admit()`` for new admissions) and
        keeps returning False until the gap closes."""
        with self._sync_lock:
            self._gate()
            return not self._draining

    # -- registry status ------------------------------------------------
    def _publish_status(self, *, phase: str,
                        target_step: Optional[int] = None,
                        stats: Optional[dict] = None) -> None:
        if self.registry is None:
            return
        try:
            self.registry.publish_replica(
                self.replica, step=self.handle.step,
                target_step=target_step, phase=phase, stats=stats)
        except OSError:
            pass        # advisory, like every registry write: an unwritable
            #             inventory must never take the replica down

    # -- follower loop (launch/serve.py --follow) ----------------------
    def follow(self, *, poll_s: float = 0.5,
               stop: Optional[threading.Event] = None,
               on_sync: Optional[Callable[[dict], None]] = None,
               max_polls: Optional[int] = None) -> int:
        """Poll/fetch/stage until ``stop`` is set (or ``max_polls`` polls
        ran).  Swaps are still the ENGINE's business at its generation
        boundaries; this loop only keeps the staged side fresh.  Returns
        the number of syncs applied."""
        n = polls = 0
        while not (stop is not None and stop.is_set()):
            rec = self.sync_once()
            if rec is not None:
                n += 1
                if on_sync is not None:
                    on_sync(rec)
            polls += 1
            if max_polls is not None and polls >= max_polls:
                break
            if stop is not None:
                stop.wait(poll_s)
            else:
                time.sleep(poll_s)
        return n
