"""Pure-JAX AdamW with dtype-configurable moments + cosine/warmup schedule.

No optax in this environment; this is the production optimizer.  Moment dtype is
configurable (fp32 default; bf16 for the 671B config so optimizer state fits the
pod — see EXPERIMENTS.md) and the checkpoint substrate serializes whatever dtype
is in use.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

tree_map = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def init_opt_state(params, oc: OptConfig) -> dict:
    dt = jnp.dtype(oc.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {"m": tree_map(zeros, params), "v": tree_map(zeros, params)}


def schedule(oc: OptConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    decay_span = jnp.maximum(oc.decay_steps - oc.warmup_steps, 1)
    prog = jnp.clip((step - oc.warmup_steps) / decay_span, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, opt, step, oc: OptConfig):
    """Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(oc, step)
    b1, b2 = oc.b1, oc.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    mdt = jnp.dtype(oc.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
