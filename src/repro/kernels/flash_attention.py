"""Pallas TPU flash attention (causal, GQA, d_qk != d_v for MLA).

TPU-native design (not a CUDA port): the grid's innermost dimension iterates KV
blocks *sequentially on one core*, so the online-softmax running state
(m, l, acc) lives in VMEM scratch that persists across grid steps — no atomics,
no shared-memory staging.  Block shapes keep the MXU busy: (block_q x d) @
(d x block_k) with d >= 128 on the lane dimension.  Causality is enforced two
ways: fully-masked blocks are skipped via ``pl.when`` (half the work at long
seq), and the diagonal block uses an iota mask.

Validated in interpret mode against kernels/ref.py over shape/dtype sweeps
(tests/test_kernels.py); compiled path is the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, block_q, block_k, num_kv_blocks, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip blocks strictly above the causal diagonal
    visible = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, Dq)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, Dq)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash(q, k, v, *, causal=True, scale=None, block_q=128, block_k=128,
          interpret=False):
    """q: (B,Sq,H,Dq); k: (B,Skv,Hkv,Dq); v: (B,Skv,Hkv,Dv) -> (B,Sq,H,Dv)."""
    B, Sq, H, Dq = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(Dq))
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, block_q, Skv, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        num_kv_blocks=nk, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, Dq), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dq), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, Dv), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dv), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
