"""Mamba2 SSD (state-space duality) chunked scan.

``ssd_chunked_xla`` — pure-XLA chunked algorithm (scan over chunks; within-chunk
quadratic + cross-chunk state recurrence).  Matches ``ref.ssd`` exactly in math,
but runs in O(S*Q) memory and turns the time recurrence into MXU-friendly
matmuls.  ``ssd_chunked`` — the Pallas TPU kernel with the same contract
(see bottom of file).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _chunk_ssd_math(x, dt, A, Bm, Cm, state_in):
    """One chunk, fp32. x:(B,Q,H,P) dt:(B,Q,H) A:(H,) Bm/Cm:(B,Q,N) state:(B,H,P,N)."""
    a = dt * A                                            # (B,Q,H), negative
    cA = jnp.cumsum(a, axis=1)                            # inclusive cumsum
    # within-chunk (diagonal) part: y_i += sum_{j<=i} exp(cA_i - cA_j) dt_j (C_i.B_j) x_j
    cb = jnp.einsum("bin,bjn->bij", Cm, Bm)               # (B,Q,Q)
    Q = x.shape[1]
    tri = np.tril(np.ones((Q, Q), np.float32))
    decay = jnp.exp(cA[:, :, None, :] - cA[:, None, :, :])     # (B,i,j,H)
    scores = cb[..., None] * decay * tri[None, :, :, None]     # (B,i,j,H)
    scores = scores * dt[:, None, :, :]                        # dt_j
    y_diag = jnp.einsum("bijh,bjhp->bihp", scores, x)
    # contribution of the incoming state: y_i += exp(cA_i) C_i . state_in
    y_off = jnp.einsum("bin,bhpn,bih->bihp", Cm, state_in, jnp.exp(cA))
    # chunk state update: state_out = state_in*exp(cA_Q) + sum_j exp(cA_Q-cA_j) dt_j B_j x_j
    last = jnp.exp(cA[:, -1, :])                               # (B,H)
    w = jnp.exp(cA[:, -1, None, :] - cA) * dt                  # (B,Q,H)
    state_new = jnp.einsum("bjn,bjh,bjhp->bhpn", Bm, w, x)
    state_out = state_in * last[:, :, None, None] + state_new
    return y_diag + y_off, state_out


def ssd_chunked_xla(x, dt, A_log, Bm, Cm, D, *, chunk=256, init_state=None,
                    return_state=False):
    """Same contract as ``ref.ssd`` (see kernels/ref.py)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    A = -jnp.exp(A_log.astype(jnp.float32))
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    xs = (
        x.reshape(Bsz, nc, Q, H, P).swapaxes(0, 1).astype(jnp.float32),
        dt.reshape(Bsz, nc, Q, H).swapaxes(0, 1).astype(jnp.float32),
        Bm.reshape(Bsz, nc, Q, N).swapaxes(0, 1).astype(jnp.float32),
        Cm.reshape(Bsz, nc, Q, N).swapaxes(0, 1).astype(jnp.float32),
    )

    def step(state, inp):
        xc, dtc, bc, cc = inp
        y, state = _chunk_ssd_math(xc, dtc, A, bc, cc, state)
        return state, y

    state, ys = jax.lax.scan(step, init_state, xs)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, P)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, state
    return y


def ssd_step(x, dt, A_log, Bm, Cm, D, state):
    """Single decode step.  x:(B,H,P) dt:(B,H) Bm/Cm:(B,N) state:(B,H,P,N)."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A)                               # (B,H)
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dtf, Bm.astype(jnp.float32), xf)
    state = state * decay[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), state


def ssd_chunked(x, dt, A_log, Bm, Cm, D, *, chunk=256, init_state=None,
                return_state=False, interpret=True):
    """Pallas TPU kernel wrapper (defined in this module, kernel body below)."""
    from repro.kernels._ssd_pallas import ssd_pallas

    return ssd_pallas(x, dt, A_log, Bm, Cm, D, chunk=chunk, init_state=init_state,
                      return_state=return_state, interpret=interpret)
