"""Pallas TPU flash-decode: one query token against a long KV cache.

Decode is memory-bound (every cache byte read once per token), so the kernel's
job is to stream KV blocks through VMEM at full HBM bandwidth while the online
softmax rides along in scratch.  All H query heads are processed per grid step
— the (H x Dq) @ (Dq x block_k) matmul keeps the MXU's 128-lane dimension full
even at batch 1.  ``kv_len`` masks unwritten cache slots (ring-buffer serving).

Grid: (B, num_kv_blocks) — KV innermost, sequential per core, scratch persists.
GQA/MLA: per-kv-head q groups are handled by a reshape inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale, block_k, num_kv_blocks, G):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = kvlen_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)          # (H, Dq)
        k = k_ref[0, :, :, :].astype(jnp.float32)          # (bk, Hkv, Dq)
        v = v_ref[0, :, :, :].astype(jnp.float32)          # (bk, Hkv, Dv)
        H, Dq = q.shape
        Hkv = k.shape[1]
        qg = q.reshape(Hkv, G, Dq)
        # scores: (Hkv, G, bk)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (Hkv, G, s.shape[-1]), 2)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...].reshape(Hkv, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])                  # (Hkv, G, bk)
        l_new = l_ref[...].reshape(Hkv, G) * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)            # (Hkv, G, Dv)
        acc = acc_ref[...].reshape(Hkv, G, -1)
        acc_ref[...] = (acc * alpha[..., None] + pv).reshape(H, -1)
        m_ref[...] = m_new.reshape(H)
        l_ref[...] = l_new.reshape(H)

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def flash_decode(q, k, v, *, kv_len=None, scale=None, block_k=256,
                 interpret=False):
    """q: (B,1,H,Dq); k: (B,S,Hkv,Dq); v: (B,S,Hkv,Dv) -> (B,1,H,Dv)."""
    B, Sq, H, Dq = q.shape
    assert Sq == 1
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(Dq))
    scale = float(scale)
    block_k = min(block_k, Skv)
    assert Skv % block_k == 0
    nk = Skv // block_k
    if kv_len is None:
        kv_len = Skv
    kv_len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, num_kv_blocks=nk, G=G)

    return pl.pallas_call(
        kernel,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),         # kv_len scalar
            pl.BlockSpec((1, 1, H, Dq), lambda b, ki: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_k, Hkv, Dq), lambda b, ki: (b, ki, 0, 0)),
            pl.BlockSpec((1, block_k, Hkv, Dv), lambda b, ki: (b, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, Dv), lambda b, ki: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, Dv), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len_arr, q, k, v)
