"""Jit-friendly dispatching wrappers over the kernel implementations.

``impl`` selects the backend:
  auto             small shapes -> naive oracle; long sequences -> blockwise XLA;
                   decode -> full-cache einsum (the flash-decode data movement)
  xla              naive oracle
  xla_chunked      blockwise XLA scan (FLOP-exact causal)
  pallas           Pallas TPU kernel (compiled; TPU target)
  pallas_interpret Pallas kernel body interpreted on CPU (validation)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref, xla_attention

_NAIVE_MAX_SEQ = 2048


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    kv_len=None,
    impl: str = "auto",
    decode: bool = False,
    scale=None,
    q_offset=0,
) -> jax.Array:
    Sq = q.shape[1]
    if impl == "ring" and not (decode or Sq == 1):
        from repro.kernels.ring_attention import ring_attention
        from repro.parallel.context import current_mesh

        mesh = current_mesh()
        if mesh is not None:
            return ring_attention(q, k, v, mesh=mesh, scale=scale, causal=causal)
        impl = "auto"  # no mesh context (tests): fall through
    if impl in ("pallas", "pallas_interpret"):
        interpret = impl == "pallas_interpret"
        if decode or Sq == 1:
            from repro.kernels import decode_attention

            return decode_attention.flash_decode(
                q, k, v, kv_len=kv_len, scale=scale, interpret=interpret
            )
        from repro.kernels import flash_attention

        return flash_attention.flash(
            q, k, v, causal=causal, scale=scale, interpret=interpret
        )

    if decode or Sq == 1:
        # One-token step: a single masked einsum over the cache is already the
        # minimal data movement (reads each cache byte once).
        return ref.attention(
            q, k, v, causal=False, kv_len=kv_len, scale=scale, q_offset=q_offset
        )
    if impl == "xla" or (impl == "auto" and Sq <= _NAIVE_MAX_SEQ) or not causal:
        return ref.attention(
            q, k, v, causal=causal, kv_len=kv_len, scale=scale, q_offset=q_offset
        )
    # long-sequence causal self-attention
    return xla_attention.causal_blockwise(q, k, v, scale=scale)


def ssd(x, dt, A_log, Bm, Cm, D, *, chunk=256, impl="auto", init_state=None,
        return_state=False):
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ssd_scan

        return ssd_scan.ssd_chunked(
            x, dt, A_log, Bm, Cm, D, chunk=chunk,
            init_state=init_state, return_state=return_state,
            interpret=(impl == "pallas_interpret"),
        )
    if impl == "xla_chunked" or (impl == "auto" and x.shape[1] > 64):
        from repro.kernels import ssd_scan

        return ssd_scan.ssd_chunked_xla(
            x, dt, A_log, Bm, Cm, D, chunk=chunk,
            init_state=init_state, return_state=return_state,
        )
    return ref.ssd(x, dt, A_log, Bm, Cm, D, init_state=init_state,
                   return_state=return_state)


def wkv6(r, k, v, w, u, *, impl="auto", init_state=None, return_state=False,
         chunk=128):
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import rwkv6_scan

        return rwkv6_scan.wkv6_chunked(
            r, k, v, w, u, chunk=chunk, init_state=init_state,
            return_state=return_state, interpret=(impl == "pallas_interpret"),
        )
    if impl == "xla_chunked" or (impl == "auto" and r.shape[1] > 64):
        from repro.kernels import rwkv6_scan

        return rwkv6_scan.wkv6_chunked_xla(
            r, k, v, w, u, chunk=chunk, init_state=init_state,
            return_state=return_state,
        )
    return ref.wkv6(r, k, v, w, u, init_state=init_state, return_state=return_state)


def checksum(words: jax.Array, *, impl="auto", block: int = 2048) -> jax.Array:
    """Digest of a uint32 word stream; input zero-padded to a block multiple so
    every impl (ref oracle, pallas, pallas_interpret) agrees bit-for-bit."""
    from repro.kernels import checksum as ck

    # mirror the kernel's guards here so the ref impl rejects / short-circuits
    # exactly like the pallas one (empty input: XOR/SUM over nothing is 0)
    ck.require_pow2(block)
    if words.shape[0] == 0:
        return jnp.uint32(0)
    pad = (-words.shape[0]) % block
    if pad:
        words = jnp.pad(words, (0, pad))
    if impl in ("pallas", "pallas_interpret"):
        return ck.checksum_pallas(words, block=block,
                                  interpret=(impl == "pallas_interpret"))
    return ref.checksum(words)


def chunk_fingerprints(words: jax.Array, *, chunk_words: int,
                       impl="auto") -> jax.Array:
    """Per-chunk uint32 fingerprints of a uint32 word stream — the delta
    plane's dirty-chunk pre-filter (one digest per fixed-size chunk, index
    mixing chunk-local).  Input is zero-padded to a chunk multiple so every
    impl (ref oracle, pallas, pallas_interpret, and the host-side
    serialization.fingerprint_chunks) agrees bit-for-bit."""
    from repro.kernels import checksum as ck

    ck.require_pow2(chunk_words, name="chunk_words")
    if words.shape[0] == 0:
        return jnp.zeros((0,), jnp.uint32)
    pad = (-words.shape[0]) % chunk_words
    if pad:
        words = jnp.pad(words, (0, pad))
    if impl in ("pallas", "pallas_interpret"):
        return ck.chunk_fingerprints_pallas(
            words, chunk_words=chunk_words,
            interpret=(impl == "pallas_interpret"))
    return ref.chunk_fingerprints(words, chunk_words)
