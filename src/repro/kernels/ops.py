"""Jit-friendly dispatching wrappers over the kernel implementations.

``impl`` selects the backend:
  auto             small shapes -> naive oracle; long sequences -> blockwise XLA;
                   decode -> full-cache einsum (the flash-decode data movement)
  xla              naive oracle
  xla_chunked      blockwise XLA scan (FLOP-exact causal)
  pallas           Pallas TPU kernel (compiled; TPU target)
  pallas_interpret Pallas kernel body interpreted on CPU (validation)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref, xla_attention

_NAIVE_MAX_SEQ = 2048


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    kv_len=None,
    impl: str = "auto",
    decode: bool = False,
    scale=None,
    q_offset=0,
) -> jax.Array:
    Sq = q.shape[1]
    if impl == "ring" and not (decode or Sq == 1):
        from repro.kernels.ring_attention import ring_attention
        from repro.parallel.context import current_mesh

        mesh = current_mesh()
        if mesh is not None:
            return ring_attention(q, k, v, mesh=mesh, scale=scale, causal=causal)
        impl = "auto"  # no mesh context (tests): fall through
    if impl in ("pallas", "pallas_interpret"):
        interpret = impl == "pallas_interpret"
        if decode or Sq == 1:
            from repro.kernels import decode_attention

            return decode_attention.flash_decode(
                q, k, v, kv_len=kv_len, scale=scale, interpret=interpret
            )
        from repro.kernels import flash_attention

        return flash_attention.flash(
            q, k, v, causal=causal, scale=scale, interpret=interpret
        )

    if decode or Sq == 1:
        # One-token step: a single masked einsum over the cache is already the
        # minimal data movement (reads each cache byte once).
        return ref.attention(
            q, k, v, causal=False, kv_len=kv_len, scale=scale, q_offset=q_offset
        )
    if impl == "xla" or (impl == "auto" and Sq <= _NAIVE_MAX_SEQ) or not causal:
        return ref.attention(
            q, k, v, causal=causal, kv_len=kv_len, scale=scale, q_offset=q_offset
        )
    # long-sequence causal self-attention
    return xla_attention.causal_blockwise(q, k, v, scale=scale)


def ssd(x, dt, A_log, Bm, Cm, D, *, chunk=256, impl="auto", init_state=None,
        return_state=False):
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ssd_scan

        return ssd_scan.ssd_chunked(
            x, dt, A_log, Bm, Cm, D, chunk=chunk,
            init_state=init_state, return_state=return_state,
            interpret=(impl == "pallas_interpret"),
        )
    if impl == "xla_chunked" or (impl == "auto" and x.shape[1] > 64):
        from repro.kernels import ssd_scan

        return ssd_scan.ssd_chunked_xla(
            x, dt, A_log, Bm, Cm, D, chunk=chunk,
            init_state=init_state, return_state=return_state,
        )
    return ref.ssd(x, dt, A_log, Bm, Cm, D, init_state=init_state,
                   return_state=return_state)


def wkv6(r, k, v, w, u, *, impl="auto", init_state=None, return_state=False,
         chunk=128):
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import rwkv6_scan

        return rwkv6_scan.wkv6_chunked(
            r, k, v, w, u, chunk=chunk, init_state=init_state,
            return_state=return_state, interpret=(impl == "pallas_interpret"),
        )
    if impl == "xla_chunked" or (impl == "auto" and r.shape[1] > 64):
        from repro.kernels import rwkv6_scan

        return rwkv6_scan.wkv6_chunked_xla(
            r, k, v, w, u, chunk=chunk, init_state=init_state,
            return_state=return_state,
        )
    return ref.wkv6(r, k, v, w, u, init_state=init_state, return_state=return_state)


def checksum(words: jax.Array, *, impl="auto", block: int = 2048) -> jax.Array:
    """Digest of a uint32 word stream; input zero-padded to a block multiple so
    every impl (ref oracle, pallas, pallas_interpret) agrees bit-for-bit."""
    from repro.kernels import checksum as ck

    # mirror the kernel's guards here so the ref impl rejects / short-circuits
    # exactly like the pallas one (empty input: XOR/SUM over nothing is 0)
    ck.require_pow2(block)
    if words.shape[0] == 0:
        return jnp.uint32(0)
    pad = (-words.shape[0]) % block
    if pad:
        words = jnp.pad(words, (0, pad))
    if impl in ("pallas", "pallas_interpret"):
        return ck.checksum_pallas(words, block=block,
                                  interpret=(impl == "pallas_interpret"))
    return ref.checksum(words)


def chunk_fingerprints(words: jax.Array, *, chunk_words: int,
                       impl="auto") -> jax.Array:
    """Per-chunk uint32 fingerprints of a uint32 word stream — the delta
    plane's dirty-chunk pre-filter (one digest per fixed-size chunk, index
    mixing chunk-local).  A ragged tail is zero-padded INSIDE each impl
    (only the tail chunk is padded — no O(stream) padded copy), so every
    impl (ref oracle, pallas, pallas_interpret, and the host-side
    serialization.fingerprint_chunks) agrees bit-for-bit."""
    from repro.kernels import checksum as ck

    ck.require_pow2(chunk_words, name="chunk_words")
    if words.shape[0] == 0:
        return jnp.zeros((0,), jnp.uint32)
    if impl in ("pallas", "pallas_interpret"):
        return ck.chunk_fingerprints_pallas(
            words, chunk_words=chunk_words,
            interpret=(impl == "pallas_interpret"))
    return ref.chunk_fingerprints(words, chunk_words)


def leaf_words(arr) -> jax.Array:
    """Little-endian uint32 word stream over a leaf's payload bytes,
    zero-padded to a word boundary — exactly the stream
    ``serialization.fingerprint_chunks`` views host-side, but WITHOUT
    leaving the device: a jax leaf is bitcast/recombined in place (uint32
    out, never donated), so fingerprinting live params costs zero
    device->host bytes.

    numpy inputs take a pure-numpy fast path (a zero-copy ``<u4`` view when
    the payload is word-aligned).  Going through jnp would silently downcast
    float64 host arrays when x64 is disabled — the fast path keeps host
    trees bit-exact as well as free.
    """
    import numpy as np

    if not isinstance(arr, jax.Array):
        a = np.ascontiguousarray(np.asarray(arr)).reshape(-1)
        buf = a.view(np.uint8)
        pad = (-buf.nbytes) % 4
        if pad:
            padded = np.zeros(buf.nbytes + pad, np.uint8)
            padded[:buf.nbytes] = buf
            buf = padded
        return buf.view("<u4")
    x = arr.reshape(-1)
    if x.dtype == jnp.bool_:
        # jnp.bool_ stores one byte per element holding 0/1 — same memory
        # image astype produces, so the byte stream is preserved
        x = x.astype(jnp.uint8)
    itemsize = x.dtype.itemsize
    if itemsize == 4:
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    if itemsize == 8:
        # width-shrinking bitcast adds a minor dim, index 0 = low 32 bits —
        # little-endian word order, matching the host <u4 view
        return jax.lax.bitcast_convert_type(x, jnp.uint32).reshape(-1)
    if itemsize == 2:
        u16 = jax.lax.bitcast_convert_type(x, jnp.uint16)
        if u16.shape[0] % 2:
            u16 = jnp.pad(u16, (0, 1))
        u16 = u16.astype(jnp.uint32)
        return u16[0::2] | (u16[1::2] << 16)
    if itemsize == 1:
        u8 = (x if x.dtype == jnp.uint8
              else jax.lax.bitcast_convert_type(x, jnp.uint8))
        padw = (-u8.shape[0]) % 4
        if padw:
            u8 = jnp.pad(u8, (0, padw))
        b = u8.reshape(-1, 4).astype(jnp.uint32)
        return b[:, 0] | (b[:, 1] << 8) | (b[:, 2] << 16) | (b[:, 3] << 24)
    raise TypeError(f"leaf_words: unsupported itemsize {itemsize} "
                    f"for dtype {x.dtype}")


def tree_chunk_fingerprints(named_leaves, chunk_bytes: int, *,
                            impl="auto") -> dict:
    """``{name: np.uint32[n_chunks]}`` per-chunk fingerprints for a list of
    ``(name, leaf)`` pairs, computed ON DEVICE for jax leaves — the delta
    plane's device-resident dirty detection (dirty chunks are decided
    before any device->host copy; only fingerprint vectors, a few bytes per
    MB of state, cross the link).

    Values are bit-identical to ``serialization.fingerprint_chunks`` on the
    same leaf bytes: each leaf's word stream is split into an ALIGNED body
    (fingerprinted in place, no padded copy) and a ragged tail; all tails
    are zero-padded and batched into ONE extra kernel call across the whole
    tree, so non-multiple-of-4 / non-chunk-multiple leaves cost one launch
    total, not one per leaf.  Inputs are only read — donation-safe.
    """
    import numpy as np

    if chunk_bytes < 4 or chunk_bytes % 4:
        raise ValueError(
            f"chunk_bytes must be a multiple of 4, got {chunk_bytes}")
    chunk_words = chunk_bytes // 4
    out: dict = {}
    body_fp: dict = {}
    tails: list = []                       # (name, padded tail words)
    for name, leaf in named_leaves:
        w = leaf_words(leaf)
        n = int(w.shape[0])
        if n == 0:
            out[name] = np.zeros(0, np.uint32)
            continue
        rem = n % chunk_words
        nbody = n - rem
        if nbody:
            body_fp[name] = chunk_fingerprints(
                jnp.asarray(w[:nbody]), chunk_words=chunk_words, impl=impl)
        if rem:
            tail = w[nbody:]
            if isinstance(tail, np.ndarray):
                t = np.zeros(chunk_words, np.uint32)
                t[:rem] = tail
                tail = t
            else:
                tail = jnp.pad(tail, (0, chunk_words - rem))
            tails.append((name, tail))
    tail_fp: dict = {}
    if tails:
        stacked = jnp.concatenate([jnp.asarray(t) for _, t in tails])
        fps = np.asarray(chunk_fingerprints(
            stacked, chunk_words=chunk_words, impl=impl))
        for i, (name, _) in enumerate(tails):
            tail_fp[name] = fps[i]
    for name in body_fp:
        out[name] = np.asarray(body_fp[name])
    for name, fp in tail_fp.items():
        prev = out.get(name)
        out[name] = (np.append(prev, fp) if prev is not None
                     else np.asarray([fp], np.uint32))
    return out
