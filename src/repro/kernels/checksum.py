"""Pallas TPU checkpoint-integrity checksum — the C/R hot path on device.

The paper checksums checkpoint images on the host; at TPU scale the state lives
in HBM, and hashing it *before* the device->host transfer detects corruption at
HBM bandwidth instead of PCIe bandwidth (and lets the coordinator compare
per-worker digests without moving data).  The hash is an order-dependent
FNV-style mix (matching kernels/ref.py::checksum exactly): each 32-bit word is
mixed with its global index, then XOR- and SUM-reduced.  Both reductions are
associative, so per-block partials combine across sequential grid steps in
SMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PRIME = 16777619


def require_pow2(value: int, name: str = "block") -> None:
    """Both kernels fold their XOR reduction with a reshape-halving tree, so
    the tile length must be a positive power of two — anything else would
    silently drop words.  Raised eagerly (host-side), mirrored by
    kernels/ops.py so every impl fails the same way."""
    if value < 1 or value & (value - 1):
        raise ValueError(f"{name} must be a positive power of two, got {value}")


def _checksum_kernel(w_ref, o_ref, xacc_ref, sacc_ref, *, nb, block):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        xacc_ref[0] = jnp.uint32(0)
        sacc_ref[0] = jnp.uint32(0)

    w = w_ref[...]
    idx = (bi * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
           ).astype(jnp.uint32)
    mixed = (w ^ (idx * jnp.uint32(PRIME))) * (idx | jnp.uint32(1))
    # XOR-reduce via bit tricks: jnp.bitwise_xor.reduce is not available in
    # kernels; fold with a log-tree using reshape halving.
    x = mixed
    n = block
    while n > 1:
        x = x[: n // 2] ^ x[n // 2 :]
        n //= 2
    xacc_ref[0] = xacc_ref[0] ^ x[0]
    sacc_ref[0] = sacc_ref[0] + jnp.sum(mixed, dtype=jnp.uint32)

    @pl.when(bi == nb - 1)
    def _final():
        o_ref[0] = xacc_ref[0] + sacc_ref[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def checksum_pallas(words: jax.Array, *, block: int = 2048,
                    interpret: bool = False) -> jax.Array:
    """words: (N,) uint32 -> uint32 digest.  N padded to a power-of-two block."""
    require_pow2(block)
    n = words.shape[0]
    if n == 0:
        # the ref oracle's empty digest: XOR and SUM over nothing are both 0.
        # Without this guard the block math below degenerates through
        # (-1).bit_length() == 0 into a zero-step grid with an uninitialized
        # SMEM output.
        return jnp.uint32(0)
    block = min(block, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % block
    if pad:
        # zero words at index >= n change the digest; mix is index-dependent, so
        # pad with zeros AND account: zero word mixes to (0 ^ idx*P)*(idx|1) !=0.
        # Instead pad the *input* and compute on the padded length — the ref
        # oracle is called on the same padded array by the ops wrapper.
        words = jnp.pad(words, (0, pad))
        n = words.shape[0]
    nb = n // block
    kernel = functools.partial(_checksum_kernel, nb=nb, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.uint32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.uint32), pltpu.SMEM((1,), jnp.uint32)],
        interpret=interpret,
    )(words)[0]


def _chunk_fp_kernel(w_ref, o_ref, *, chunk_words):
    # one grid step = one chunk; index mixing is chunk-LOCAL so the value
    # matches serialization.fingerprint_chunks / ref.chunk_fingerprints on
    # the same word stream whatever the chunk's position in the leaf
    w = w_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk_words,), 0).astype(jnp.uint32)
    mixed = (w ^ (idx * jnp.uint32(PRIME))) * (idx | jnp.uint32(1))
    x = mixed
    n = chunk_words
    while n > 1:
        x = x[: n // 2] ^ x[n // 2 :]
        n //= 2
    o_ref[0] = x[0] + jnp.sum(mixed, dtype=jnp.uint32)


def _chunk_fp_call(words: jax.Array, chunk_words: int,
                   interpret: bool) -> jax.Array:
    """pallas_call over an ALIGNED word stream (len % chunk_words == 0)."""
    nc = words.shape[0] // chunk_words
    kernel = functools.partial(_chunk_fp_kernel, chunk_words=chunk_words)
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((chunk_words,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((nc,), jnp.uint32),
        interpret=interpret,
    )(words)


@functools.partial(jax.jit, static_argnames=("chunk_words", "interpret"))
def chunk_fingerprints_pallas(words: jax.Array, *, chunk_words: int,
                              interpret: bool = False) -> jax.Array:
    """Per-chunk fingerprints of a uint32 word stream, on device.

    words: (N,) uint32 -> (ceil(N / chunk_words),) uint32, one digest per
    fixed-size chunk (the delta plane's dirty-chunk pre-filter: comparing
    these against the parent step's marks which chunks even need a content
    hash, at HBM bandwidth instead of host hash speed).  A ragged tail is
    zero-padded — same convention as every other impl, so the three agree
    bit-for-bit.  The pad touches ONLY the tail chunk (body and padded tail
    go through separate grids), so fingerprinting a big device-resident
    leaf never materializes an O(leaf) padded copy in HBM.  Same tiling
    idiom as ``checksum_pallas``: a 1-d grid over blocks with the per-chunk
    digest landing in SMEM; no scratch, since chunks don't combine across
    grid steps.
    """
    require_pow2(chunk_words, name="chunk_words")
    n = words.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    rem = n % chunk_words
    if not rem:
        return _chunk_fp_call(words, chunk_words, interpret)
    tail = jnp.pad(words[n - rem:], (0, chunk_words - rem))
    tail_fp = _chunk_fp_call(tail, chunk_words, interpret)
    if n == rem:
        return tail_fp
    body_fp = _chunk_fp_call(words[: n - rem], chunk_words, interpret)
    return jnp.concatenate([body_fp, tail_fp])
