"""RWKV6 (Finch) WKV recurrence — chunked formulations.

``wkv6_chunked_xla`` — pure-XLA chunked algorithm (log-space decays, fp32).
``wkv6_chunked`` — Pallas TPU kernel wrapper with the same contract.

Recurrence (matches ``ref.wkv6``):
    y_t   = r_t . (S_t + u * k_t v_t^T)
    S_t+1 = diag(w_t) S_t + k_t v_t^T
Unrolled within a chunk:  contribution of key j to query i>j carries the decay
prod_{l=j+1..i-1} w_l — computed as exp of cumulative-log differences.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _chunk_wkv_math(r, k, v, w, u, state_in):
    """One chunk, fp32.  r/k/v/w: (B,Q,H,D); u: (H,D); state: (B,H,D,D)."""
    B, Q, H, D = r.shape
    logw = jnp.log(jnp.maximum(w, 1e-30))
    cw = jnp.cumsum(logw, axis=1)                          # inclusive: sum_{l<=i} log w_l
    # decay from key j to query i (j < i): exp(cw_{i-1} - cw_j)
    # r_dec_i = r_i * exp(cw_{i-1}) ; k_dec_j = k_j * exp(-cw_j)
    cw_prev = jnp.concatenate([jnp.zeros_like(cw[:, :1]), cw[:, :-1]], axis=1)
    r_dec = r * jnp.exp(cw_prev)
    k_dec = k * jnp.exp(-cw)
    scores = jnp.einsum("bihd,bjhd->bhij", r_dec, k_dec)   # (B,H,Q,Q)
    ii = jnp.arange(Q)
    strict = (ii[None, :] < ii[:, None]).astype(scores.dtype)   # j < i
    scores = scores * strict[None, None]
    y = jnp.einsum("bhij,bjhd->bihd", scores, v)
    # diagonal (current-token) bonus term: r_i . (u * k_i v_i^T)
    diag = jnp.sum(r * u[None, None] * k, axis=-1)          # (B,Q,H)
    y = y + diag[..., None] * v
    # incoming state: y_i += (r_i * exp(cw_{i-1})) . S_in
    y = y + jnp.einsum("bihk,bhkv->bihv", r_dec, state_in)
    # state out: S_out = diag(prod w) S_in + sum_j (k_j * exp(cw_Q - cw_j)) v_j^T
    total = jnp.exp(cw[:, -1])                              # (B,H,D)
    k_carry = k * jnp.exp(cw[:, -1:, :, :] - cw)
    state_out = state_in * total[..., None] + jnp.einsum("bjhk,bjhv->bhkv", k_carry, v)
    return y, state_out


def wkv6_chunked_xla(r, k, v, w, u, *, chunk=128, init_state=None,
                     return_state=False):
    B, S, H, D = r.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    if init_state is None:
        init_state = jnp.zeros((B, H, D, D), jnp.float32)

    f32 = jnp.float32
    xs = tuple(
        z.reshape(B, nc, Q, H, D).swapaxes(0, 1).astype(f32) for z in (r, k, v, w)
    )
    uf = u.astype(f32)

    def step(state, inp):
        rc, kc, vc, wc = inp
        y, state = _chunk_wkv_math(rc, kc, vc, wc, uf, state)
        return state, y

    state, ys = jax.lax.scan(step, init_state, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, H, D).astype(r.dtype)
    if return_state:
        return y, state
    return y


def wkv6_step(r, k, v, w, u, state):
    """Single decode step.  r/k/v/w: (B,H,D); u: (H,D); state: (B,H,D,D)."""
    f32 = jnp.float32
    rf, kf, vf, wf = (z.astype(f32) for z in (r, k, v, w))
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, state + u.astype(f32)[None, :, :, None] * kv)
    state = state * wf[..., None] + kv
    return y.astype(r.dtype), state


def wkv6_chunked(r, k, v, w, u, *, chunk=128, init_state=None, return_state=False,
                 interpret=True):
    from repro.kernels._rwkv6_pallas import wkv6_pallas

    return wkv6_pallas(r, k, v, w, u, chunk=chunk, init_state=init_state,
                       return_state=return_state, interpret=interpret)
