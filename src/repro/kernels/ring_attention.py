"""Ring attention (context parallelism) over a mesh axis — beyond-paper perf.

Motivation (see EXPERIMENTS.md §Perf): archs whose head counts don't divide the
16-way model axis (qwen2: 14 q heads, 2 kv heads) fall back to *replicated*
attention — every model shard computes the full S^2 attention.  Ring attention
shards the SEQUENCE over the model axis instead: each device holds S/P queries
and S/P keys/values, and KV shards rotate around the ring via
``collective_permute`` while an online softmax accumulates — per-device
attention FLOPs and memory drop by P for any head count.

TPU mapping: the permute rides the ICI ring (the natural v5e topology); each
hop's block matmul is the same MXU tile as the flash kernel.  Causality: block
pairs with no visible elements are skipped via a where-mask (v1 computes masked
blocks — the striped-layout halving is a recorded further iteration).

Used under ``jax.shard_map`` with seq-sharded q/k/v; positions are derived from
``axis_index``.  Exact vs the ref oracle (tests/test_ring_attention.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# jax >= 0.6 promotes shard_map to jax.shard_map and renames check_rep ->
# check_vma; older jax ships it under experimental
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def _block_attend(q, k, v, q_off, k_off, scale, causal):
    """One masked flash block in fp32.  q: (B,Sq,Hkv,G,D) k/v: (B,Sk,Hkv,D)."""
    s = jnp.einsum("bqkgd,bskd->bqkgs", q, k) * scale
    if causal:
        qpos = q_off + jnp.arange(q.shape[1])
        kpos = k_off + jnp.arange(k.shape[1])
        mask = kpos[None, :] <= qpos[:, None]                  # (Sq,Sk)
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    # fully-masked rows: exp(-inf - -inf) guards
    m_safe = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(jnp.where(jnp.isinf(s), -jnp.inf, s - m_safe[..., None]))
    p = jnp.where(jnp.isnan(p), 0.0, p)
    l = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bqkgs,bskd->bqkgd", p, v)
    return m_safe, jnp.where(jnp.isinf(m), -jnp.inf, m_safe), l, pv


def ring_attention_local(q, k, v, *, axis_name: str, scale=None,
                         causal: bool = True, axis_size: Optional[int] = None):
    """Body to run under shard_map.  q/k/v: LOCAL shards (B, S/P, H|Hkv, D),
    sequence sharded over ``axis_name``.  Returns local out (B, S/P, H, Dv).
    ``axis_size`` is the static ring length; older jax has no
    ``jax.lax.axis_size``, so the wrapper passes it from the mesh."""
    if axis_size is not None:
        P = axis_size
    else:
        P = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    B, Sq, H, Dq = q.shape
    Hkv, Dv = k.shape[2], v.shape[-1]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / np.sqrt(Dq)
    qg = q.reshape(B, Sq, Hkv, G, Dq).astype(jnp.float32)
    q_off = idx * Sq

    perm = [(j, (j + 1) % P) for j in range(P)]

    def step(i, carry):
        acc, m, l, kb, vb = carry
        src = (idx - i) % P                     # rank that produced this block
        k_off = src * kb.shape[1]
        bm_raw, bm, bl, bpv = _block_attend(
            qg, kb.astype(jnp.float32), vb.astype(jnp.float32),
            q_off, k_off, scale, causal)
        m_new = jnp.maximum(m, bm)
        alpha = jnp.exp(jnp.where(jnp.isinf(m), -jnp.inf, m - m_new))
        beta = jnp.exp(jnp.where(jnp.isinf(bm), -jnp.inf, bm - m_new))
        l = l * alpha + bl * beta
        acc = acc * alpha[..., None] + bpv * beta[..., None]
        kb = jax.lax.ppermute(kb, axis_name, perm)
        vb = jax.lax.ppermute(vb, axis_name, perm)
        return (acc, m_new, l, kb, vb)

    acc0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    acc, m, l, _, _ = jax.lax.fori_loop(
        0, P, step, (acc0, m0, l0, k, v))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def ring_attention(q, k, v, *, mesh, axis: str = "model", scale=None,
                   causal: bool = True, batch_axes: Optional[tuple] = ("data",)):
    """pjit-callable wrapper: shards seq over ``axis``, batch over
    ``batch_axes``, runs the ring under shard_map."""
    from jax.sharding import PartitionSpec as P

    baxes = tuple(a for a in (batch_axes or ()) if a in mesh.axis_names)
    bspec = baxes[0] if len(baxes) == 1 else (baxes if baxes else None)
    spec_q = P(bspec, axis, None, None)
    fn = functools.partial(ring_attention_local, axis_name=axis, scale=scale,
                           causal=causal, axis_size=int(mesh.shape[axis]))
    return _shard_map(
        fn, mesh=mesh,
        in_specs=(spec_q, spec_q, spec_q),
        out_specs=spec_q,
        **{_CHECK_KW: False},
    )(q, k, v)
