"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

One (batch, head) pair per outer grid step; the innermost grid dim walks chunks
sequentially, carrying the (P x N) SSM state in VMEM scratch — the recurrence
never leaves the core.  Within a chunk everything is MXU matmuls:
CB^T (Q x Q), the masked-decay score @ x, and the B^T (w*x) state update.
A second output (the final state) is written on the last chunk for decode
handoff / checkpointing of in-flight sequences.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, st0_ref,
                y_ref, stout_ref, state_ref, *, nc, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = st0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)              # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)               # (Q,)
    Bm = b_ref[0].astype(jnp.float32)                      # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)                      # (Q, N)
    A = -jnp.exp(alog_ref[0].astype(jnp.float32))          # scalar
    D = d_ref[0].astype(jnp.float32)

    a = dt * A                                             # (Q,)
    cA = jnp.cumsum(a)                                     # inclusive
    state = state_ref[...]                                 # (P, N)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cA[:, None] - cA[None, :])
    scores = jnp.where(jj <= ii, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)
    # incoming-state contribution: exp(cA_i) * C_i @ state^T
    cst = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) # (Q,P)
    y = y + cst * jnp.exp(cA)[:, None]
    y = y + x * D
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: state' = state*exp(cA_Q) + sum_j w_j B_j x_j^T  -> (P,N)
    w = jnp.exp(cA[-1] - cA) * dt                          # (Q,)
    bx = jax.lax.dot_general(x * w[:, None], Bm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P,N)
    state_ref[...] = state * jnp.exp(cA[-1]) + bx

    @pl.when(ci == nc - 1)
    def _final():
        stout_ref[0, 0] = state_ref[...].astype(stout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "return_state", "interpret"))
def ssd_pallas(x, dt, A_log, Bm, Cm, D, *, chunk=128, init_state=None,
               return_state=False, interpret=False):
    """Contract identical to kernels/ref.py::ssd."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    y, stout = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, Bm, Cm, D, init_state)
    if return_state:
        return y, stout
    return y
