"""FLOP-exact blockwise causal attention in pure XLA (lax.scan over visible blocks).

This is the dry-run / CPU execution path for long sequences: memory is bounded by
one (block_q x block_k) score tile per step, and — unlike a naive masked softmax —
only *visible* (lower-triangular) blocks are ever computed, so ``cost_analysis``
FLOPs match the causal-attention roofline instead of double-counting masked work.
The Pallas flash kernel (kernels/flash_attention.py) is the TPU-target equivalent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def causal_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale=None,
    block_q: int = 1024,
    block_k: int = 1024,
) -> jax.Array:
    """q: (B,Sq,H,Dq)  k: (B,Skv,Hkv,Dq)  v: (B,Skv,Hkv,Dv) ; self-attention (Sq==Skv)."""
    B, Sq, H, Dq = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    if scale is None:
        scale = 1.0 / np.sqrt(Dq)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    # pad ragged sequences up to a block multiple; padded keys sit *after* all
    # real queries on the causal diagonal, so the causal mask hides them.
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    if pq or pk:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        out = causal_blockwise(q, k, v, scale=scale, block_q=block_q,
                               block_k=block_k)
        return out[:, :Sq]
    nq, nk = Sq // block_q, Skv // block_k

    # Enumerate visible (q-block, k-block) pairs in row-major order (j ascending per i)
    pairs = [
        (i, j)
        for i in range(nq)
        for j in range(nk)
        if j * block_k <= (i + 1) * block_q - 1
    ]
    ii = jnp.array([p[0] for p in pairs], jnp.int32)
    jj = jnp.array([p[1] for p in pairs], jnp.int32)

    qg = q.reshape(B, Sq, Hkv, G, Dq)
    acc0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)

    def body(carry, ij):
        acc, m, l = carry
        i, j = ij
        qs, ks = i * block_q, j * block_k
        qb = jax.lax.dynamic_slice_in_dim(qg, qs, block_q, axis=1)   # (B,bq,Hkv,G,Dq)
        kb = jax.lax.dynamic_slice_in_dim(k, ks, block_k, axis=1)    # (B,bk,Hkv,Dq)
        vb = jax.lax.dynamic_slice_in_dim(v, ks, block_k, axis=1)    # (B,bk,Hkv,Dv)
        s = jnp.einsum(
            "bqkgd,bskd->bqkgs", qb.astype(jnp.float32), kb.astype(jnp.float32)
        ) * scale                                                     # (B,bq,Hkv,G,bk)
        qpos = qs + jnp.arange(block_q)
        kpos = ks + jnp.arange(block_k)
        mask = kpos[None, :] <= qpos[:, None]                         # (bq,bk)
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)

        mb = jax.lax.dynamic_slice_in_dim(m, qs, block_q, axis=1)
        lb = jax.lax.dynamic_slice_in_dim(l, qs, block_q, axis=1)
        ab = jax.lax.dynamic_slice_in_dim(acc, qs, block_q, axis=1)

        m_new = jnp.maximum(mb, jnp.max(s, axis=-1))
        # rows with everything masked so far keep m=-inf; guard the exp
        alpha = jnp.exp(jnp.where(jnp.isinf(mb), -jnp.inf, mb - m_new))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isnan(p), 0.0, p)
        l_new = lb * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p, vb.astype(jnp.float32))
        a_new = ab * alpha[..., None] + pv

        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, qs, axis=1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, qs, axis=1)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, qs, axis=1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (ii, jj))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)
