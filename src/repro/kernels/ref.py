"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels (interpret mode on CPU, compiled on
TPU) are validated against, and the small-shape fast path used by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _grouped(q: jax.Array, num_kv_heads: int) -> jax.Array:
    """(B,S,H,D) -> (B,S,Hkv,G,D) where H = Hkv*G."""
    B, S, H, D = q.shape
    G = H // num_kv_heads
    return q.reshape(B, S, num_kv_heads, G, D)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    kv_len=None,
    scale=None,
    q_offset=0,
) -> jax.Array:
    """Naive masked attention oracle.

    q: (B,Sq,H,Dq)   k: (B,Skv,Hkv,Dq)   v: (B,Skv,Hkv,Dv)  with H % Hkv == 0.
    ``kv_len`` (scalar) masks cache positions >= kv_len.  ``q_offset`` shifts the
    causal diagonal (query i attends keys <= q_offset + i).
    """
    B, Sq, H, Dq = q.shape
    Hkv = k.shape[2]
    if scale is None:
        scale = 1.0 / np.sqrt(Dq)
    qg = _grouped(q, Hkv).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    Skv = k.shape[1]
    mask = None
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Skv)[None, :]
        mask = ki <= qi
    if kv_len is not None:
        lm = jnp.arange(Skv)[None, :] < kv_len
        mask = lm if mask is None else (mask & lm)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# ----------------------------------------------------------------------------------
# Mamba2 SSD oracle: sequential recurrence over time.
# ----------------------------------------------------------------------------------


def ssd(x, dt, A_log, Bm, Cm, D, *, init_state=None, return_state=False):
    """Mamba2 selective-state-space oracle (per-step recurrence).

    x:  (B,S,H,P)   channels grouped into H heads of dim P
    dt: (B,S,H)     softplus-activated step sizes (already positive)
    A_log: (H,)     state decay (A = -exp(A_log))
    Bm: (B,S,N)     input matrix  (single group)
    Cm: (B,S,N)     output matrix (single group)
    D:  (H,)        skip
    state: (B,H,P,N)
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P) (B,H) (B,N) (B,N)
        decay = jnp.exp(dtt.astype(jnp.float32) * A)            # (B,H)
        dbx = jnp.einsum("bh,bn,bhp->bhpn", dtt.astype(jnp.float32),
                         bt.astype(jnp.float32), xt.astype(jnp.float32))
        state = state * decay[..., None, None] + dbx
        yt = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, yt

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bm, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    state, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1)                                   # (B,S,H,P)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, state
    return y


# ----------------------------------------------------------------------------------
# RWKV6 WKV oracle: sequential recurrence over time.
# ----------------------------------------------------------------------------------


def wkv6(r, k, v, w, u, *, init_state=None, return_state=False):
    """RWKV6 recurrence oracle.

    r,k,v: (B,S,H,D)    w: (B,S,H,D) per-step decay in (0,1)    u: (H,D) bonus.
    state: (B,H,D,D)  maps k-dim -> v-dim.
    y_t = r_t . (state + u*k_t v_t^T);  state' = diag(w_t) state + k_t v_t^T
    """
    B, S, H, D = r.shape
    if init_state is None:
        init_state = jnp.zeros((B, H, D, D), jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = [z.astype(jnp.float32) for z in inp]    # (B,H,D)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, state + u.astype(jnp.float32)[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, yt

    xs = tuple(jnp.moveaxis(z, 1, 0) for z in (r, k, v, w))
    state, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(r.dtype)
    if return_state:
        return y, state
    return y


# ----------------------------------------------------------------------------------
# Checkpoint checksum oracle (blocked FNV-style rolling hash over int32 words).
# ----------------------------------------------------------------------------------


def checksum(words: jax.Array) -> jax.Array:
    """words: (N,) uint32 -> scalar uint32 digest (order-dependent)."""
    PRIME = jnp.uint32(16777619)
    idx = jnp.arange(words.shape[0], dtype=jnp.uint32)
    mixed = (words.astype(jnp.uint32) ^ (idx * PRIME)) * (idx | jnp.uint32(1))
    return jnp.bitwise_xor.reduce(mixed) + jnp.sum(mixed, dtype=jnp.uint32)


def chunk_fingerprints(words: jax.Array, chunk_words: int) -> jax.Array:
    """Per-chunk digests: (N,) uint32 -> (ceil(N / chunk_words),) uint32; a
    ragged tail is zero-padded (the shared convention — a zero word still
    mixes to a nonzero value, so padding is part of the definition).  Same
    FNV-style mix as ``checksum`` but with the index CHUNK-LOCAL, so each
    chunk's value is independent of its position — the property the delta
    plane's dirty-chunk pre-filter needs.  Oracle for
    checksum.chunk_fingerprints_pallas and the numpy
    serialization.fingerprint_chunks path (all three bit-identical)."""
    PRIME = jnp.uint32(16777619)
    pad = (-words.shape[0]) % chunk_words
    if pad:
        words = jnp.pad(words, (0, pad))
    w = words.astype(jnp.uint32).reshape(-1, chunk_words)
    idx = jnp.arange(chunk_words, dtype=jnp.uint32)[None, :]
    mixed = (w ^ (idx * PRIME)) * (idx | jnp.uint32(1))
    return jnp.bitwise_xor.reduce(mixed, axis=1) + jnp.sum(
        mixed, axis=1, dtype=jnp.uint32)
